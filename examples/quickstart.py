"""Quickstart — the paper's Framework Usage box, runnable end to end.

    import GETA                      ->  repro.core / repro.launch.train
    geta = GETA(model)               ->  build_geta(lm, compression_cfg)
    optimizer = geta.qasso()         ->  QASSO(...)
    optimizer.step()                 ->  qasso.update(...)
    geta.construct_subnet()          ->  construct_subnet(...)

Runs a tiny LM through the full 4-stage joint pruning + QAT pipeline on CPU
(~1 minute) and exports the pruned + int-quantized subnet.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import CompressionConfig, get_arch
from repro.core.subnet import construct_subnet
from repro.data.synthetic import batch_for
from repro.launch.train import build_geta, make_geta_train_step
from repro.models.transformer import LM


def main():
    # 1. any DNN from the model zoo (reduced config for CPU speed)
    cfg = get_arch("internlm2-1.8b", smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))

    # 2. geta = GETA(model): QADG analysis + QASSO optimizer
    comp = CompressionConfig(
        target_sparsity=0.4, bit_lower=4, bit_upper=16,
        warmup_steps=8, projection_periods=2, projection_steps=6,
        pruning_periods=3, pruning_steps=6, cooldown_steps=12)
    qadg, qasso = build_geta(lm, comp, lr=1e-3)
    qparams = lm.init_qparams(params, bits_init=16.0)
    qstate = qasso.init(params, qparams)
    print(f"QADG: {len(qadg.sites)} quant sites, "
          f"{qadg.space.total_units()} prunable structures")

    # 3. train as normal — optimizer.step()
    step = jax.jit(make_geta_train_step(lm, qasso))
    total = qasso.cfg.total_steps
    for i in range(total):
        batch = batch_for(cfg, seed=0, step=i, batch=4, seq=32)
        params, qparams, qstate, metrics = step(params, qparams, qstate,
                                                batch)
        if i % 10 == 0 or i == total - 1:
            print(f"step {i:3d} stage={int(metrics['stage'])} "
                  f"loss={float(metrics['loss']):.3f} "
                  f"bits=[{float(metrics['bits_min']):.1f},"
                  f"{float(metrics['bits_max']):.1f}] "
                  f"sparsity={float(metrics['sparsity_hard']):.2f}")

    # 4. quantized pruned DNN
    subnet = construct_subnet(qadg, params, qparams, qstate.keep_mask)
    print(f"subnet: sparsity={subnet.meta['sparsity']:.2f} "
          f"mean_bits={subnet.meta['mean_bits']:.1f} "
          f"int weights={len(subnet.int_weights)} tensors")


if __name__ == "__main__":
    main()

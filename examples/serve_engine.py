"""Drive the continuous-batching engine over mixed-length requests.

Submits a handful of requests with different prompt lengths and token
budgets, drains the engine, and prints each request's generated tokens
plus the throughput counters (decode tok/s, one-shot prefill tok/s, slot
occupancy). `--compressed` serves from Subnet int8 codes through the
quant-dequant GEMM epilogue; `--packed` bit-packs the codes at their
learned sub-byte storage widths (unpack-dequant epilogue, DESIGN.md
§4.8); `--pruned` physically slices the model to magnitude masks first
(surviving heads / MLP hidden / experts only — the GEMMs and the KV
arena shrink with realized sparsity). Stacked, they are the full
deployment path: sub-byte codes at pruned shapes. `--speculative`
attaches the self-speculative draft — the same checkpoint sliced to
`--draft-sparsity` and packed at `--draft-bits` proposes up to
`--draft-k` tokens per round, the target verifies them in one chunked
pass, and the output stream stays token-identical to the plain engine
(DESIGN.md §4.10); the report line adds the acceptance rate.
`--paged` swaps the per-slot contiguous KV arena for the paged block
arena (DESIGN.md §4.11): per-request KV is page-granular, identical
prompts share refcounted pages and skip their prefill (`--hot-prompt`
sends every request the same prompt — watch `prefix_hits`), and
`--kv-bits 8|4` stores the pages as int8/nibble-packed codes
dequantized in-VMEM by the flash-decode kernel. `--tp N` shards the
whole stack over an N-device mesh — attention heads, MLP hidden, vocab,
and the KV arena's head axis — token-identical to 1 device with
per-device param/KV bytes ~1/N (`--devices N` forces N fake host
devices for trying this on a CPU box); `--chunked-prefill C` prefills
prompts at most C rows per step into a staging row so decode keeps
running mid-prefill (DESIGN.md §4.12).

    PYTHONPATH=src python examples/serve_engine.py --devices 4 --tp 4 \
        --packed --bits 4 --prompt-lens 16,4,9,12 --gens 12 --slots 2

    PYTHONPATH=src python examples/serve_engine.py --chunked-prefill 8 \
        --prompt-lens 6,40 --gens 24,8 --slots 2

    PYTHONPATH=src python examples/serve_engine.py --packed --pruned \
        --bits 4 --prompt-lens 16,4,9,12 --gens 24,8,16,12 --slots 2

    PYTHONPATH=src python examples/serve_engine.py --speculative \
        --draft-k 4 --draft-sparsity 0 --draft-bits 8 \
        --prompt-lens 16,4,9,12 --gens 24,8,16,12 --slots 2

    PYTHONPATH=src python examples/serve_engine.py --paged --kv-bits 8 \
        --hot-prompt --prompt-lens 16,16,16,9 --gens 12 --slots 2

(On these random-init smoke weights only a keep-all draft tracks the
target — `--draft-sparsity 0` shows acceptance ~1.0. A GETA cooldown
checkpoint, whose pruned groups are already zero, gets the same
acceptance from its s50 slice: `launch.speculative.
build_checkpoint_engines` and `BENCH_speculative.json` cover that pair.)
"""
import argparse

from repro.launch.engine import build_engine, synthetic_prompts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--prompt-lens", default="16,4,9,12",
                    help="comma-separated per-request prompt lengths")
    ap.add_argument("--gens", default="24,8,16,12",
                    help="comma-separated per-request token budgets "
                         "(a single value broadcasts)")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--no-quant", dest="quant", action="store_false",
                    default=True)
    ap.add_argument("--compressed", action="store_true", default=False,
                    help="decode from Subnet int codes (quant-dequant GEMM "
                         "epilogue) instead of dense weights")
    ap.add_argument("--packed", action="store_true", default=False,
                    help="bit-pack the codes at each site's learned storage "
                         "width (2/3/4/8) and decode via the unpack-dequant "
                         "epilogue (implies --compressed)")
    ap.add_argument("--bits", type=float, default=8.0,
                    help="quantizer init width (e.g. 4 serves a genuinely "
                         "4-bit packed artifact)")
    ap.add_argument("--pruned", action="store_true", default=False,
                    help="physically slice the model to magnitude masks at "
                         "--sparsity and serve the pruned shapes (smaller "
                         "GEMMs, shrunk KV arena); stacks with --compressed")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--speculative", action="store_true", default=False,
                    help="draft/verify decoding: a sliced+packed subnet of "
                         "the same checkpoint drafts tokens, the target "
                         "verifies them in one chunked pass — "
                         "token-identical output, fewer target dispatches")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="max proposals per speculative round")
    ap.add_argument("--draft-sparsity", type=float, default=0.5,
                    help="draft subnet sparsity (0 keeps all units)")
    ap.add_argument("--draft-bits", type=float, default=8.0,
                    help="draft quantizer width (8 tracks the target "
                         "closely; 2 is cheap but rarely accepted)")
    ap.add_argument("--paged", action="store_true", default=False,
                    help="paged KV arena: page-granular allocation + "
                         "whole-prompt prefix sharing (DESIGN.md §4.11)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged mode: KV rows per page (multiple of 8)")
    ap.add_argument("--kv-bits", type=int, default=None, choices=[4, 8],
                    help="paged mode: int8/int4 page store (implies "
                         "--paged; approximate numerics)")
    ap.add_argument("--hot-prompt", action="store_true", default=False,
                    help="requests with equal --prompt-lens entries send "
                         "the *identical* prompt (prefixes of the first "
                         "request's tokens) — the prefix-sharing demo: "
                         "repeats admit with prefix_hits, no prefill")
    ap.add_argument("--tp", type=int, default=0,
                    help="shard the engine over a tp-device mesh "
                         "(attention heads / MLP hidden / vocab / KV-head "
                         "axis) — token-identical to 1 device, per-device "
                         "bytes ~1/tp (DESIGN.md §4.12)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N fake host devices "
                         "(xla_force_host_platform_device_count) so --tp "
                         "runs on a CPU box")
    ap.add_argument("--chunked-prefill", type=int, default=None,
                    metavar="CHUNK",
                    help="prefill prompts at most CHUNK rows per step into "
                         "a staging row so decode keeps running mid-prefill "
                         "(DESIGN.md §4.12)")
    args = ap.parse_args()
    if args.devices:
        import os
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        if "xla_force_host_platform_device_count" not in \
                os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = \
                (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()
    if args.kv_bits is not None:
        args.paged = True

    lens = [int(x) for x in args.prompt_lens.split(",")]
    gens = [int(x) for x in args.gens.split(",")]
    if len(gens) == 1:
        gens = gens * len(lens)
    assert len(gens) == len(lens), "--gens must match --prompt-lens"

    eng, lm = build_engine(args.arch, smoke=True, quantized=args.quant,
                           compressed=args.compressed, packed=args.packed,
                           bits_init=args.bits, pruned=args.pruned,
                           sparsity=args.sparsity, max_slots=args.slots,
                           max_seq=max(p + g for p, g in zip(lens, gens)),
                           verbose=True, speculative=args.speculative,
                           draft_k=args.draft_k,
                           draft_sparsity=args.draft_sparsity,
                           draft_bits=args.draft_bits, paged=args.paged,
                           page_size=args.page_size, kv_bits=args.kv_bits,
                           tp=args.tp, prefill_chunk=args.chunked_prefill)
    prompts = synthetic_prompts(lm.cfg, lens)
    if args.hot_prompt:
        prompts = [prompts[0][:n].copy() for n in lens]
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.warmup()
    out = eng.run()
    for rid, n, g in zip(rids, lens, gens):
        toks = " ".join(str(t) for t in out[rid][:12])
        more = " ..." if len(out[rid]) > 12 else ""
        print(f"request {rid}: prompt {n} tokens -> {len(out[rid])}/{g} "
              f"generated: {toks}{more}")
    th = eng.throughput()
    s = eng.stats
    line = (f"decode: {s['decode_tokens']} tokens in {s['decode_s']:.2f}s "
            f"({th['decode_tok_per_s']:.1f} tok/s, occupancy "
            f"{th['slot_occupancy']:.2f} over {args.slots} slots); "
            f"one-shot prefill: {s['prefill_tokens']} tokens "
            f"({th['prefill_tok_per_s']:.1f} tok/s)")
    if args.speculative:
        line += (f"; speculative: {s['spec_accepted']}/{s['spec_drafted']} "
                 f"drafted tokens accepted "
                 f"({th['acceptance_rate']:.2f}) over {s['spec_steps']} "
                 f"rounds")
    if args.paged:
        line += (f"; paged: {s['prefills']} prefills, "
                 f"{s['prefix_hits']} prefix hits, kv_bytes "
                 f"{eng.kv_bytes()} of {eng.kv_pool_bytes()} pooled")
    if args.tp:
        line += (f"; tp={args.tp}: param bytes/device "
                 f"{eng.param_bytes(per_device=True)} of "
                 f"{eng.param_bytes()}, kv bytes/device "
                 f"{eng.kv_bytes(per_device=True)} of {eng.kv_bytes()}")
    if args.chunked_prefill:
        line += (f"; chunked@{args.chunked_prefill}: "
                 f"{s['prefill_chunks']} chunks, "
                 f"{s['decode_steps_mid_prefill']} decode steps "
                 f"mid-prefill")
    print(line)


if __name__ == "__main__":
    main()

"""Drive the continuous-batching engine over mixed-length requests.

Submits a handful of requests with different prompt lengths and token
budgets, drains the engine, and prints each request's generated tokens
plus the throughput counters (decode tok/s, one-shot prefill tok/s, slot
occupancy). `--compressed` serves from Subnet int8 codes through the
quant-dequant GEMM epilogue; `--packed` bit-packs the codes at their
learned sub-byte storage widths (unpack-dequant epilogue, DESIGN.md
§4.8); `--pruned` physically slices the model to magnitude masks first
(surviving heads / MLP hidden / experts only — the GEMMs and the KV
arena shrink with realized sparsity). Stacked, they are the full
deployment path: sub-byte codes at pruned shapes.

    PYTHONPATH=src python examples/serve_engine.py --packed --pruned \
        --bits 4 --prompt-lens 16,4,9,12 --gens 24,8,16,12 --slots 2
"""
import argparse

from repro.launch.engine import build_engine, synthetic_prompts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--prompt-lens", default="16,4,9,12",
                    help="comma-separated per-request prompt lengths")
    ap.add_argument("--gens", default="24,8,16,12",
                    help="comma-separated per-request token budgets "
                         "(a single value broadcasts)")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--no-quant", dest="quant", action="store_false",
                    default=True)
    ap.add_argument("--compressed", action="store_true", default=False,
                    help="decode from Subnet int codes (quant-dequant GEMM "
                         "epilogue) instead of dense weights")
    ap.add_argument("--packed", action="store_true", default=False,
                    help="bit-pack the codes at each site's learned storage "
                         "width (2/3/4/8) and decode via the unpack-dequant "
                         "epilogue (implies --compressed)")
    ap.add_argument("--bits", type=float, default=8.0,
                    help="quantizer init width (e.g. 4 serves a genuinely "
                         "4-bit packed artifact)")
    ap.add_argument("--pruned", action="store_true", default=False,
                    help="physically slice the model to magnitude masks at "
                         "--sparsity and serve the pruned shapes (smaller "
                         "GEMMs, shrunk KV arena); stacks with --compressed")
    ap.add_argument("--sparsity", type=float, default=0.5)
    args = ap.parse_args()

    lens = [int(x) for x in args.prompt_lens.split(",")]
    gens = [int(x) for x in args.gens.split(",")]
    if len(gens) == 1:
        gens = gens * len(lens)
    assert len(gens) == len(lens), "--gens must match --prompt-lens"

    eng, lm = build_engine(args.arch, smoke=True, quantized=args.quant,
                           compressed=args.compressed, packed=args.packed,
                           bits_init=args.bits, pruned=args.pruned,
                           sparsity=args.sparsity, max_slots=args.slots,
                           max_seq=max(p + g for p, g in zip(lens, gens)),
                           verbose=True)
    rids = [eng.submit(p, g) for p, g in
            zip(synthetic_prompts(lm.cfg, lens), gens)]
    eng.warmup()
    out = eng.run()
    for rid, n, g in zip(rids, lens, gens):
        toks = " ".join(str(t) for t in out[rid][:12])
        more = " ..." if len(out[rid]) > 12 else ""
        print(f"request {rid}: prompt {n} tokens -> {len(out[rid])}/{g} "
              f"generated: {toks}{more}")
    th = eng.throughput()
    s = eng.stats
    print(f"decode: {s['decode_tokens']} tokens in {s['decode_s']:.2f}s "
          f"({th['decode_tok_per_s']:.1f} tok/s, occupancy "
          f"{th['slot_occupancy']:.2f} over {args.slots} slots); "
          f"one-shot prefill: {s['prefill_tokens']} tokens "
          f"({th['prefill_tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()

"""Compress a CNN (the paper's Table 2/4 setting) on synthetic CIFAR.

Joint structured pruning + mixed-precision QAT on ResNet20(reduced),
reporting accuracy + relative BOPs against the FP32 baseline.

    PYTHONPATH=src python examples/compress_cnn.py [--steps 240]
"""
import argparse
import sys

sys.path.insert(0, "benchmarks") if "benchmarks" not in sys.path else None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=160)
    ap.add_argument("--sparsity", type=float, default=0.35)
    ap.add_argument("--act-quant", action="store_true")
    args = ap.parse_args()

    from benchmarks.geta_experiments import (RESNET20_R, run_baseline_cnn,
                                             run_geta_cnn)
    print("training FP32 baseline ...")
    base = run_baseline_cnn(RESNET20_R, steps=args.steps)
    print(f"baseline: acc={base['acc']:.3f} rel_bops=1.0")
    print("training GETA joint compressed ...")
    geta = run_geta_cnn(RESNET20_R, steps=args.steps,
                        sparsity=args.sparsity, act_quant=args.act_quant)
    print(f"GETA:     acc={geta['acc']:.3f} "
          f"rel_bops={geta['rel_bops']:.4f} "
          f"sparsity={geta['sparsity']:.2f} "
          f"mean_bits={geta['mean_bits']:.1f}")


if __name__ == "__main__":
    main()

"""Serve a (QAT-quantized) LM with batched KV-cache decoding.

    PYTHONPATH=src python examples/serve_compressed.py --gen 32
"""
import argparse

from repro.launch.serve import serve_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--no-quant", dest="quant", action="store_false",
                    default=True)
    args = ap.parse_args()
    serve_loop(args.arch, smoke=True, batch=args.batch,
               prompt_len=args.prompt_len, gen=args.gen,
               quantized=args.quant)


if __name__ == "__main__":
    main()

"""Serve an LM with batched KV-cache decoding — dense fake-quant params or
the compressed Subnet int-code path (see examples/README.md §4).

    PYTHONPATH=src python examples/serve_compressed.py --gen 32 --compressed
"""
import argparse

from repro.launch.serve import serve_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--no-quant", dest="quant", action="store_false",
                    default=True)
    ap.add_argument("--compressed", action="store_true", default=False,
                    help="decode from Subnet int codes (quant-dequant GEMM "
                         "epilogue) instead of dense weights")
    args = ap.parse_args()
    serve_loop(args.arch, smoke=True, batch=args.batch,
               prompt_len=args.prompt_len, gen=args.gen,
               quantized=args.quant, compressed=args.compressed)


if __name__ == "__main__":
    main()

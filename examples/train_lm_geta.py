"""End-to-end LM training driver with GETA, checkpointing and fault
tolerance — the production loop at reduced scale.

Default config is a ~10M-param model that trains a few hundred steps in
minutes on this CPU container; pass --hundred-m for the ~100M-param variant
(the documented target scale; budget ~1 s/step x steps on CPU, instant on
a real accelerator).

    PYTHONPATH=src python examples/train_lm_geta.py --steps 200

Sharded (data-parallel over N devices; on a CPU host N fake XLA devices
are forced before jax initializes — add --fsdp to shard params/opt-state):

    PYTHONPATH=src python examples/train_lm_geta.py --steps 50 --devices 4
"""
import argparse
import dataclasses
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/geta_lm_ckpt")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="step at which to simulate a node failure")
    ap.add_argument("--devices", type=int, default=None,
                    help="data-parallel mesh over N devices (CPU hosts get "
                         "N forced XLA host devices)")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params + optimizer state over the data axis")
    args = ap.parse_args()

    if args.devices and args.devices > 1:
        # must precede the first jax import — jax locks the device count.
        # Append to any existing XLA_FLAGS (setdefault would silently
        # leave the host single-device when the user has unrelated flags
        # exported); an explicit device-count flag in the env wins.
        flags = os.environ.get("XLA_FLAGS", "")
        if "force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    from repro.configs import CompressionConfig
    from repro.launch.train import train_loop

    mesh = None
    if args.devices:
        import jax

        from repro.launch.mesh import make_subset_mesh
        n = min(args.devices, jax.device_count())
        if n < args.devices:
            print(f"requested {args.devices} devices, host has "
                  f"{jax.device_count()}; using {n}")
        if args.batch % n != 0:
            raise SystemExit(f"--batch {args.batch} must divide by {n}")
        mesh = make_subset_mesh(n)

    arch = "internlm2-1.8b"
    if args.hundred_m:
        # ~100M params: widen the smoke family
        import repro.configs.internlm2_1_8b as M
        M.SMOKE = dataclasses.replace(
            M.SMOKE, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
            d_head=64, d_ff=2048, vocab=32000)

    comp = CompressionConfig(
        target_sparsity=0.3, bit_lower=4, bit_upper=16,
        warmup_steps=args.steps // 8,
        projection_periods=2, projection_steps=args.steps // 10,
        pruning_periods=4, pruning_steps=args.steps // 10,
        cooldown_steps=args.steps // 4)
    state, qadg, qasso, losses = train_loop(
        arch, smoke=True, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, comp=comp,
        inject_failure_at=args.inject_failure,
        mesh=mesh, fsdp=args.fsdp)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"sparsity={float(qasso.space.sparsity(state['qstate'].keep_mask)):.2f}")


if __name__ == "__main__":
    main()

"""End-to-end LM training driver with GETA, checkpointing and fault
tolerance — the production loop at reduced scale.

Default config is a ~10M-param model that trains a few hundred steps in
minutes on this CPU container; pass --hundred-m for the ~100M-param variant
(the documented target scale; budget ~1 s/step x steps on CPU, instant on
a real accelerator).

    PYTHONPATH=src python examples/train_lm_geta.py --steps 200
"""
import argparse
import dataclasses

from repro.configs import CompressionConfig, get_arch
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/geta_lm_ckpt")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="step at which to simulate a node failure")
    args = ap.parse_args()

    arch = "internlm2-1.8b"
    if args.hundred_m:
        # ~100M params: widen the smoke family
        import repro.configs.internlm2_1_8b as M
        M.SMOKE = dataclasses.replace(
            M.SMOKE, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
            d_head=64, d_ff=2048, vocab=32000)

    comp = CompressionConfig(
        target_sparsity=0.3, bit_lower=4, bit_upper=16,
        warmup_steps=args.steps // 8,
        projection_periods=2, projection_steps=args.steps // 10,
        pruning_periods=4, pruning_steps=args.steps // 10,
        cooldown_steps=args.steps // 4)
    state, qadg, qasso, losses = train_loop(
        arch, smoke=True, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, comp=comp,
        inject_failure_at=args.inject_failure)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"sparsity={float(qasso.space.sparsity(state['qstate'].keep_mask)):.2f}")


if __name__ == "__main__":
    main()

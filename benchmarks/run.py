"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. `us_per_call` is the wall
time of one GETA train step on this host (CPU); `derived` carries the
table's headline quantity (accuracy/EM @ rel-BOPs, ablation deltas, ...).

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_table2_resnet20(fast=False):
    """Table 2: ResNet20/CIFAR10 — GETA structured vs baseline (wt quant)."""
    from benchmarks.geta_experiments import (RESNET20_R, run_baseline_cnn,
                                             run_geta_cnn)
    steps = 120 if fast else 240
    base = run_baseline_cnn(RESNET20_R, steps=steps)
    geta = run_geta_cnn(RESNET20_R, steps=steps, sparsity=0.35,
                        act_quant=False)
    us = geta["wall_s"] / max(steps, 1) * 1e6
    _row("table2_resnet20_baseline", 0.0,
         f"acc={base['acc']:.3f};rel_bops=1.0")
    _row("table2_resnet20_geta", us,
         f"acc={geta['acc']:.3f};rel_bops={geta['rel_bops']:.4f};"
         f"sparsity={geta['sparsity']:.2f};bits={geta['mean_bits']:.1f}")
    return {"base": base, "geta": geta}


def bench_table4_vgg7(fast=False):
    """Table 4: VGG7/CIFAR10 — weight AND activation quantization."""
    from benchmarks.geta_experiments import (VGG7_R, run_baseline_cnn,
                                             run_geta_cnn)
    steps = 100 if fast else 200
    base = run_baseline_cnn(VGG7_R, steps=steps)
    geta = run_geta_cnn(VGG7_R, steps=steps, sparsity=0.5, act_quant=True)
    us = geta["wall_s"] / max(steps, 1) * 1e6
    _row("table4_vgg7_baseline", 0.0, f"acc={base['acc']:.3f};rel_bops=1.0")
    _row("table4_vgg7_geta_wa", us,
         f"acc={geta['acc']:.3f};rel_bops={geta['rel_bops']:.4f}")
    return {"base": base, "geta": geta}


def bench_table5_resnet56(fast=False):
    """Table 5 analogue: deeper CNN at two sparsities (40%/50%)."""
    from benchmarks.geta_experiments import RESNET56_R, run_geta_cnn
    steps = 100 if fast else 200
    out = {}
    for sp in (0.4, 0.5):
        r = run_geta_cnn(RESNET56_R, steps=steps, sparsity=sp)
        us = r["wall_s"] / max(steps, 1) * 1e6
        _row(f"table5_resnet56_sp{int(sp*100)}", us,
             f"acc={r['acc']:.3f};rel_bops={r['rel_bops']:.4f}")
        out[sp] = r
    return out


def bench_table3_bert(fast=False):
    """Table 3: BERT/SQuAD-style — GETA joint vs prune-then-PTQ."""
    from benchmarks.geta_experiments import (run_geta_bert,
                                             run_prune_then_ptq_bert)
    steps = 100 if fast else 200
    sparsities = (0.3, 0.5) if fast else (0.1, 0.3, 0.5, 0.7)
    out = {}
    for sp in sparsities:
        t0 = time.time()
        joint = run_geta_bert(sp, steps=steps)
        us = (time.time() - t0) / steps * 1e6
        seq = run_prune_then_ptq_bert(sp, steps=steps)
        _row(f"table3_bert_sp{int(sp*100)}_geta", us,
             f"em={joint['em']:.3f};rel_bops={joint['rel_bops']:.4f}")
        _row(f"table3_bert_sp{int(sp*100)}_prune_ptq", us,
             f"em={seq['em']:.3f};rel_bops={seq['rel_bops']:.4f}")
        out[sp] = {"joint": joint, "sequential": seq}
    return out


def bench_fig4a_ablation(fast=False):
    """Fig 4a: remove each QASSO stage, measure the accuracy drop."""
    from benchmarks.geta_experiments import RESNET56_R, run_geta_cnn
    steps = 80 if fast else 160
    full = run_geta_cnn(RESNET56_R, steps=steps, sparsity=0.35)
    _row("fig4a_full", 0.0, f"acc={full['acc']:.3f}")
    out = {"full": full}
    for stage in ("warmup", "projection", "joint", "cooldown"):
        r = run_geta_cnn(RESNET56_R, steps=steps, sparsity=0.35,
                         skip_stage=stage)
        _row(f"fig4a_no_{stage}", 0.0,
             f"acc={r['acc']:.3f};delta={r['acc']-full['acc']:+.3f}")
        out[stage] = r
    return out


def bench_fig4b_frontier(fast=False):
    """Fig 4b: sparsity x bit-range compression frontier."""
    from benchmarks.geta_experiments import RESNET56_R, run_geta_cnn
    steps = 60 if fast else 120
    grid_sp = (0.3, 0.6) if fast else (0.3, 0.5, 0.7)
    grid_b = ((4, 6),) if fast else ((2, 4), (4, 6), (6, 8))
    out = {}
    for sp in grid_sp:
        for (bl, bu) in grid_b:
            r = run_geta_cnn(RESNET56_R, steps=steps, sparsity=sp,
                             b_l=float(bl), b_u=float(bu) + 8)
            _row(f"fig4b_sp{int(sp*100)}_b{bl}", 0.0,
                 f"acc={r['acc']:.3f};rel_bops={r['rel_bops']:.4f}")
            out[(sp, bl)] = r
    return out


def bench_kernel_fake_quant(fast=False):
    """Fused fake-quant op vs eager op-chain (CPU timings; the TPU win is
    the single HBM round-trip, see DESIGN.md)."""
    from repro.core.quant import fake_quant
    from repro.kernels.ref import fake_quant_fwd_ref
    x = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))
    d, qm, t = jnp.float32(0.05), jnp.float32(1.2), jnp.float32(0.9)

    fused = jax.jit(lambda x: fake_quant(x, d, qm, t))
    ref = jax.jit(lambda x: fake_quant_fwd_ref(x, d, qm, t))
    fused(x).block_until_ready()
    ref(x).block_until_ready()
    n = 20 if fast else 50
    t0 = time.time()
    for _ in range(n):
        fused(x).block_until_ready()
    tf = (time.time() - t0) / n * 1e6
    t0 = time.time()
    for _ in range(n):
        ref(x).block_until_ready()
    tr = (time.time() - t0) / n * 1e6
    _row("kernel_fake_quant_fused", tf, f"ref_us={tr:.1f}")
    return {"fused_us": tf, "ref_us": tr}


def bench_kernel_fused_joint(fast=False):
    """Fused x @ (fake_quant(w) * mask) GEMM epilogue vs the unfused
    quantize -> mask -> matmul chain (three HBM passes of W). Timed on this
    host's default dispatch backend; the TPU win is the single HBM pass of
    W (DESIGN.md §4)."""
    from repro.core.quant import fake_quant
    from repro.kernels import ops
    m, k, n = (256, 1024, 1024) if fast else (512, 2048, 2048)
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (n,)) > 0.4).astype(
        jnp.float32)
    d, qm, t = jnp.float32(0.05), jnp.float32(1.2), jnp.float32(0.9)

    fused = jax.jit(lambda x, w: ops.fq_masked_matmul_op(x, w, mask, d, qm, t))
    unfused = jax.jit(
        lambda x, w: x @ (fake_quant(w, d, qm, t) * mask[None, :]))
    fused(x, w).block_until_ready()
    unfused(x, w).block_until_ready()
    reps = 10 if fast else 30
    t0 = time.time()
    for _ in range(reps):
        fused(x, w).block_until_ready()
    tf = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        unfused(x, w).block_until_ready()
    tu = (time.time() - t0) / reps * 1e6
    _row("kernel_fused_joint_gemm", tf,
         f"unfused_us={tu:.1f};speedup={tu/max(tf,1e-9):.2f}x")
    return {"fused_us": tf, "unfused_us": tu}


def bench_serve_decode(fast=False):
    """Decode throughput: dense fake-quant params vs compressed Subnet int
    codes (the quant-dequant GEMM epilogue), same smoke model. Timing is
    decode-only (the prefill inside serve_loop warms the jit, so compile
    and init are excluded)."""
    from repro.launch.serve import serve_loop
    gen = 8 if fast else 16
    out = {}
    for mode, compressed in (("dense", False), ("compressed", True)):
        stats = {}
        serve_loop("internlm2-1.8b", smoke=True, batch=2, prompt_len=4,
                   gen=gen, compressed=compressed, verbose=False,
                   stats=stats)
        us = stats["decode_s"] * 1e6 / max(stats["tokens"], 1)
        _row(f"serve_decode_{mode}", us,
             f"tok_per_s={stats['tok_per_s']:.1f}")
        out[mode] = us
    _row("serve_decode_compressed_speedup", 0.0,
         f"{out['dense']/max(out['compressed'],1e-9):.2f}x")
    return out


ALL = [bench_table2_resnet20, bench_table3_bert, bench_table4_vgg7,
       bench_table5_resnet56, bench_fig4a_ablation, bench_fig4b_frontier,
       bench_kernel_fake_quant, bench_kernel_fused_joint, bench_serve_decode]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced steps/sweeps (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for fn in ALL:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            fn(fast=args.fast)
        except Exception as e:  # report, keep the harness going
            _row(fn.__name__ + "_FAILED", 0.0, f"{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. `us_per_call` is the wall
time of one GETA train step on this host (CPU); `derived` carries the
table's headline quantity (accuracy/EM @ rel-BOPs, ablation deltas, ...).

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_table2_resnet20(fast=False):
    """Table 2: ResNet20/CIFAR10 — GETA structured vs baseline (wt quant)."""
    from benchmarks.geta_experiments import (RESNET20_R, run_baseline_cnn,
                                             run_geta_cnn)
    steps = 120 if fast else 240
    base = run_baseline_cnn(RESNET20_R, steps=steps)
    geta = run_geta_cnn(RESNET20_R, steps=steps, sparsity=0.35,
                        act_quant=False)
    us = geta["wall_s"] / max(steps, 1) * 1e6
    _row("table2_resnet20_baseline", 0.0,
         f"acc={base['acc']:.3f};rel_bops=1.0")
    _row("table2_resnet20_geta", us,
         f"acc={geta['acc']:.3f};rel_bops={geta['rel_bops']:.4f};"
         f"sparsity={geta['sparsity']:.2f};bits={geta['mean_bits']:.1f}")
    return {"base": base, "geta": geta}


def bench_table4_vgg7(fast=False):
    """Table 4: VGG7/CIFAR10 — weight AND activation quantization."""
    from benchmarks.geta_experiments import (VGG7_R, run_baseline_cnn,
                                             run_geta_cnn)
    steps = 100 if fast else 200
    base = run_baseline_cnn(VGG7_R, steps=steps)
    geta = run_geta_cnn(VGG7_R, steps=steps, sparsity=0.5, act_quant=True)
    us = geta["wall_s"] / max(steps, 1) * 1e6
    _row("table4_vgg7_baseline", 0.0, f"acc={base['acc']:.3f};rel_bops=1.0")
    _row("table4_vgg7_geta_wa", us,
         f"acc={geta['acc']:.3f};rel_bops={geta['rel_bops']:.4f}")
    return {"base": base, "geta": geta}


def bench_table5_resnet56(fast=False):
    """Table 5 analogue: deeper CNN at two sparsities (40%/50%)."""
    from benchmarks.geta_experiments import RESNET56_R, run_geta_cnn
    steps = 100 if fast else 200
    out = {}
    for sp in (0.4, 0.5):
        r = run_geta_cnn(RESNET56_R, steps=steps, sparsity=sp)
        us = r["wall_s"] / max(steps, 1) * 1e6
        _row(f"table5_resnet56_sp{int(sp*100)}", us,
             f"acc={r['acc']:.3f};rel_bops={r['rel_bops']:.4f}")
        out[sp] = r
    return out


def bench_table3_bert(fast=False):
    """Table 3: BERT/SQuAD-style — GETA joint vs prune-then-PTQ."""
    from benchmarks.geta_experiments import (run_geta_bert,
                                             run_prune_then_ptq_bert)
    steps = 100 if fast else 200
    sparsities = (0.3, 0.5) if fast else (0.1, 0.3, 0.5, 0.7)
    out = {}
    for sp in sparsities:
        t0 = time.time()
        joint = run_geta_bert(sp, steps=steps)
        us = (time.time() - t0) / steps * 1e6
        seq = run_prune_then_ptq_bert(sp, steps=steps)
        _row(f"table3_bert_sp{int(sp*100)}_geta", us,
             f"em={joint['em']:.3f};rel_bops={joint['rel_bops']:.4f}")
        _row(f"table3_bert_sp{int(sp*100)}_prune_ptq", us,
             f"em={seq['em']:.3f};rel_bops={seq['rel_bops']:.4f}")
        out[sp] = {"joint": joint, "sequential": seq}
    return out


def bench_fig4a_ablation(fast=False):
    """Fig 4a: remove each QASSO stage, measure the accuracy drop."""
    from benchmarks.geta_experiments import RESNET56_R, run_geta_cnn
    steps = 80 if fast else 160
    full = run_geta_cnn(RESNET56_R, steps=steps, sparsity=0.35)
    _row("fig4a_full", 0.0, f"acc={full['acc']:.3f}")
    out = {"full": full}
    for stage in ("warmup", "projection", "joint", "cooldown"):
        r = run_geta_cnn(RESNET56_R, steps=steps, sparsity=0.35,
                         skip_stage=stage)
        _row(f"fig4a_no_{stage}", 0.0,
             f"acc={r['acc']:.3f};delta={r['acc']-full['acc']:+.3f}")
        out[stage] = r
    return out


def bench_fig4b_frontier(fast=False):
    """Fig 4b: sparsity x bit-range compression frontier."""
    from benchmarks.geta_experiments import RESNET56_R, run_geta_cnn
    steps = 60 if fast else 120
    grid_sp = (0.3, 0.6) if fast else (0.3, 0.5, 0.7)
    grid_b = ((4, 6),) if fast else ((2, 4), (4, 6), (6, 8))
    out = {}
    for sp in grid_sp:
        for (bl, bu) in grid_b:
            r = run_geta_cnn(RESNET56_R, steps=steps, sparsity=sp,
                             b_l=float(bl), b_u=float(bu) + 8)
            _row(f"fig4b_sp{int(sp*100)}_b{bl}", 0.0,
                 f"acc={r['acc']:.3f};rel_bops={r['rel_bops']:.4f}")
            out[(sp, bl)] = r
    return out


def bench_kernel_fake_quant(fast=False):
    """Fused fake-quant op vs eager op-chain (CPU timings; the TPU win is
    the single HBM round-trip, see DESIGN.md)."""
    from repro.core.quant import fake_quant
    from repro.kernels.ref import fake_quant_fwd_ref
    x = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))
    d, qm, t = jnp.float32(0.05), jnp.float32(1.2), jnp.float32(0.9)

    fused = jax.jit(lambda x: fake_quant(x, d, qm, t))
    ref = jax.jit(lambda x: fake_quant_fwd_ref(x, d, qm, t))
    fused(x).block_until_ready()
    ref(x).block_until_ready()
    n = 20 if fast else 50
    t0 = time.time()
    for _ in range(n):
        fused(x).block_until_ready()
    tf = (time.time() - t0) / n * 1e6
    t0 = time.time()
    for _ in range(n):
        ref(x).block_until_ready()
    tr = (time.time() - t0) / n * 1e6
    _row("kernel_fake_quant_fused", tf, f"ref_us={tr:.1f}")
    return {"fused_us": tf, "ref_us": tr}


def bench_kernel_fused_joint(fast=False):
    """Fused x @ (fake_quant(w) * mask) GEMM epilogue vs the unfused
    quantize -> mask -> matmul chain (three HBM passes of W). Timed on this
    host's default dispatch backend; the TPU win is the single HBM pass of
    W (DESIGN.md §4)."""
    from repro.core.quant import fake_quant
    from repro.kernels import ops
    m, k, n = (256, 1024, 1024) if fast else (512, 2048, 2048)
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (n,)) > 0.4).astype(
        jnp.float32)
    d, qm, t = jnp.float32(0.05), jnp.float32(1.2), jnp.float32(0.9)

    fused = jax.jit(lambda x, w: ops.fq_masked_matmul_op(x, w, mask, d, qm, t))
    unfused = jax.jit(
        lambda x, w: x @ (fake_quant(w, d, qm, t) * mask[None, :]))
    fused(x, w).block_until_ready()
    unfused(x, w).block_until_ready()
    reps = 10 if fast else 30
    t0 = time.time()
    for _ in range(reps):
        fused(x, w).block_until_ready()
    tf = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        unfused(x, w).block_until_ready()
    tu = (time.time() - t0) / reps * 1e6
    _row("kernel_fused_joint_gemm", tf,
         f"unfused_us={tu:.1f};speedup={tu/max(tf,1e-9):.2f}x")
    return {"fused_us": tf, "unfused_us": tu}


def bench_serve_decode(fast=False):
    """Decode throughput: dense fake-quant params vs compressed Subnet int
    codes (the quant-dequant GEMM epilogue), same smoke model. Timing is
    decode-only (the prefill inside serve_loop warms the jit, so compile
    and init are excluded)."""
    from repro.launch.serve import serve_loop
    gen = 8 if fast else 16
    out = {}
    for mode, compressed in (("dense", False), ("compressed", True)):
        stats = {}
        serve_loop("internlm2-1.8b", smoke=True, batch=2, prompt_len=4,
                   gen=gen, compressed=compressed, verbose=False,
                   stats=stats)
        us = stats["decode_s"] * 1e6 / max(stats["tokens"], 1)
        _row(f"serve_decode_{mode}", us,
             f"tok_per_s={stats['tok_per_s']:.1f}")
        out[mode] = us
    _row("serve_decode_compressed_speedup", 0.0,
         f"{out['dense']/max(out['compressed'],1e-9):.2f}x")
    return out


def bench_engine_prefill(fast=False):
    """One-shot parallel prefill (`LM.prefill`, a single (B, S) forward
    that fills the caches) vs the sequential per-token decode-step prefill
    the static serve_loop uses. Same model, same tokens, both jit-warmed;
    the row's derived field carries the speedup."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.subnet import prepare_serving
    from repro.models.transformer import LM

    cfg = get_arch("internlm2-1.8b", smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    params, qparams, _ = prepare_serving(lm, params, compressed=True)
    B, S = 2, (16 if fast else 32)
    max_seq = S + 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    step = jax.jit(lm.decode_step)
    prefill = jax.jit(lm.prefill)

    def sequential():
        caches = lm.init_cache(B, max_seq, dtype=jnp.float32)
        for p in range(S):
            lg, caches = step(params, qparams, caches, toks[:, p:p + 1],
                              jnp.int32(p))
        return lg

    def oneshot():
        caches = lm.init_cache(B, max_seq, dtype=jnp.float32)
        lg, _ = prefill(params, qparams, caches, toks)
        return lg

    jax.block_until_ready(sequential())
    jax.block_until_ready(oneshot())
    reps = 3 if fast else 5
    out = {}
    for name, fn in (("sequential", sequential), ("oneshot", oneshot)):
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn())
        wall = (time.time() - t0) / reps
        out[name] = B * S / max(wall, 1e-9)
        _row(f"engine_prefill_{name}", wall * 1e6 / (B * S),
             f"tok_per_s={out[name]:.1f}")
    _row("engine_prefill_oneshot_speedup", 0.0,
         f"{out['oneshot']/max(out['sequential'],1e-9):.2f}x")
    return out


def bench_engine_continuous(fast=False):
    """Continuous vs static batching at mixed request lengths. Static
    lockstep decodes every group to its longest member (the short request
    burns slots as padding); the engine evicts on completion and admits
    the next queued request into the freed slot. tok/s counts *useful*
    tokens only, decode-time only (prefill/compile excluded for both)."""
    from repro.launch.engine import build_engine, synthetic_prompts
    from repro.launch.serve import serve_loop

    slots = 2
    gens = [6, 18, 6, 18] if fast else [8, 32, 8, 32, 12, 24]
    prompt_len = 6
    # both arms time decode only, and each request's first token comes from
    # the untimed prefill — so useful decoded tokens are (gen-1) per
    # request (exactly what eng.stats['decode_tokens'] counts)
    useful = sum(g - 1 for g in gens)

    # static: consecutive groups of `slots`, each decoded to max(gens)
    static_s = 0.0
    for i in range(0, len(gens), slots):
        grp = gens[i:i + slots]
        stats = {}
        serve_loop("internlm2-1.8b", True, len(grp), prompt_len, max(grp),
                   compressed=True, verbose=False, stats=stats)
        static_s += stats["decode_s"]
    static_tps = useful / max(static_s, 1e-9)
    _row("engine_static_batching", static_s * 1e6 / useful,
         f"tok_per_s={static_tps:.1f}")

    eng, lm = build_engine("internlm2-1.8b", True, compressed=True,
                           max_slots=slots, max_seq=prompt_len + max(gens))
    for p, g in zip(synthetic_prompts(lm.cfg, [prompt_len] * len(gens)),
                    gens):
        eng.submit(p, g)
    eng.warmup()
    eng.run()
    cont_tps = eng.stats["decode_tokens"] / max(eng.stats["decode_s"], 1e-9)
    _row("engine_continuous_batching",
         eng.stats["decode_s"] * 1e6 / max(eng.stats["decode_tokens"], 1),
         f"tok_per_s={cont_tps:.1f};occupancy="
         f"{eng.throughput()['slot_occupancy']:.2f}")
    _row("engine_continuous_speedup", 0.0,
         f"{cont_tps/max(static_tps,1e-9):.2f}x")
    return {"static": static_tps, "continuous": cont_tps}


def bench_engine_decode_pruned(fast=False):
    """Slim serving: engine decode on physically pruned shapes at sparsity
    0 / 0.3 / 0.5 (magnitude masks, compressed int codes on the sliced
    weights). The derived field carries realized param + KV-arena bytes —
    the paper's compression claim in bytes actually allocated, not mask
    zeros — and the s30/s50 rows should sit measurably below the keep-all
    s0 row in us/token (smaller GEMMs, fewer KV rows)."""
    from repro.launch.engine import build_engine, synthetic_prompts

    slots = 4
    gen = 12 if fast else 24
    lens = [6, 6, 6, 6]
    out = {}
    for tag, sp in (("s0", 0.0), ("s30", 0.3), ("s50", 0.5)):
        eng, lm = build_engine("internlm2-1.8b", True, compressed=True,
                               pruned=sp > 0, sparsity=sp, max_slots=slots,
                               max_seq=max(lens) + gen)
        for p in synthetic_prompts(lm.cfg, lens):
            eng.submit(p, gen)
        eng.warmup()
        eng.run()
        us = eng.stats["decode_s"] * 1e6 / max(eng.stats["decode_tokens"], 1)
        realized = eng.serving_meta.get("sparsity", 0.0)
        _row(f"engine_decode_pruned_{tag}", us,
             f"tok_per_s={eng.throughput()['decode_tok_per_s']:.1f};"
             f"sparsity={realized:.2f};"
             f"param_bytes={eng.param_bytes()};kv_bytes={eng.kv_bytes()}")
        out[tag] = {"us": us, "param_bytes": eng.param_bytes(),
                    "kv_bytes": eng.kv_bytes()}
    _row("engine_decode_pruned_s50_speedup", 0.0,
         f"{out['s0']['us']/max(out['s50']['us'],1e-9):.2f}x;"
         f"kv_shrink={out['s0']['kv_bytes']/max(out['s50']['kv_bytes'],1):.2f}x")
    return out


def bench_engine_decode_packed(fast=False):
    """Sub-byte packed serving: engine decode from bit-packed word streams
    at learned widths 8 / 4 / 2 (`--packed`, quantizers initialized at
    each width so the artifact genuinely stores that many bits). The
    derived field carries tokens/s plus the realized served `param_bytes`
    and the packed-vs-int8 container ratio — the ISSUE's ≤0.55x-at-4-bit
    claim as bytes actually allocated (4-bit packs 8 codes per int32 word
    = exactly 0.5x its int8 container; 2-bit 0.25x)."""
    from repro.launch.engine import build_engine, synthetic_prompts

    slots = 4
    gen = 12 if fast else 24
    lens = [6, 6, 6, 6]
    out = {}
    for tag, bits in (("b8", 8.0), ("b4", 4.0), ("b2", 2.0)):
        eng, lm = build_engine("internlm2-1.8b", True, packed=True,
                               bits_init=bits, max_slots=slots,
                               max_seq=max(lens) + gen)
        for p in synthetic_prompts(lm.cfg, lens):
            eng.submit(p, gen)
        eng.warmup()
        eng.run()
        us = eng.stats["decode_s"] * 1e6 / max(eng.stats["decode_tokens"], 1)
        m = eng.serving_meta
        ratio = (m["weight_bytes_compressed"]
                 / max(m["weight_bytes_unpacked"], 1))
        _row(f"engine_decode_packed_{tag}", us,
             f"tok_per_s={eng.throughput()['decode_tok_per_s']:.1f};"
             f"param_bytes={eng.param_bytes()};"
             f"weight_bytes={m['weight_bytes_compressed']};"
             f"vs_int8={ratio:.2f}x")
        out[tag] = {"us": us, "param_bytes": eng.param_bytes(),
                    "ratio": ratio}
    return out


def bench_engine_decode_attn(fast=False):
    """Fused flash-decode attention on the engine decode path: kernel arm
    vs the legacy full-length einsum arm, across dense / pruned(s50) /
    packed(b4) engines, same weights/prompts/seed per config (the arms
    must be token-identical — asserted here, same contract as the
    `--decode-attn-parity` CI smoke). The derived field carries both
    arms' tok/s plus the analytic decode-attention roofline
    (`roofline.analysis.decode_attn_row`): attained-vs-roof HBM bandwidth
    of the arena traffic at the measured step time. Persists everything
    to BENCH_decode.json at the repo root — the tracked decode perf
    trajectory."""
    import json
    import os

    from repro.launch.engine import build_engine, synthetic_prompts
    from repro.models.layers import use_decode_attn
    from repro.roofline.analysis import HBM_BW, decode_attn_row

    slots = 4
    gen = 12 if fast else 24
    lens = [6, 6, 6, 6]
    configs = [
        ("dense", {}),
        ("pruned_s50", dict(compressed=True, pruned=True, sparsity=0.5)),
        ("packed_b4", dict(packed=True, bits_init=4.0)),
    ]
    results = {}
    for tag, kw in configs:
        arms = {}
        tokens = {}
        # several drain cycles per arm, best cycle kept: one cycle is ~a
        # dozen decode steps, far too short for stable wall timing on a
        # shared host, so the minimum-us/token cycle (least scheduler
        # interference) is the recorded figure for both arms alike
        reps = 3 if fast else 10
        for arm, enabled in (("einsum", False), ("kernel", True)):
            with use_decode_attn(enabled):
                eng, lm = build_engine("internlm2-1.8b", True,
                                       max_slots=slots,
                                       max_seq=max(lens) + gen, **kw)
                eng.warmup()
                best = None
                for r in range(reps):
                    s0 = dict(eng.stats)
                    for p in synthetic_prompts(lm.cfg, lens):
                        eng.submit(p, gen)
                    tokens[arm] = eng.run()
                    dsec = eng.stats["decode_s"] - s0["decode_s"]
                    dtok = eng.stats["decode_tokens"] - s0["decode_tokens"]
                    dstep = eng.stats["decode_steps"] - s0["decode_steps"]
                    cyc = {
                        "us_per_tok": dsec * 1e6 / max(dtok, 1),
                        "tok_per_s": dtok / max(dsec, 1e-9),
                        "step_s": dsec / max(dstep, 1),
                    }
                    if best is None or cyc["us_per_tok"] < best["us_per_tok"]:
                        best = cyc
            arms[arm] = best
        for rid in tokens["einsum"]:
            np.testing.assert_array_equal(
                tokens["kernel"][rid], tokens["einsum"][rid],
                err_msg=f"decode-attn arms diverged ({tag}, request {rid})")
        # analytic roofline at this engine's *served* attention shapes
        # (pruned subnets decode fewer kv heads — lm.shapes carries them)
        att = [sh for sh in lm.shapes if sh.n_heads > 0]
        cache_bytes = jnp.dtype(eng._cache_dtype).itemsize
        ctx = max(lens) + gen / 2.0    # mean valid cache length over decode
        roof = decode_attn_row(
            batch=slots, ctx=ctx,
            n_heads=int(np.mean([sh.n_heads for sh in att])),
            n_kv_heads=int(np.mean([sh.n_kv_heads for sh in att])),
            d_head=int(np.mean([sh.d_head for sh in att])),
            n_layers=len(att), cache_bytes=cache_bytes)
        step_s = arms["kernel"]["step_s"]
        roofline = {
            "bytes_per_step": roof.bytes_hbm,
            "flops_per_step": roof.flops,
            "roof_step_s": roof.roof_s,
            "attained_gbps": roof.attained_gbps(step_s),
            "frac_of_roof": roof.frac_of_roof(step_s),
            "hbm_roof_gbps": HBM_BW / 1e9,
        }
        speedup = (arms["einsum"]["us_per_tok"]
                   / max(arms["kernel"]["us_per_tok"], 1e-9))
        _row(f"engine_decode_attn_{tag}", arms["kernel"]["us_per_tok"],
             f"tok_per_s={arms['kernel']['tok_per_s']:.1f};"
             f"einsum_tok_per_s={arms['einsum']['tok_per_s']:.1f};"
             f"speedup={speedup:.2f}x;"
             f"attained_gbps={roofline['attained_gbps']:.2f};"
             f"frac_of_roof={roofline['frac_of_roof']:.4f}")
        results[tag] = {"kernel": arms["kernel"], "einsum": arms["einsum"],
                        "speedup": speedup, "roofline": roofline,
                        "token_identical": True}
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_decode.json")
    payload = {
        "bench": "engine_decode_attn",
        "arch": "internlm2-1.8b(smoke)",
        "workload": {"slots": slots, "prompt_lens": lens, "gen": gen},
        "host_backend": jax.default_backend(),
        "rows": results,
    }
    with open(os.path.abspath(path), "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return results


def bench_engine_decode_speculative(fast=False):
    """Self-speculative decoding from the nested GETA subnet family: a
    masked-checkpoint b8 target verified against its own s50-sliced
    packed draft (`launch.speculative.build_checkpoint_engines` — the
    deployment shape a GETA cooldown checkpoint serves), across draft
    windows k in {2, 4, 8}. Headline metric is accepted-tokens/s vs the
    never-drafted b8 baseline on the *same* target arrays, with the
    acceptance rate quoted (the b8 draft is the target at its surviving
    widths, so acceptance ~1 and the draft's ~2x-cheaper sliced steps
    carry the win; the b2-draft row shows the aggressive end where
    acceptance, not step cost, is the binding constraint). Both engines
    must be token-identical per cell — asserted, same oracle as the
    `--speculative --smoke` CI step. Persists to BENCH_speculative.json
    at the repo root.

    Workload note: gen is pinned at 16 — the never-drafted baseline
    decodes through `_window`'s fused on-device scans (one host sync per
    up-to-32-token window), so on this smoke-scale CPU model long
    generations amortize the baseline's sync cost faster than the
    speculative path's one-sync-per-round can match; short generations
    are where the draft's ~2x-cheaper sliced steps show through. Real
    model scales shift the balance toward compute (and speculation) at
    every gen."""
    import json
    import os

    from repro.launch.engine import synthetic_prompts
    from repro.launch.speculative import build_checkpoint_engines

    slots = 4
    gen = 16
    lens = [6, 6, 6, 6]
    reps = 3 if fast else 8
    ks = [2, 4, 8]

    def cycles(eng, lm):
        # several drain cycles, best cycle kept (same rationale as
        # bench_engine_decode_attn: one cycle is too short for stable
        # wall timing, the min-us/token cycle has least interference)
        best, toks = None, None
        for _ in range(reps):
            s0 = dict(eng.stats)
            for p in synthetic_prompts(lm.cfg, lens):
                eng.submit(p, gen)
            toks = eng.run()
            d = {k: eng.stats[k] - s0[k] for k in s0}
            cyc = {
                "us_per_tok": d["decode_s"] * 1e6
                / max(d["decode_tokens"], 1),
                "tok_per_s": d["decode_tokens"] / max(d["decode_s"], 1e-9),
                "acceptance": d["spec_accepted"] / max(d["spec_drafted"], 1),
            }
            if best is None or cyc["us_per_tok"] < best["us_per_tok"]:
                best = cyc
        return best, toks

    spec, base, lm = build_checkpoint_engines(
        "internlm2-1.8b", True, sparsity=0.5, draft_bits=8.0,
        draft_k=max(ks), max_slots=slots, max_seq=max(lens) + gen)
    base.warmup()
    base_best, base_toks = cycles(base, lm)
    _row("engine_decode_speculative_baseline_b8", base_best["us_per_tok"],
         f"tok_per_s={base_best['tok_per_s']:.1f};speculative=off")

    results = {"baseline_b8": base_best}
    spec.warmup()       # compiles every k in {0} + pow2 <= max(ks) once
    for k in ks:
        spec.draft_k = k
        best, toks = cycles(spec, lm)
        for (_, got), (_, want) in zip(sorted(toks.items()),
                                       sorted(base_toks.items())):
            np.testing.assert_array_equal(
                got, want, err_msg=f"speculative k={k} diverged from the "
                f"never-drafted baseline")
        speedup = base_best["us_per_tok"] / max(best["us_per_tok"], 1e-9)
        _row(f"engine_decode_speculative_k{k}", best["us_per_tok"],
             f"accepted_tok_per_s={best['tok_per_s']:.1f};"
             f"baseline_tok_per_s={base_best['tok_per_s']:.1f};"
             f"speedup={speedup:.2f}x;"
             f"acceptance={best['acceptance']:.2f};draft=s50/b8")
        results[f"k{k}"] = {**best, "speedup": speedup,
                            "draft_bits": 8.0, "token_identical": True}

    # the aggressive end of the subnet family: a 2-bit draft is cheaper
    # per step but its proposals rarely survive verification — the row
    # documents that acceptance, not draft cost, binds at low bits
    spec2, _, lm2 = build_checkpoint_engines(
        "internlm2-1.8b", True, sparsity=0.5, draft_bits=2.0, draft_k=4,
        max_slots=slots, max_seq=max(lens) + gen)
    spec2.warmup()
    best2, toks2 = cycles(spec2, lm2)
    for (_, got), (_, want) in zip(sorted(toks2.items()),
                                   sorted(base_toks.items())):
        np.testing.assert_array_equal(
            got, want, err_msg="speculative b2-draft diverged from the "
            "never-drafted baseline")
    speedup2 = base_best["us_per_tok"] / max(best2["us_per_tok"], 1e-9)
    _row("engine_decode_speculative_k4_b2draft", best2["us_per_tok"],
         f"accepted_tok_per_s={best2['tok_per_s']:.1f};"
         f"baseline_tok_per_s={base_best['tok_per_s']:.1f};"
         f"speedup={speedup2:.2f}x;"
         f"acceptance={best2['acceptance']:.2f};draft=s50/b2")
    results["k4_b2draft"] = {**best2, "speedup": speedup2,
                             "draft_bits": 2.0, "token_identical": True}

    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_speculative.json")
    payload = {
        "bench": "engine_decode_speculative",
        "arch": "internlm2-1.8b(smoke)",
        "workload": {"slots": slots, "prompt_lens": lens, "gen": gen,
                     "target": "masked-checkpoint dense b8 (s50 groups "
                               "hard-zeroed)",
                     "draft": "same checkpoint, s50-sliced packed subnet"},
        "host_backend": jax.default_backend(),
        "rows": results,
    }
    with open(os.path.abspath(path), "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return results


def bench_engine_paged_kv(fast=False):
    """Paged + quantized KV arena (DESIGN.md §4.11): concurrency at a
    fixed KV HBM budget.

    Workload: requests sharing one hot "system prompt" (page-aligned),
    short per-request generations — the serving shape prefix sharing and
    page-granular allocation exist for. The contiguous arena pins
    max_seq rows per slot no matter what; the paged arena charges each
    request only its *owned* pages (the shared prompt is pinned once,
    refcounted), stored as int8 codes + per-row scales. The headline row
    divides the contiguous per-request bytes by the paged *marginal*
    per-request bytes (measured from the engine's own allocation
    accounting with every slot live) — how many more concurrent
    requests the same KV HBM holds — and asserts the ISSUE's >=2x. An
    unshared (all-distinct prompts) row isolates what quantization alone
    buys. Persists to BENCH_paged.json at the repo root."""
    import json
    import os

    from repro.launch.engine import build_engine, synthetic_prompts

    slots = 4
    sys_len, gen = 16, 8
    page_size = 8
    max_seq = sys_len + gen

    def admitted_kv_bytes(eng, prompts, n):
        # submit n requests and run exactly one engine step: every slot
        # admits (allocating its pages) and decodes once, so kv_bytes()
        # reads the arena with all n requests live
        for p in prompts[:n]:
            eng.submit(p, gen)
        eng.step()
        return eng.kv_bytes()

    contig, lm = build_engine("internlm2-1.8b", True, max_slots=slots,
                              max_seq=max_seq)
    per_req_contig = contig.kv_bytes() // slots
    _row("engine_paged_kv_contiguous_per_request", 0.0,
         f"bytes={per_req_contig};max_seq={max_seq}")

    def marginal(shared):
        eng, _ = build_engine("internlm2-1.8b", True, max_slots=slots,
                              max_seq=max_seq, paged=True,
                              page_size=page_size, kv_bits=8)
        prompts = synthetic_prompts(lm.cfg, [sys_len] * slots)
        if shared:
            prompts = [prompts[0].copy() for _ in prompts]
        eng.warmup()
        b1 = admitted_kv_bytes(eng, prompts, 1)
        bn = admitted_kv_bytes(eng, prompts[1:], slots - 1)
        eng.run()
        return (bn - b1) // (slots - 1), b1, eng

    per_req_shared, base_shared, eng_s = marginal(shared=True)
    _row("engine_paged_kv_paged_int8_shared_marginal", 0.0,
         f"bytes={per_req_shared};base={base_shared};"
         f"prefix_hits={eng_s.stats['prefix_hits']};"
         f"page_size={page_size}")
    per_req_unshared, base_unshared, _ = marginal(shared=False)
    _row("engine_paged_kv_paged_int8_unshared_marginal", 0.0,
         f"bytes={per_req_unshared};base={base_unshared}")

    # concurrency at the contiguous engine's own KV budget: how many
    # requests fit in the HBM the contiguous arena pins for `slots`
    budget = contig.kv_bytes()
    fit_paged = (budget - base_shared) // max(per_req_shared, 1) + 1
    concurrency_x = per_req_contig / max(per_req_shared, 1)
    _row("engine_paged_kv_concurrency", 0.0,
         f"{concurrency_x:.2f}x;contig_fits={slots};"
         f"paged_fits={fit_paged};budget={budget}")
    assert concurrency_x >= 2.0, (
        f"paged+int8+shared concurrency {concurrency_x:.2f}x < 2x")

    results = {
        "contiguous_per_request_bytes": int(per_req_contig),
        "paged_int8_shared_marginal_bytes": int(per_req_shared),
        "paged_int8_unshared_marginal_bytes": int(per_req_unshared),
        "paged_base_bytes_shared": int(base_shared),
        "paged_base_bytes_unshared": int(base_unshared),
        "kv_budget_bytes": int(budget),
        "requests_at_budget": {"contiguous": slots,
                               "paged_int8_shared": int(fit_paged)},
        "concurrency_x": float(concurrency_x),
        "prefix_hits": int(eng_s.stats["prefix_hits"]),
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_paged.json")
    payload = {
        "bench": "engine_paged_kv",
        "arch": "internlm2-1.8b(smoke)",
        "workload": {"slots": slots, "system_prompt_len": sys_len,
                     "gen": gen, "page_size": page_size, "kv_bits": 8},
        "host_backend": jax.default_backend(),
        "rows": results,
    }
    with open(os.path.abspath(path), "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return results


def bench_engine_tp(fast=False):
    """Tensor-parallel serving (DESIGN.md §4.12): engine decode at TP
    1 / 2 / 4 on the same weights/prompts/seed, plus the disaggregated
    chunked-prefill row.

    On a 1-device host only the tp=1 row runs; under
    `XLA_FLAGS=--xla_force_host_platform_device_count=4` the 2- and
    4-device rows appear. Fake CPU devices share the same cores, so
    `us_per_tok` measures GSPMD dispatch overhead, not a speedup — the
    quantities that transfer to hardware are the per-device param/KV
    bytes (the ~1/tp memory claim) and the token-identity assert (every
    TP arm must emit exactly the 1-device stream; the smoke arch has 2
    KV heads, so tp=4 shows the replicate-fallback: params still shrink,
    the arena doesn't). The chunked row serves a long prompt behind a
    short one and records how many decode steps ran mid-prefill — the
    head-of-line-blocking fix, asserted nonzero. Persists to
    BENCH_tp.json at the repo root."""
    import json
    import os

    from repro.launch.engine import build_engine, synthetic_prompts

    slots = 4
    gen = 12 if fast else 24
    lens = [6, 6, 6, 6]
    sizes = [n for n in (1, 2, 4) if n <= jax.device_count()]
    results = {}
    base_tokens = None
    for n in sizes:
        eng, lm = build_engine("internlm2-1.8b", True, max_slots=slots,
                               max_seq=max(lens) + gen, tp=n if n > 1 else 0)
        for p in synthetic_prompts(lm.cfg, lens):
            eng.submit(p, gen)
        eng.warmup()
        toks = eng.run()
        if base_tokens is None:
            base_tokens = toks
        else:
            for rid in base_tokens:
                np.testing.assert_array_equal(
                    toks[rid], base_tokens[rid],
                    err_msg=f"tp={n} decode diverged from 1-device")
        us = eng.stats["decode_s"] * 1e6 / max(eng.stats["decode_tokens"], 1)
        full_p, per_p = eng.param_bytes(), eng.param_bytes(per_device=True)
        full_k, per_k = eng.kv_bytes(), eng.kv_bytes(per_device=True)
        _row(f"engine_decode_tp_{n}dev", us,
             f"tok_per_s={eng.throughput()['decode_tok_per_s']:.1f};"
             f"param_bytes_per_dev={per_p};"
             f"param_shrink={full_p / max(per_p, 1):.2f}x;"
             f"kv_bytes_per_dev={per_k};"
             f"kv_shrink={full_k / max(per_k, 1):.2f}x;"
             f"token_identical={base_tokens is not None}")
        results[f"tp{n}"] = {
            "devices": n, "us_per_tok": us,
            "param_bytes_per_dev": int(per_p), "param_bytes": int(full_p),
            "kv_bytes_per_dev": int(per_k), "kv_bytes": int(full_k),
            "token_identical": True,
        }

    # disaggregated chunked prefill: a 40-token prompt prefills in chunks
    # of 8 behind an already-decoding short request; without chunking the
    # long prefill is one dispatch every active slot waits on
    chunk = 8
    eng, lm = build_engine("internlm2-1.8b", True, max_slots=2, max_seq=64,
                           prefill_chunk=chunk)
    prompts = synthetic_prompts(lm.cfg, [6, 40])
    eng.submit(prompts[0], 16 if fast else 32)
    eng.submit(prompts[1], 8)
    eng.warmup()
    eng.run()
    assert eng.stats["decode_steps_mid_prefill"] > 0, \
        "chunked prefill never interleaved a decode step"
    us = eng.stats["decode_s"] * 1e6 / max(eng.stats["decode_tokens"], 1)
    _row("engine_prefill_chunked", us,
         f"chunk={chunk};prefill_chunks={eng.stats['prefill_chunks']};"
         f"decode_steps_mid_prefill={eng.stats['decode_steps_mid_prefill']};"
         f"tok_per_s={eng.throughput()['decode_tok_per_s']:.1f}")
    results["chunked_prefill"] = {
        "chunk": chunk, "us_per_tok": us,
        "prefill_chunks": int(eng.stats["prefill_chunks"]),
        "decode_steps_mid_prefill":
            int(eng.stats["decode_steps_mid_prefill"]),
    }

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_tp.json")
    payload = {
        "bench": "engine_tp",
        "arch": "internlm2-1.8b(smoke)",
        "workload": {"slots": slots, "prompt_lens": lens, "gen": gen,
                     "prefill_chunk": chunk},
        "host_backend": jax.default_backend(),
        "rows": results,
    }
    with open(os.path.abspath(path), "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return results


def bench_sharded_train_scaling(fast=False):
    """1 -> N-device GETA train-step scaling (data-parallel, deterministic
    ordered reduction — DESIGN.md §5).

    On a 1-device host this prints the single-device row only; under
    `XLA_FLAGS=--xla_force_host_platform_device_count=N` it adds a row per
    mesh size. Fake CPU devices share the same cores, so `us_per_step`
    measures dispatch/partitioning overhead rather than real speedup —
    the `per_dev_batch` column is the quantity that scales on hardware."""
    from repro.configs import CompressionConfig, get_arch
    from repro.data.synthetic import batch_for
    from repro.launch.mesh import make_subset_mesh
    from repro.launch.specs import param_specs
    from repro.launch.train import build_geta, make_sharded_geta_train_step
    from repro.distributed.sharding import make_plan
    from repro.models.transformer import LM

    steps = 6 if fast else 20
    batch = 8
    comp = CompressionConfig(
        target_sparsity=0.25, warmup_steps=2, projection_periods=1,
        projection_steps=2, pruning_periods=2, pruning_steps=2,
        cooldown_steps=max(steps - 8, 2))
    n_dev = jax.device_count()
    sizes = sorted({1, n_dev} | ({2} if n_dev >= 2 else set()))
    sizes = [n for n in sizes if batch % n == 0]
    base_us = None
    out = {}
    for n in sizes:
        cfg = get_arch("internlm2-1.8b", smoke=True)
        lm = LM(cfg)
        params, _ = lm.init(jax.random.PRNGKey(0))
        qparams = lm.init_qparams(params, bits_init=16.0)
        _, qasso = build_geta(lm, comp, lr=3e-3, base_optimizer="momentum")
        qstate = qasso.init(params, qparams)
        mesh = make_subset_mesh(n)
        _, p_sh, _ = param_specs(lm, mesh, make_plan(mesh, fsdp=False))
        jstep, (psh, qsh, ssh, bsh) = make_sharded_geta_train_step(
            lm, qasso, mesh, params, qparams, param_shardings=p_sh,
            grad_slices=n)
        params = jax.device_put(params, psh)
        qparams = jax.device_put(qparams, qsh)
        qstate = jax.device_put(qstate, ssh)
        b0 = jax.device_put(batch_for(cfg, 0, 0, batch, 16), bsh)
        # warm the compile outside the timed loop
        params, qparams, qstate, m = jstep(params, qparams, qstate, b0)
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        for i in range(1, steps):
            b = jax.device_put(batch_for(cfg, 0, i, batch, 16), bsh)
            params, qparams, qstate, m = jstep(params, qparams, qstate, b)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / max(steps - 1, 1) * 1e6
        base_us = base_us or us
        _row(f"sharded_geta_step_{n}dev", us,
             f"devices={n};per_dev_batch={batch//n};"
             f"rel_step_time={us/base_us:.2f};loss={float(m['loss']):.3f}")
        out[n] = us
    return out


def bench_static_analysis(fast=False):
    """§4.13: the static contract checker over the full serving matrix +
    trainer — wall time per analyzed entry (trace-only, no compiles) and
    the finding counts the CI gate sees."""
    from repro.analysis import passes, registry, report

    t0 = time.time()
    engines, traced = registry.build_serving()
    traced = traced + [registry.build_training()]
    findings = passes.run_all(engines, traced)
    wall = time.time() - t0
    base = report.load_baseline()
    new, sup = report.split_findings(findings, base)
    _row("static_analysis_full_matrix", wall * 1e6 / max(len(traced), 1),
         f"entries={len(traced)};groups={len(engines)};wall_s={wall:.1f};"
         f"findings={len(findings)};new={len(new)};suppressed={len(sup)}")
    return wall


ALL = [bench_table2_resnet20, bench_table3_bert, bench_table4_vgg7,
       bench_table5_resnet56, bench_fig4a_ablation, bench_fig4b_frontier,
       bench_kernel_fake_quant, bench_kernel_fused_joint, bench_serve_decode,
       bench_engine_prefill, bench_engine_continuous,
       bench_engine_decode_pruned, bench_engine_decode_packed,
       bench_engine_decode_attn, bench_engine_decode_speculative,
       bench_engine_paged_kv, bench_engine_tp, bench_sharded_train_scaling,
       bench_static_analysis]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced steps/sweeps (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for fn in ALL:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            fn(fast=args.fast)
        except Exception as e:  # report, keep the harness going
            _row(fn.__name__ + "_FAILED", 0.0, f"{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()

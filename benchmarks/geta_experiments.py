"""Shared machinery for the paper-table benchmarks (Tables 2-5, Fig 4).

All experiments run REDUCED architectures on deterministic synthetic data
(this container is CPU-only and offline), so absolute accuracies differ
from the paper; the claims being validated are the *relative* ones:
joint > sequential at matched BOPs, every QASSO stage contributes, and the
explicit sparsity/bit-width controls are honored exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.core.bops import model_bops
from repro.core.qadg import build_qadg
from repro.core.qasso import QASSO, QASSOConfig
from repro.core.subnet import construct_subnet
from repro.data.synthetic import image_batch, qa_batch
from repro.models.bert import BertEncoder
from repro.models.cnn import CNN, CNNSpec
from repro.optim.schedules import constant

# Reduced CNN specs (same family, small widths) for CPU-speed experiments.
RESNET20_R = CNNSpec("resnet20-r", "resnet", [8, 16, 32],
                     blocks_per_stage=2)
RESNET56_R = CNNSpec("resnet56-r", "resnet", [8, 16, 32],
                     blocks_per_stage=3)
VGG7_R = CNNSpec("vgg7-r", "vgg", [16, 16, 32, 32, 64, 64], fc_dim=128)


def qasso_cfg(steps: int, sparsity: float, b_l=4.0, b_u=16.0,
              skip_stage: Optional[str] = None) -> QASSOConfig:
    """Schedule scaled to `steps`, with optional stage ablation (Fig 4a)."""
    w = max(steps // 10, 1)
    pp, ps = 3, max(steps // 15, 1)
    rp, rs = 4, max(steps // 12, 1)
    cd = max(steps // 4, 1)
    if skip_stage == "warmup":
        w = 0
    if skip_stage == "projection":
        pp = 1
        ps = 1
    if skip_stage == "joint":
        rp, rs = 1, 1
    if skip_stage == "cooldown":
        cd = 1
    return QASSOConfig(
        target_sparsity=sparsity, bit_lower=b_l, bit_upper=b_u,
        warmup_steps=w, projection_periods=pp, projection_steps=ps,
        bit_reduction=min(2.0, (b_u - b_l) / pp),
        pruning_periods=rp, pruning_steps=rs, cooldown_steps=cd,
        base_optimizer="adam", lr_quant=1e-3)


def run_geta_cnn(spec: CNNSpec, steps=240, batch=64, sparsity=0.35,
                 b_l=4.0, b_u=16.0, act_quant=False, lr=3e-3,
                 skip_stage=None, seed=0):
    """GETA on a CNN, returns (accuracy, rel_bops, wall_s, subnet meta)."""
    model = CNN(spec)
    params = model.init(jax.random.PRNGKey(seed))
    qparams = model.init_qparams(params, bits_init=b_u,
                                 act_quant=act_quant)
    qadg = build_qadg(model.build_graph(act_quant=act_quant).graph)
    qadg.space.validate(params)
    cfg = qasso_cfg(steps, sparsity, b_l, b_u, skip_stage)
    qasso = QASSO(qadg.space, qadg.sites, cfg, constant(lr))
    state = qasso.init(params, qparams)

    @jax.jit
    def step(params, qparams, state, batch_):
        loss, (gx, gq) = jax.value_and_grad(model.loss, argnums=(0, 1))(
            params, qparams, batch_)
        p, q, s, m = qasso.update(params, qparams, gx, gq, state)
        return p, q, s, m, loss

    t0 = time.time()
    for i in range(cfg.total_steps):
        b = image_batch(seed, i, batch)
        params, qparams, state, metrics, loss = step(params, qparams,
                                                     state, b)
    wall = time.time() - t0

    test = image_batch(seed + 1, 10_000, 256)
    acc = float(model.accuracy(params, qparams, test))
    bops = model_bops(qadg, params, qparams, model.layer_macs(1),
                      masks=state.keep_mask,
                      act_bits_default=32.0 if not act_quant else 32.0)
    sub = construct_subnet(qadg, params, qparams, state.keep_mask)
    return {"acc": acc, "rel_bops": bops["rel_bops"], "wall_s": wall,
            "sparsity": sub.meta["sparsity"],
            "mean_bits": sub.meta["mean_bits"], "loss": float(loss)}


def run_baseline_cnn(spec: CNNSpec, steps=240, batch=64, lr=3e-3, seed=0):
    """Uncompressed FP32 baseline."""
    model = CNN(spec)
    params = model.init(jax.random.PRNGKey(seed))
    from repro.optim.base import adam, tree_add
    opt = adam()
    ostate = opt.init(params)

    @jax.jit
    def step(params, ostate, batch_):
        loss, gx = jax.value_and_grad(
            lambda p: model.loss(p, None, batch_))(params)
        delta, ostate = opt.update(gx, ostate, params, jnp.float32(lr))
        return tree_add(params, delta), ostate, loss

    for i in range(steps):
        params, ostate, loss = step(params, ostate, image_batch(seed, i,
                                                                batch))
    test = image_batch(seed + 1, 10_000, 256)
    acc = float(model.accuracy(params, None, test))
    return {"acc": acc, "rel_bops": 1.0}


def run_geta_bert(sparsity: float, steps=200, batch=16, seq=64,
                  b_l=4.0, b_u=16.0, seed=0):
    """GETA joint on BERT-small + synthetic QA (Table 3, GETA rows)."""
    model = BertEncoder(n_layers=2, d_model=64, n_heads=4, d_ff=256,
                        vocab=512, max_seq=seq)
    params = model.init(jax.random.PRNGKey(seed))
    qparams = model.init_qparams(params, bits_init=8.0)
    qadg = build_qadg(model.build_graph().graph)
    qadg.space.validate(params)
    cfg = qasso_cfg(steps, sparsity, b_l, b_u)
    qasso = QASSO(qadg.space, qadg.sites, cfg, constant(2e-3))
    state = qasso.init(params, qparams)

    @jax.jit
    def step(params, qparams, state, batch_):
        loss, (gx, gq) = jax.value_and_grad(model.loss, argnums=(0, 1))(
            params, qparams, batch_)
        return qasso.update(params, qparams, gx, gq, state) + (loss,)

    for i in range(cfg.total_steps):
        b = qa_batch(seed, i, batch, seq, 512)
        params, qparams, state, metrics, loss = step(params, qparams,
                                                     state, b)
    test = qa_batch(seed + 1, 77_000, 128, seq, 512)
    em = float(model.exact_match(params, qparams, test))
    bops = model_bops(qadg, params, qparams,
                      model.layer_macs(1, seq), masks=state.keep_mask)
    return {"em": em, "rel_bops": bops["rel_bops"]}


def run_prune_then_ptq_bert(sparsity: float, steps=200, batch=16, seq=64,
                            ptq_bits=8.0, seed=0):
    """Sequential baseline of Table 3: pruning-aware training (HESSO-style
    = QASSO with quantization disabled/idle at 32 bits) then post-training
    quantization of the surviving weights."""
    model = BertEncoder(n_layers=2, d_model=64, n_heads=4, d_ff=256,
                        vocab=512, max_seq=seq)
    params = model.init(jax.random.PRNGKey(seed))
    # prune-only: bits pinned at 32 (range [32, 32] disables quant pressure)
    qparams = model.init_qparams(params, bits_init=32.0)
    qadg = build_qadg(model.build_graph().graph)
    cfg = qasso_cfg(steps, sparsity, b_l=32.0, b_u=32.0)
    qasso = QASSO(qadg.space, qadg.sites, cfg, constant(2e-3))
    state = qasso.init(params, qparams)

    @jax.jit
    def step(params, qparams, state, batch_):
        loss, (gx, gq) = jax.value_and_grad(model.loss, argnums=(0, 1))(
            params, qparams, batch_)
        return qasso.update(params, qparams, gx, gq, state) + (loss,)

    for i in range(cfg.total_steps):
        b = qa_batch(seed, i, batch, seq, 512)
        params, qparams, state, metrics, loss = step(params, qparams,
                                                     state, b)
    # PTQ: re-init quantizers at ptq_bits from the trained weights; no
    # retraining (the paper's PTQ baseline).
    ptq = model.init_qparams(params, bits_init=ptq_bits)
    test = qa_batch(seed + 1, 77_000, 128, seq, 512)
    em = float(model.exact_match(params, ptq, test))
    bops = model_bops(qadg, params, ptq, model.layer_macs(1, seq),
                      masks=state.keep_mask)
    return {"em": em, "rel_bops": bops["rel_bops"]}

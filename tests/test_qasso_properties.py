"""Property-based QASSO invariants (hypothesis).

Three structural guarantees the optimizer must hold for *any* admissible
configuration, not just the tuned test schedule:

1. PPSG projection (Alg 3) always leaves the derived bit width inside the
   progressively-shrinking range [b_l, b_u - p*b_r] — both the pure
   projection operator and the live projection stage of a full run.
2. Cool-down hard-zeros exactly the redundant groups: every element
   covered by a pruned unit is exactly 0.0, every kept unit survives with
   nonzero mass, and the pruned-unit count is the Eq 7b target.
3. The stage boundaries derived from `QASSOConfig` partition
   [0, total_steps) with no gaps and no overlap.
"""
import types

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import quant as Q
from repro.core.graph import GraphBuilder
from repro.core.qadg import build_qadg
from repro.core.qasso import QASSO, QASSOConfig
from repro.optim.schedules import constant


# ------------------------------------------------------------ 1. projection
@given(qm=st.floats(0.05, 8.0), t=st.floats(0.2, 3.0),
       bits0=st.floats(1.1, 28.0), period=st.integers(0, 7),
       b_l=st.floats(2.0, 6.0), b_u=st.floats(8.0, 20.0),
       b_r=st.floats(0.5, 3.0))
@settings(max_examples=200)
def test_projection_keeps_bits_in_shrinking_range(qm, t, bits0, period,
                                                  b_l, b_u, b_r):
    """For any quantizer state (even one far outside the range) and any
    period p, projecting with the period-p effective upper bound lands the
    derived bit width inside [b_l, b_u - p*b_r] (floored at b_l)."""
    b_u_eff = max(b_u - b_r * period, b_l)
    qp = Q.QuantParams(d=Q.step_size_for_bits(
        jnp.float32(qm), jnp.float32(t), jnp.float32(bits0)),
        q_m=jnp.float32(qm), t=jnp.float32(t))
    out = Q.project_step_size(qp, b_l, b_u_eff)
    b = float(Q.bit_width(out.d, out.q_m, out.t))
    assert b_l - 1e-3 <= b <= b_u_eff + 1e-3, (b, b_l, b_u_eff)


# --------------------------------------------- shared tiny QASSO problem
def _tiny_problem(seed=0, hidden=16):
    gb = GraphBuilder()
    gb.input("in")
    gb.linear("fc1", "fc1.w", out_dim=hidden)
    gb.act("relu1")
    gb.linear("fc2", "fc2.w", out_dim=4, non_prunable=True)
    gb.output("out")
    gb.attach_weight_quant("fc1", "fc1.w.wq")
    gb.attach_weight_quant("fc2", "fc2.w.wq")
    qadg = build_qadg(gb.graph)

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = {"fc1.w": jax.random.normal(k1, (6, hidden)) * 0.4,
              "fc2.w": jax.random.normal(k2, (hidden, 4)) * 0.4}
    qparams = {"fc1.w.wq": Q.init_quant_params(params["fc1.w"], bits=16.0),
               "fc2.w.wq": Q.init_quant_params(params["fc2.w"], bits=16.0)}
    X = jax.random.normal(k3, (32, 6))
    Y = X @ jax.random.normal(jax.random.PRNGKey(seed + 77), (6, 4))

    def loss_fn(p, q):
        w1 = Q.fake_quant(p["fc1.w"], q["fc1.w.wq"].d, q["fc1.w.wq"].q_m,
                          q["fc1.w.wq"].t)
        h = jax.nn.relu(X @ w1)
        w2 = Q.fake_quant(p["fc2.w"], q["fc2.w.wq"].d, q["fc2.w.wq"].q_m,
                          q["fc2.w.wq"].t)
        return jnp.mean((h @ w2 - Y) ** 2)

    return qadg, params, qparams, loss_fn


def _run_qasso(cfg, seed):
    """Full-schedule run; returns per-step bit traces + final state."""
    qadg, params, qparams, loss_fn = _tiny_problem(seed)
    qasso = QASSO(qadg.space, qadg.sites, cfg, constant(5e-3))
    state = qasso.init(params, qparams)

    @jax.jit
    def step(params, qparams, state):
        loss, (gx, gq) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            params, qparams)
        return qasso.update(params, qparams, gx, gq, state)

    bit_trace = []
    for i in range(cfg.total_steps):
        params, qparams, state, metrics = step(params, qparams, state)
        bit_trace.append({s.name: float(Q.bit_width(
            qparams[s.name].d, qparams[s.name].q_m, qparams[s.name].t))
            for s in qadg.sites})
    return qadg, qasso, params, qparams, state, bit_trace


CFG = QASSOConfig(target_sparsity=0.5, bit_lower=4, bit_upper=16,
                  warmup_steps=4, projection_periods=3, projection_steps=4,
                  bit_reduction=2, pruning_periods=3, pruning_steps=5,
                  cooldown_steps=6, base_optimizer="adam", lr_quant=1e-3)


def test_projection_stage_bits_track_schedule():
    """White-box: after every projection-stage step of a live run, each
    site's bits sit inside the *current period's* shrinking range."""
    cfg = CFG
    _, _, _, _, _, trace = _run_qasso(cfg, seed=0)
    for i in range(cfg.warmup_end, cfg.projection_end):
        period = (i - cfg.warmup_end) // cfg.projection_steps
        b_u_eff = max(cfg.bit_upper - cfg.bit_reduction * (period + 1),
                      cfg.bit_lower)
        for site, b in trace[i].items():
            assert cfg.bit_lower - 1e-3 <= b <= b_u_eff + 1e-3, \
                (i, site, b, b_u_eff)


# ------------------------------------------------------------- 2. cool-down
@given(seed=st.integers(0, 50),
       sparsity=st.sampled_from([0.25, 0.5, 0.75]))
@settings(max_examples=5, deadline=None)
def test_cooldown_hard_zeros_exactly_the_redundant_groups(seed, sparsity):
    import dataclasses
    cfg = dataclasses.replace(CFG, target_sparsity=sparsity)
    qadg, qasso, params, qparams, state, _ = _run_qasso(cfg, seed)

    fams = qasso.space.prunable_families()
    n_pruned = 0
    for fam in fams:
        keep = np.asarray(state.keep_mask[fam.name])
        red = np.asarray(state.redundant[fam.name])
        # the frozen keep mask is exactly the complement of the final
        # redundant partition — nothing extra zeroed, nothing spared
        np.testing.assert_array_equal(keep, 1.0 - red)
        n_pruned += int(np.sum(keep < 0.5))
    # Eq 7b: the progressive target lands on round(K * units) (within the
    # one-unit rounding the progressive per-period targets allow)
    assert abs(n_pruned - sparsity * qasso.space.total_units()) <= 1 + 1e-6

    fam = fams[0]
    keep = np.asarray(state.keep_mask[fam.name])
    pruned = np.nonzero(keep < 0.5)[0]
    kept = np.nonzero(keep >= 0.5)[0]
    w1 = np.asarray(params["fc1.w"])
    w2 = np.asarray(params["fc2.w"])
    # hard zeros, exactly on the redundant units...
    assert np.all(w1[:, pruned] == 0.0)
    assert np.all(w2[pruned, :] == 0.0)
    # ...and only there: every kept unit keeps nonzero mass
    if len(kept):
        assert np.all(np.abs(w1[:, kept]).sum(axis=0) > 0.0)


# ------------------------------------------------------- 3. stage partition
@given(warm=st.integers(0, 30), pp=st.integers(1, 5), ps=st.integers(1, 20),
       br=st.floats(0.0, 4.0), P=st.integers(1, 5), ks=st.integers(1, 20),
       cd=st.integers(0, 30))
@settings(max_examples=100)
def test_stage_boundaries_partition_the_horizon(warm, pp, ps, br, P, ks, cd):
    """stage_index carves [0, total_steps) into four consecutive intervals
    with no gaps or overlap, for any admissible schedule (empty stages
    allowed when a length is 0)."""
    cfg = QASSOConfig(warmup_steps=warm, projection_periods=pp,
                      projection_steps=ps, bit_reduction=br,
                      pruning_periods=P, pruning_steps=ks, cooldown_steps=cd)
    edges = [0, cfg.warmup_end, cfg.projection_end, cfg.joint_end,
             cfg.total_steps]
    assert edges == sorted(edges)
    assert cfg.warmup_end - 0 == warm
    assert cfg.projection_end - cfg.warmup_end == pp * ps
    assert cfg.joint_end - cfg.projection_end == P * ks
    assert cfg.total_steps - cfg.joint_end == cd

    # evaluate the real (jit-compatible) stage switch over the horizon
    shim = types.SimpleNamespace(cfg=cfg)
    stages = np.asarray(QASSO.stage_index(shim, jnp.arange(cfg.total_steps)))
    for s in range(4):
        lo, hi = edges[s], edges[s + 1]
        assert np.all(stages[lo:hi] == s), (s, lo, hi)
    # exhaustive partition: each step is claimed by exactly one stage
    assert stages.shape[0] == cfg.total_steps
    assert np.all(np.diff(stages) >= 0)

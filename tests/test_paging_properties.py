"""Property-based page-allocator invariants (hypothesis).

For hypothesis-drawn op scripts (alloc / share / release / flush over a
small pool), `tests/test_paged_kv.py::run_allocator_case` asserts after
every op that no page is handed out while an owner holds it, that every
allocated page reads back zero (released pages stay quarantined until an
explicit flush), and that refcount-shared pages survive any one owner's
release with contents intact. Runs under the conftest "repro"
derandomized profile; the deterministic scripts in tests/test_paged_kv.py
drive the same checker when hypothesis is absent.
"""
import pytest

pytest.importorskip("hypothesis")  # property-based tests; see requirements-dev.txt
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from test_paged_kv import run_allocator_case  # noqa: E402


@given(st.data())
@settings(max_examples=50, deadline=None)
def test_allocator_invariants_random_scripts(data):
    n_pages = data.draw(st.integers(4, 16), label="n_pages")
    n_ops = data.draw(st.integers(1, 30), label="n_ops")
    owners = "abcdef"
    script = []
    for _ in range(n_ops):
        kind = data.draw(st.sampled_from(
            ["alloc", "alloc", "share", "release", "flush"]))
        if kind == "alloc":
            script.append(("alloc", data.draw(st.sampled_from(owners)),
                           data.draw(st.integers(1, n_pages))))
        elif kind == "share":
            script.append(("share", data.draw(st.sampled_from(owners)),
                           data.draw(st.sampled_from(owners))))
        elif kind == "release":
            script.append(("release", data.draw(st.sampled_from(owners))))
        else:
            script.append(("flush",))
    run_allocator_case(script, n_pages=n_pages, page_size=4)

"""Static contract checker tier (DESIGN.md §4.13).

Two layers: unit tests of the jaxpr-walk / VMEM-model / report machinery
against *synthetic violations* of every contract class (injected psum in
a TP serving jaxpr, unpinned arena jit, uncovered dispatch shape,
over-VMEM tile, closure-captured megaconstant, f64 widen), and an
integration sweep that builds the real engine matrix + trainer and
asserts the analyzer is green on main modulo the checked-in baseline.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import jaxpr_utils as ju
from repro.analysis import passes, registry, report, verify, vmem
from repro.distributed.collectives import shard_map
from repro.kernels import autotune, gemm_core, introspect
from repro.launch.mesh import make_tp_mesh
from repro.launch.scheduler import chunk_buckets, chunk_plan, \
    reachable_chunk_shapes
from repro.launch.speculative import pow2_floor, reachable_spec_ks

BASELINE = os.path.join(os.path.dirname(__file__), "..",
                        "analysis_baseline.json")


def _entry(name, fn, args, kind="serving", group="test", expected_out=None,
           static_argnums=(), launches=(), tp=1):
    """A synthetic TracedEntry around `jax.make_jaxpr` output."""
    jaxpr = jax.make_jaxpr(fn, static_argnums=static_argnums)(*args)
    return registry.TracedEntry(
        group=group, name=name, kind=kind, fn=fn, args=tuple(args),
        static_argnums=static_argnums, expected_out=expected_out,
        jaxpr=jaxpr, launches=list(launches), tp=tp)


# --------------------------------------------------- jaxpr walk utilities
def test_walk_finds_psum_inside_shard_map():
    mesh = make_tp_mesh(1)
    f = jax.jit(shard_map(lambda x: jax.lax.psum(x, "model"),
                          mesh=mesh, in_specs=P("model"), out_specs=P()))
    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,)))
    hits = ju.find_prims(jaxpr, {"psum", "psum2"})
    assert hits, "walk must descend through pjit into the shard_map body"
    assert all(ju.in_shard_map(path) for _, path in hits)
    assert "pjit" in hits[0][1]


def test_walk_descends_into_scan():
    def f(x):
        return jax.lax.scan(lambda c, _: (jnp.sin(c), None), x,
                            None, length=3)[0]
    jaxpr = jax.make_jaxpr(jax.jit(f))(jnp.ones((2,)))
    assert ju.prim_counts(jaxpr)["sin"] >= 1
    (eqn, path), = ju.find_prims(jaxpr, {"sin"})
    assert "scan" in path and not ju.in_shard_map(path)


def test_outer_pjit_and_unspecified_out_shardings():
    f = jax.jit(lambda x: x * 2)
    jaxpr = jax.make_jaxpr(f)(jnp.ones((2,)))
    eqn = ju.outer_pjit_eqn(jaxpr)
    assert eqn is not None
    outs = ju.out_shardings_of(eqn)
    assert len(outs) == 1 and ju.is_unspecified(outs[0])

    mesh = make_tp_mesh(1)
    sh = NamedSharding(mesh, P())
    g = jax.jit(lambda x: x * 2, out_shardings=sh)
    eqn2 = ju.outer_pjit_eqn(jax.make_jaxpr(g)(jnp.ones((2,))))
    outs2 = ju.out_shardings_of(eqn2)
    assert not ju.is_unspecified(outs2[0])
    assert ju.spec_of(outs2[0]) == P()


def test_collect_consts_sees_closure_capture():
    big = np.arange(1_000_000, dtype=np.float32)
    f = jax.jit(lambda x: x + jnp.asarray(big)[:2])
    jaxpr = jax.make_jaxpr(f)(jnp.ones((2,)))
    consts = ju.collect_consts(jaxpr, min_elems=1 << 16)
    assert any(np.size(c) == 1_000_000 for _, c in consts)


# ------------------------------------------------ pass 1: identity audit
def test_identity_flags_injected_psum_in_serving():
    mesh = make_tp_mesh(1)
    f = jax.jit(shard_map(lambda x: jax.lax.psum(x, "model"),
                          mesh=mesh, in_specs=P("model"), out_specs=P()))
    te = _entry("decode", f, (jnp.ones((4,)),), kind="serving")
    findings = passes.audit_identity([te])
    assert findings and findings[0].pass_name == "identity"
    assert any(f.fid.endswith(":psum") or ":psum" in f.fid
               for f in findings)


def test_identity_allows_training_all_gather_in_shard_map_only():
    mesh = make_tp_mesh(1)

    def gather(x):
        return jax.lax.all_gather(x, "model")

    f = jax.jit(shard_map(gather, mesh=mesh, in_specs=P("model"),
                          out_specs=P(None, "model")))
    te_train = _entry("train_step", f, (jnp.ones((4,)),), kind="training")
    assert passes.audit_identity([te_train]) == []
    # the same jaxpr viewed as a serving entry is a violation
    te_serve = _entry("decode", f, (jnp.ones((4,)),), kind="serving")
    assert passes.audit_identity([te_serve])


def test_identity_flags_training_psum_anywhere():
    mesh = make_tp_mesh(1)
    f = jax.jit(shard_map(lambda x: jax.lax.psum(x, "model"),
                          mesh=mesh, in_specs=P("model"), out_specs=P()))
    te = _entry("train_step", f, (jnp.ones((4,)),), kind="training")
    findings = passes.audit_identity([te])
    assert findings and "psum" in findings[0].fid


# -------------------------------------------- pass 2: sharding-pin audit
def test_sharding_audit_flags_unpinned_jit():
    mesh = make_tp_mesh(1)
    want = NamedSharding(mesh, P())
    f = jax.jit(lambda x: x * 2)          # no out_shardings: the old
    te = _entry("insert", f, (jnp.ones((4,)),),  # `_insert` pattern
                expected_out=want)
    findings = passes.audit_sharding_pins([te])
    assert len(findings) == 1
    assert "unpinned" in findings[0].fid


def test_sharding_audit_accepts_pinned_and_flags_mismatch():
    mesh = make_tp_mesh(1)
    want = NamedSharding(mesh, P())
    pinned = jax.jit(lambda x: x * 2, out_shardings=want)
    te = _entry("insert", pinned, (jnp.ones((4,)),), expected_out=want)
    assert passes.audit_sharding_pins([te]) == []

    want_other = NamedSharding(mesh, P("data"))
    te2 = _entry("insert", pinned, (jnp.ones((4,)),),
                 expected_out=want_other)
    findings = passes.audit_sharding_pins([te2])
    assert len(findings) == 1 and "mismatch" in findings[0].fid


# --------------------------------------------- pass 3: compile-set audit
def test_reachable_spec_ks_matches_dispatch_quantizer():
    for draft_k in (1, 3, 4, 7):
        reach = reachable_spec_ks(draft_k, 32)
        assert reach == {pow2_floor(min(draft_k, rem - 1))
                         for rem in range(1, 33)}
        assert all(k == 0 or k & (k - 1) == 0 for k in reach)


def test_reachable_chunk_shapes_covered_by_buckets():
    for chunk in (4, 8, 16):
        reach = reachable_chunk_shapes(64, chunk)
        assert reach <= set(chunk_buckets(chunk))
        # every plan's pieces really are in the reachable set
        for s in (1, 5, 17, 64):
            assert set(chunk_plan(s, chunk)) <= reach


def test_compile_set_flags_uncovered_window(analysis_matrix):
    engines, _ = analysis_matrix
    eng = engines["dense"]
    orig = eng.warmed_window_ks
    # instance-attribute shadow: warmup "forgets" every window above 1
    eng.warmed_window_ks = lambda: [1]
    try:
        findings = [f for f in passes.audit_compile_set({"dense": eng})
                    if f.entry == "decode_window"]
    finally:
        eng.warmed_window_ks = orig
    assert findings, "uncovered pow2 windows must be flagged"
    assert passes.audit_compile_set({"dense": eng}) == []


def test_compile_set_flags_uncovered_chunk_bucket(analysis_matrix,
                                                  monkeypatch):
    engines, _ = analysis_matrix
    eng = engines["chunked"]
    # warmup "forgets" the pow2 remainder buckets: only the full chunk
    monkeypatch.setattr("repro.launch.scheduler.chunk_buckets",
                        lambda c: [c])
    findings = passes.audit_compile_set({"chunked": eng})
    assert any(f.entry == "prefill_chunk" for f in findings)


def test_compile_set_flags_uncovered_spec_k(analysis_matrix):
    engines, _ = analysis_matrix
    eng = engines["speculative"]
    orig = eng._spec_ks
    eng._spec_ks = lambda: [0]
    try:
        findings = passes.audit_compile_set({"speculative": eng})
    finally:
        eng._spec_ks = orig
    assert any(f.entry == "spec" for f in findings)


# ------------------------------------------------- pass 4: VMEM budgeter
def _gemm_launch(blocks, k_pack=1, **kw):
    d = dict(M=1024, N=1024, K=1024, k_pack=k_pack, n_col=0, n_scalar=0,
             ops="", backend="static", blocks=blocks)
    d.update(kw)
    return introspect.GemmLaunch(**d)


def test_vmem_model_flags_oversized_tile():
    small = _gemm_launch((64, 128, 128, 128))
    huge = _gemm_launch((512, 2048, 1024, 1024))
    assert not introspect.over_budget(small)
    assert introspect.over_budget(huge)
    te = _entry("decode", jax.jit(lambda x: x), (jnp.ones((2,)),),
                launches=[small, huge])
    findings = vmem.audit_vmem([te])
    assert len(findings) == 1
    assert "gemm:1024x1024x1024" in findings[0].fid


def test_vmem_packed_tile_counts_decoded_blowup():
    # bits=3 packs 8 codes/word; plan_blocks inflates bk to lcm(24, bk)
    plan = gemm_core.plan_blocks(256, 256, 768, k_pack=8,
                                 blocks=(64, 128, 128))
    bm, bn, bk, bkw = plan
    assert bk % 8 == 0 and bkw == bk // 8
    packed = _gemm_launch(plan, k_pack=8)
    unpacked = _gemm_launch(plan, k_pack=1)
    assert introspect.gemm_vmem_bytes(packed) > \
        introspect.gemm_vmem_bytes(unpacked)


def test_autotune_rejects_oversized_candidates():
    fits, rejected = autotune.vmem_filter(
        [(64, 128, 128), (512, 2048, 2048)], 1024, 2048, 2048)
    assert (64, 128, 128) in fits
    assert rejected and all(v > introspect.VMEM_BUDGET_BYTES
                            for v in rejected.values())

    x = jnp.ones((16, 32), jnp.float32)
    w = jnp.ones((32, 128), jnp.float32)
    with pytest.raises(ValueError, match="VMEM budget"):
        autotune.autotune_gemm(x, w, backend="pallas-interpret",
                               vmem_budget=1)


# --------------------------------- pass 5: constants / dtype-widen audit
def test_constants_audit_flags_megaconstant():
    big = np.zeros(1_000_000, dtype=np.float32)
    f = jax.jit(lambda x: x + jnp.asarray(big)[:2])
    te = _entry("prefill", f, (jnp.ones((2,)),))
    findings = passes.audit_constants([te])
    assert len(findings) == 1
    assert "const-1000000" in findings[0].fid
    # same trace under the default threshold=tiny consts: clean
    g = jax.jit(lambda x: x + 1.0)
    assert passes.audit_constants(
        [_entry("prefill", g, (jnp.ones((2,)),))]) == []


def test_constants_audit_flags_f64_widen():
    with jax.experimental.enable_x64():
        f = jax.jit(lambda x: x.astype(jnp.float64).sum())
        te = _entry("decode", f, (jnp.ones((2,), jnp.float32),))
    findings = passes.audit_constants([te])
    assert any("f64-widen" in f.fid for f in findings)


# --------------------------------------------- report / baseline contract
def test_report_is_deterministic_and_timestamp_free():
    f1 = report.make_finding("vmem", "dense", "decode", "slug", "msg",
                             detail={"bytes": 1})
    f2 = report.make_finding("identity", "train", "train_step", "psum",
                             "msg2")
    base = {f1.fid: "known"}
    cfg = {"devices": 1, "groups": ["dense"]}
    a = report.dumps(report.make_report([f1, f2], base, cfg))
    b = report.dumps(report.make_report([f2, f1], base, cfg))
    assert a == b, "report must not depend on finding discovery order"
    loaded = json.loads(a)
    assert loaded["new"] == [f2.fid]
    assert loaded["suppressed"] == [f1.fid]
    assert not any("time" in k or "date" in k for k in loaded)


def test_baseline_roundtrip(tmp_path):
    f1 = report.make_finding("vmem", "dense", "decode", "slug", "msg")
    path = str(tmp_path / "b.json")
    report.save_baseline([f1], path, reason="why")
    base = report.load_baseline(path)
    assert base == {f1.fid: "why"}
    new, sup = report.split_findings([f1], base)
    assert new == [] and sup == [f1]
    assert report.load_baseline(str(tmp_path / "missing.json")) == {}


# ------------------------------------------------------- integration/CLI
@pytest.fixture(scope="module")
def analysis_matrix():
    return registry.build_serving()


def test_engine_matrix_entry_coverage(analysis_matrix):
    engines, traced = analysis_matrix
    names = {t.key for t in traced}
    assert {"dense:prefill", "dense:insert", "dense:decode",
            "dense:decode_window", "paged:decode_paged",
            "speculative:spec", "chunked:prefill_chunk"} <= names
    # every serving entry that returns sharded state declares its contract
    for t in traced:
        if t.name.startswith(("insert", "prefill")):
            assert t.expected_out is not None, t.key


def test_analyzer_green_on_main(analysis_matrix):
    engines, traced = analysis_matrix
    traced = list(traced) + [registry.build_training()]
    findings = passes.run_all(engines, traced)
    base = report.load_baseline(BASELINE)
    new, _ = report.split_findings(findings, base)
    assert new == [], [f.fid for f in new]


def test_insert_is_pinned_on_every_group(analysis_matrix):
    """The satellite fix: arena-returning jits pin out_shardings (the old
    `_insert` relied on operand propagation and must never come back)."""
    _, traced = analysis_matrix
    checked = 0
    for t in traced:
        if not t.name.startswith("insert"):
            continue
        eqn = ju.outer_pjit_eqn(t.jaxpr)
        assert eqn is not None, t.key
        outs = ju.out_shardings_of(eqn)
        assert outs and not any(ju.is_unspecified(s) for s in outs), t.key
        checked += 1
    assert checked >= 4     # contiguous+paged arenas, target+draft


def test_cli_exit_codes(monkeypatch):
    rc = verify.main(["--configs", "dense", "--no-train",
                      "--fail-on-new", "--baseline", BASELINE])
    assert rc == 0

    bad = report.make_finding("identity", "dense", "decode", "psum", "x")
    monkeypatch.setattr(passes, "run_all",
                        lambda *a, **k: [bad])
    assert verify.main(["--configs", "dense", "--no-train",
                        "--baseline", BASELINE]) == 0
    assert verify.main(["--configs", "dense", "--no-train",
                        "--fail-on-new", "--baseline", BASELINE]) == 1


def test_cli_update_baseline(tmp_path, monkeypatch):
    bad = report.make_finding("identity", "dense", "decode", "psum", "x")
    monkeypatch.setattr(passes, "run_all", lambda *a, **k: [bad])
    path = str(tmp_path / "base.json")
    assert verify.main(["--configs", "dense", "--no-train",
                        "--baseline", path, "--update-baseline"]) == 0
    assert verify.main(["--configs", "dense", "--no-train",
                        "--fail-on-new", "--baseline", path]) == 0

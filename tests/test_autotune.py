"""GEMM block-size autotuner tier (PR 9 satellite).

Pins the tuner's three contracts: the per-shape table round-trips
through the ``REPRO_GEMM_TUNE_CACHE`` JSON file (tune once, every later
process starts warm), a corrupt or missing file can never break serving
(lookup degrades to `DEFAULT_BLOCKS`), and `autotune_gemm` records a
winner that the very next `gemm(..., blocks=None)` trace picks up while
staying bitwise-correct against the xla-ref oracle.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, gemm_core


@pytest.fixture(autouse=True)
def _hermetic(monkeypatch):
    """Every test starts with an empty in-memory table and no cache file
    env var; opt in per-test with monkeypatch.setenv."""
    monkeypatch.delenv(autotune.ENV_VAR, raising=False)
    autotune.clear()
    yield
    autotune.clear()


def test_ops_key_names_epilogue():
    assert autotune.ops_key(()) == "dense"
    mask = jnp.ones((8,), jnp.float32)
    scale = jnp.ones((8,), jnp.float32)
    assert autotune.ops_key((gemm_core.col_mask(mask),)) == "col_mask"
    assert autotune.ops_key(
        (gemm_core.dequant(scale), gemm_core.col_mask(mask))
    ) == "dequant+col_mask"
    # packed streams encode the bit width — a 4-bit and an 8-bit GEMM of
    # the same shape tune independently
    k4 = autotune.ops_key((gemm_core.unpack_dequant(4, scale),))
    k8 = autotune.ops_key((gemm_core.unpack_dequant(8, scale),))
    assert k4 != k8


def test_record_lookup_roundtrip_in_memory():
    assert autotune.lookup(8, 128, 64, "dense", "pallas-tpu") is None
    autotune.record(8, 128, 64, "dense", "pallas-tpu", (32, 128, 64))
    assert autotune.lookup(8, 128, 64, "dense", "pallas-tpu") \
        == (32, 128, 64)
    # a different shape / epilogue / backend is a distinct key
    assert autotune.lookup(8, 128, 64, "col_mask", "pallas-tpu") is None
    assert autotune.lookup(8, 128, 64, "dense", "pallas-interpret") is None
    # no env var -> save is a no-op, nothing written anywhere
    assert autotune.save() is None


def test_cache_file_persists_and_reloads(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv(autotune.ENV_VAR, str(path))
    autotune.record(4, 256, 128, "dense", "pallas-tpu", (32, 256, 128))
    payload = json.loads(path.read_text())
    assert payload["format"] == "repro-gemm-tune-v1"
    assert payload["blocks"]["4x256x128|dense|pallas-tpu"] == [32, 256, 128]
    # a fresh process (cleared memory) warms itself from the file
    autotune.clear()
    assert autotune.lookup(4, 256, 128, "dense", "pallas-tpu") \
        == (32, 256, 128)


def test_corrupt_cache_never_breaks(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    path.write_text("{ this is not json")
    monkeypatch.setenv(autotune.ENV_VAR, str(path))
    autotune.clear()
    assert autotune.lookup(8, 128, 64, "dense", "pallas-tpu") is None
    # and the default path still serves: blocks=None falls back cleanly
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (4, 32), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (32, 64), jnp.float32)
    y = gemm_core.gemm(x, w, backend="xla-ref")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x) @ np.asarray(w), rtol=1e-5)


def test_candidate_blocks_clamped_and_deduped():
    cands = autotune.candidate_blocks(4, 128, 256)
    assert len(cands) == len(set(cands))
    for b in cands:
        # every candidate is a fixed point of the clamp: nothing in the
        # list can silently retile to another list entry at dispatch
        assert gemm_core._clamp_blocks(b, 4, 128, 256) == b
    # a tiny shape collapses the 36-point grid to a handful
    assert 1 <= len(autotune.candidate_blocks(1, 64, 32)) <= 6


def test_autotune_refuses_xla_ref():
    x = jnp.zeros((4, 32), jnp.float32)
    w = jnp.zeros((32, 64), jnp.float32)
    with pytest.raises(ValueError, match="xla-ref"):
        autotune.autotune_gemm(x, w, backend="xla-ref")


def test_autotune_records_winner_and_gemm_uses_it(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv(autotune.ENV_VAR, str(path))
    k = jax.random.PRNGKey(1)
    M, K, N = 4, 32, 128
    x = jax.random.normal(k, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32)
    cands = [(32, 128, 128), (64, 128, 256)]
    winner, timings = autotune.autotune_gemm(
        x, w, backend="pallas-interpret", candidates=cands, repeats=1)
    # candidates are timed and recorded as given; gemm re-clamps whatever
    # the table hands back at dispatch time
    assert winner in cands
    assert set(timings) == set(cands)
    assert all(t > 0 for t in timings.values())
    # the winner is in the table, in the file, and the next blocks=None
    # trace of this shape resolves it — and stays exact vs the oracle
    assert autotune.lookup(M, N, K, "dense", "pallas-interpret") == winner
    payload = json.loads(path.read_text())
    assert f"{M}x{N}x{K}|dense|pallas-interpret" in payload["blocks"]
    got = gemm_core.gemm(x, w, backend="pallas-interpret")
    want = gemm_core.gemm(x, w, backend="xla-ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_autotune_persist_false_stays_in_memory(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv(autotune.ENV_VAR, str(path))
    autotune.record(2, 64, 32, "dense", "pallas-tpu", (32, 64, 32),
                    persist=False)
    assert not path.exists()
    assert autotune.lookup(2, 64, 32, "dense", "pallas-tpu") == (32, 64, 32)

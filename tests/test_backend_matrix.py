"""Backend-parity matrix: every GEMM epilogue the models can emit, swept
across the kernel backends on odd/ragged (non-MXU-aligned) shapes.

One parameterized test covers the full product

    {col_mask, dequant(int8), dequant(int16), fake_quant, fused joint}
      x {pallas-interpret vs xla-ref}
      x ragged (M, K, N) sweeps,

asserting the Pallas kernel logic and the pure-jnp oracle agree to <=1e-4.
`test_gemm_core.py` checks each op against its *ref oracle*; this matrix
pins the two *backends* against each other through the public `gemm()`
entry point, so a padding/tiling regression in either backend trips the
same test cell that names it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gemm_core

# deliberately ragged: primes, 1-row/1-col edges, > one block in each dim,
# decode-shaped small-M rows (M = active slots; exercises the aligned
# small-M bm clamp in gemm_core._clamp_blocks)
RAGGED_SHAPES = [(1, 1, 1), (1, 7, 5), (3, 193, 17), (29, 31, 37),
                 (57, 384, 129), (130, 257, 131),
                 (4, 256, 128), (8, 96, 160)]

ATOL = 1e-4


def _w_and_ops(key, kind, k, n):
    """Build (rhs tensor, epilogue ops) for one matrix cell."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    mask = (jax.random.uniform(k2, (n,)) > 0.35).astype(jnp.float32)
    d, qm, t = jnp.float32(0.05), jnp.float32(1.3), jnp.float32(0.9)
    if kind == "col_mask":
        return jax.random.normal(k1, (k, n)), (gemm_core.col_mask(mask),)
    if kind in ("dequant_int8", "dequant_int16"):
        # scale ~ q_m / 2^(bits-1): effective weights stay O(1), like the
        # codes `construct_subnet` actually emits
        dt = jnp.int8 if kind == "dequant_int8" else jnp.int16
        hi = 127 if kind == "dequant_int8" else 32000
        codes = jax.random.randint(k1, (k, n), -hi, hi).astype(dt)
        scale = (jax.random.uniform(k2, (n,)) + 0.5) * (2.0 / hi)
        return codes, (gemm_core.dequant(scale),)
    if kind.startswith("unpack_dequant"):
        # sub-byte packed codes: int32 word stream along K (bits=3 covers
        # the 10-codes-per-word stream whose block is the non-default 120)
        from repro.core.quant import pack_codes
        bits = int(kind[-1])
        hi = 2 ** (bits - 1) - 1
        codes = jax.random.randint(k1, (k, n), -hi, hi + 1).astype(jnp.int8)
        scale = (jax.random.uniform(k2, (n,)) + 0.5) * (2.0 / hi)
        return (pack_codes(codes, bits, axis=0),
                (gemm_core.unpack_dequant(bits, scale),))
    if kind == "fake_quant":
        return (jax.random.normal(k1, (k, n)) * 1.5,
                (gemm_core.fake_quant_rhs(d, qm, t),))
    assert kind == "fused_joint"
    return (jax.random.normal(k1, (k, n)) * 1.5,
            gemm_core.fq_mask_ops(d, qm, t, mask))


EPILOGUES = ["col_mask", "dequant_int8", "dequant_int16", "fake_quant",
             "fused_joint", "unpack_dequant_b4", "unpack_dequant_b3"]


@pytest.mark.parametrize("mkn", RAGGED_SHAPES,
                         ids=[f"{m}x{k}x{n}" for m, k, n in RAGGED_SHAPES])
@pytest.mark.parametrize("kind", EPILOGUES)
def test_epilogue_backend_matrix(kind, mkn):
    m, k, n = mkn
    seed = sum(ord(c) for c in kind) * 1009 + m * 7 + k * 11 + n * 13
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    w, rhs_ops = _w_and_ops(k * 31 + n, kind, k, n)
    y_pallas = gemm_core.gemm(x, w, rhs_ops, backend="pallas-interpret")
    y_ref = gemm_core.gemm(x, w, rhs_ops, backend="xla-ref")
    assert y_pallas.shape == (m, n) == y_ref.shape
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref),
                               rtol=1e-4, atol=ATOL)

"""Sharded GETA training parity tier.

The contract under test (DESIGN.md §5): a GETA/QASSO train step on a
k-device mesh is BITWISE-identical to the 1-device reference running the
same step with `grad_slices=k` — deterministic ordered gradient reduction
plus replica-consistent QASSO statistics make the whole trajectory (loss,
post-projection qparams, pruned-group masks, optimizer moments) exact, not
merely close. The asserts below use the issue tolerance (<=1e-6, identical
masks); the design delivers equality.

The 4-device cases need fake host devices:

    REPRO_MULTI_DEVICE=1 \
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m pytest tests/test_sharded_training.py

and skip themselves on 1-device hosts (the regular fast tier still runs
the 1-device consistency tests).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CompressionConfig, get_arch
from repro.data.synthetic import batch_for, image_batch
from repro.distributed.sharding import make_plan
from repro.launch.mesh import make_subset_mesh
from repro.launch.specs import param_specs
from repro.launch.train import (build_geta, make_geta_train_step,
                                make_sharded_geta_train_step)
from repro.models.cnn import CNN, CNNSpec
from repro.models.transformer import LM

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs REPRO_MULTI_DEVICE=1 "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4")

# 10 steps covering all four QASSO stages: warm-up [0,2), projection
# [2,4), joint [4,8) with a partition recompute at 4 and 6 and the
# hard-zero finalize at 7, cool-down [8,10).
COMP = CompressionConfig(
    target_sparsity=0.25, bit_lower=4, bit_upper=16,
    warmup_steps=2, projection_periods=1, projection_steps=2,
    pruning_periods=2, pruning_steps=2, cooldown_steps=2)
STEPS = 10
TINY_CNN = CNNSpec("tiny-vgg", "vgg", [16, 16], fc_dim=32, in_hw=8)


def _host(tree):
    return jax.tree_util.tree_map(np.asarray, tree)


def _run_transformer(n_devices: int, fsdp: bool, grad_slices: int = 4):
    cfg = get_arch("internlm2-1.8b", smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    qparams = lm.init_qparams(params, bits_init=16.0)
    _, qasso = build_geta(lm, COMP, lr=3e-3, base_optimizer="momentum")
    qstate = qasso.init(params, qparams)
    mesh = make_subset_mesh(n_devices)
    plan = make_plan(mesh, fsdp=fsdp)
    _, p_sh, _ = param_specs(lm, mesh, plan)
    jstep, (psh, qsh, ssh, bsh) = make_sharded_geta_train_step(
        lm, qasso, mesh, params, qparams, param_shardings=p_sh,
        grad_slices=grad_slices)
    params = jax.device_put(params, psh)
    qparams = jax.device_put(qparams, qsh)
    qstate = jax.device_put(qstate, ssh)
    losses = []
    for i in range(STEPS):
        b = jax.device_put(batch_for(cfg, 0, i, 4, 16), bsh)
        params, qparams, qstate, m = jstep(params, qparams, qstate, b)
        losses.append(float(m["loss"]))
    return losses, _host(params), _host(qparams), _host(qstate)


def _run_cnn(n_devices: int, grad_slices: int = 4):
    model = CNN(TINY_CNN)
    params = model.init(jax.random.PRNGKey(0))
    qparams = model.init_qparams(params, bits_init=16.0)
    _, qasso = build_geta(model, COMP, lr=3e-3, base_optimizer="momentum")
    qstate = qasso.init(params, qparams)
    mesh = make_subset_mesh(n_devices)
    # the CNN has no logical sharding axes: pure DP, params replicated
    jstep, (psh, qsh, ssh, bsh) = make_sharded_geta_train_step(
        model, qasso, mesh, params, qparams, grad_slices=grad_slices)
    params = jax.device_put(params, psh)
    qparams = jax.device_put(qparams, qsh)
    qstate = jax.device_put(qstate, ssh)
    losses = []
    for i in range(STEPS):
        b = jax.device_put(image_batch(0, i, 8, hw=8), bsh)
        params, qparams, qstate, m = jstep(params, qparams, qstate, b)
        losses.append(float(m["loss"]))
    return losses, _host(params), _host(qparams), _host(qstate)


def _assert_parity(run_a, run_b):
    losses_a, params_a, qparams_a, qstate_a = run_a
    losses_b, params_b, qparams_b, qstate_b = run_b
    np.testing.assert_allclose(losses_a, losses_b, rtol=0, atol=1e-6)
    for xa, xb in zip(jax.tree_util.tree_leaves(qparams_a),
                      jax.tree_util.tree_leaves(qparams_b)):
        np.testing.assert_allclose(xa, xb, rtol=0, atol=1e-6)
    for xa, xb in zip(jax.tree_util.tree_leaves(params_a),
                      jax.tree_util.tree_leaves(params_b)):
        np.testing.assert_allclose(xa, xb, rtol=0, atol=1e-6)
    # masks and the step counter must be IDENTICAL: a single flipped unit
    # means the replicas trained different subnets
    for key in ("redundant", "keep_mask"):
        ma, mb = getattr(qstate_a, key), getattr(qstate_b, key)
        for fam in ma:
            np.testing.assert_array_equal(ma[fam], mb[fam], err_msg=key)
    np.testing.assert_array_equal(qstate_a.step, qstate_b.step)


@needs4
@pytest.mark.parametrize("fsdp", [False, True], ids=["dp", "fsdp"])
def test_transformer_parity_1dev_vs_4dev(fsdp):
    """4-device GETA step == 1-device reference over 10 steps, through
    every QASSO stage (loss, qparams, masks — issue criterion <=1e-6)."""
    _assert_parity(_run_transformer(1, fsdp), _run_transformer(4, fsdp))


@needs4
def test_cnn_parity_1dev_vs_4dev():
    _assert_parity(_run_cnn(1), _run_cnn(4))


@needs4
def test_fsdp_plan_actually_shards_params():
    """Guard against the FSDP parity case silently degenerating to pure
    DP: the plan must shard the embed axis across the 4 data devices."""
    cfg = get_arch("internlm2-1.8b", smoke=True)
    lm = LM(cfg)
    mesh = make_subset_mesh(4)
    plan = make_plan(mesh, fsdp=True)
    _, p_sh, _ = param_specs(lm, mesh, plan)
    sharded = [name for name, sh in p_sh.items()
               if any(p is not None for p in sh.spec)]
    assert sharded, "fsdp plan produced no sharded params"


def test_sharded_step_matches_plain_step_single_device():
    """On a 1-device mesh with grad_slices=1 the sharded builder reduces
    to the plain jitted GETA step (runs in the regular fast tier)."""
    cfg = get_arch("internlm2-1.8b", smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    qparams = lm.init_qparams(params, bits_init=16.0)
    _, qasso = build_geta(lm, COMP, lr=3e-3, base_optimizer="momentum")
    qstate = qasso.init(params, qparams)
    b = batch_for(cfg, 0, 0, 4, 16)

    plain = jax.jit(make_geta_train_step(lm, qasso))
    p_ref, q_ref, s_ref, m_ref = plain(params, qparams, qstate, b)

    mesh = make_subset_mesh(1)
    _, qasso2 = build_geta(lm, COMP, lr=3e-3, base_optimizer="momentum")
    jstep, (psh, qsh, ssh, bsh) = make_sharded_geta_train_step(
        lm, qasso2, mesh, params, qparams, grad_slices=1)
    p_s, q_s, s_s, m_s = jstep(jax.device_put(params, psh),
                               jax.device_put(qparams, qsh),
                               jax.device_put(qstate, ssh),
                               jax.device_put(b, bsh))
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_s["loss"]),
                               rtol=0, atol=1e-6)
    for a, c in zip(jax.tree_util.tree_leaves(_host(p_ref)),
                    jax.tree_util.tree_leaves(_host(p_s))):
        np.testing.assert_allclose(a, c, rtol=0, atol=1e-6)
    for a, c in zip(jax.tree_util.tree_leaves(_host(q_ref)),
                    jax.tree_util.tree_leaves(_host(q_s))):
        np.testing.assert_allclose(a, c, rtol=0, atol=1e-6)


def test_ordered_grads_reject_mismatched_slices():
    """grad_slices must equal the mesh's DP degree on a multi-device mesh
    (one slice per device is what makes the reduction tree deterministic).
    On a 1-device mesh any slice count is a valid sequential split."""
    from repro.launch.train import make_ordered_loss_grads
    cfg = get_arch("internlm2-1.8b", smoke=True)
    lm = LM(cfg)
    if jax.device_count() >= 4:
        with pytest.raises(ValueError, match="one slice per device"):
            make_ordered_loss_grads(lm, make_subset_mesh(4), None,
                                    grad_slices=2)
    lg = make_ordered_loss_grads(lm, make_subset_mesh(1), None,
                                 grad_slices=2)
    assert callable(lg)

"""Flash-decode attention kernel tier (DESIGN.md §4.9).

Four contracts:
- kernel-vs-oracle parity ≤ 1e-4 (pallas-interpret vs xla-ref) over
  ragged cache lengths, per-slot pos vectors, GQA ratios and windowed
  ring states — the same two-backend pin as the GEMM matrix tier;
- split-K chunk-count invariance: the online-softmax cross-chunk
  combine makes any chunking of the cache length produce the same
  attention (1 chunk vs 4 chunks agree to f32 roundoff);
- windowed-cache masking: a *fresh* ring (pos < ring_len) must mask its
  zero-initialized unwritten rows — the pre-kernel decode branch skipped
  the valid mask entirely for window > 0, so those rows received
  softmax weight (the regression this tier locks out);
- engine token-identity with the kernel on vs off across dense /
  pruned / packed serving (the `--decode-attn-parity` smoke contract).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import decode_attn as da
from repro.kernels import ops, ref, use_backend
from repro.models import layers as Lyr

ATOL = 1e-4

# (B, S, KVh, g, dh, chunk): ragged lengths, GQA ratios 1/2/3/8,
# sub-lane and multi-chunk cache lengths, non-128 head dims
CASES = [
    (1, 7, 1, 1, 4, None),
    (2, 33, 2, 3, 8, 16),
    (3, 64, 4, 2, 16, 16),
    (2, 130, 1, 8, 5, 32),
    (4, 24, 2, 1, 128, None),
]


def _case(seed, B, S, KVh, g, dh):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(k1, (B, KVh, g, dh))
    k = jax.random.normal(k2, (B, S, KVh, dh))
    v = jax.random.normal(k3, (B, S, KVh, dh))
    pos = jax.random.randint(k4, (B,), 0, S)
    return q, k, v, pos


@pytest.mark.parametrize("case", CASES,
                         ids=[f"B{b}S{s}KV{h}g{g}dh{d}" for b, s, h, g, d, _
                              in CASES])
def test_kernel_vs_oracle_parity(case):
    B, S, KVh, g, dh, chunk = case
    q, k, v, pos = _case(sum(case[:5]), B, S, KVh, g, dh)
    want = ref.decode_attn_ref(q, k, v, pos)
    got = da.decode_attn_pallas(q, k, v, pos, chunk=chunk, interpret=True)
    assert got.shape == want.shape == (B, KVh, g, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=ATOL)


def test_per_slot_pos_extremes():
    """Every slot at its own progress, including rows 0 (single valid
    slot) and S-1 (whole arena valid)."""
    B, S, KVh, g, dh = 4, 40, 2, 2, 8
    q, k, v, _ = _case(7, B, S, KVh, g, dh)
    pos = jnp.asarray([0, S - 1, 17, 3], jnp.int32)
    want = ref.decode_attn_ref(q, k, v, pos)
    got = da.decode_attn_pallas(q, k, v, pos, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=ATOL)
    # pos = 0 attends over exactly one arena row: the output is v[:, 0]
    # regardless of scores — pins the valid-length mask edge
    expect = np.broadcast_to(np.asarray(v)[0, 0][:, None, :], (KVh, g, dh))
    np.testing.assert_allclose(np.asarray(got[0]), expect,
                               rtol=1e-4, atol=ATOL)


def test_windowed_ring_states():
    """Fresh ring (pos < ring_len: only the first pos+1 rows written) and
    wrapped ring (pos >= ring_len: every row written) both follow the
    min(pos+1, S) rule — fresh masks the unwritten tail, wrapped attends
    over the full ring."""
    B, S, KVh, g, dh = 2, 16, 2, 2, 8
    q, k, v, _ = _case(11, B, S, KVh, g, dh)
    # fresh: pos=5 -> rows [0, 5] valid; the oracle over the sliced cache
    # is the ground truth (no masking needed there at pos = S'-1)
    pos = jnp.asarray([5, 5], jnp.int32)
    got = da.decode_attn_pallas(q, k, v, pos, window=S, chunk=8,
                                interpret=True)
    sliced = ref.decode_attn_ref(q, k[:, :6], v[:, :6],
                                 jnp.asarray([5, 5], jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(sliced),
                               rtol=1e-4, atol=ATOL)
    # wrapped: pos >= S -> all rows valid, mask saturates at S
    pos = jnp.asarray([S + 9, 5 * S], jnp.int32)
    got = da.decode_attn_pallas(q, k, v, pos, window=S, chunk=8,
                                interpret=True)
    want = ref.decode_attn_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=ATOL)
    # and the full-arena mask at pos = S-1 equals the wrapped ring: both
    # attend over every row
    same = da.decode_attn_pallas(q, k, v, jnp.full((B,), S - 1), chunk=8,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(same),
                               rtol=1e-6, atol=1e-6)


def test_split_k_chunk_invariance():
    """1 chunk vs 4 chunks: the cross-chunk rescale combine reproduces
    the single-pass softmax to f32 roundoff."""
    B, S, KVh, g, dh = 2, 64, 2, 4, 16
    q, k, v, pos = _case(13, B, S, KVh, g, dh)
    one = da.decode_attn_pallas(q, k, v, pos, chunk=64, interpret=True)
    four = da.decode_attn_pallas(q, k, v, pos, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(one), np.asarray(four),
                               rtol=1e-6, atol=1e-6)
    want = ref.decode_attn_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(four), np.asarray(want),
                               rtol=1e-4, atol=ATOL)


def test_op_backend_dispatch():
    """`ops.decode_attn_op` routes through the dispatch registry: xla-ref
    is the oracle bit-for-bit, pallas-interpret agrees to the parity
    tier's tolerance."""
    B, S, KVh, g, dh = 2, 20, 2, 2, 8
    q, k, v, pos = _case(17, B, S, KVh, g, dh)
    want = ref.decode_attn_ref(q, k, v, pos)
    with use_backend("xla-ref"):
        got = ops.decode_attn_op(q, k, v, pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got = ops.decode_attn_op(q, k, v, pos, backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=ATOL)


# ------------------------------------------------- windowed decode masking
def _tiny_cfg(window: int) -> ModelConfig:
    return ModelConfig(name="tiny-windowed", family="dense", n_layers=1,
                       d_model=16, n_heads=4, n_kv_heads=2, d_head=4,
                       d_ff=32, vocab=64, window=window, dtype="float32")


@pytest.mark.parametrize("kernel", [True, False], ids=["kernel", "einsum"])
def test_fresh_windowed_cache_masks_unwritten_rows(kernel):
    """Regression: decoding from a *fresh* windowed cache (pos < ring_len)
    must ignore the ring's zero-initialized unwritten rows.

    While pos < window the sliding window isn't binding and the ring
    hasn't wrapped, so a windowed layer must produce exactly the
    full-causal layer's output; before the fix the windowed branch
    applied no valid-length mask at all, giving the zero rows softmax
    weight (score 0 instead of -inf) and dragging the output toward the
    unnormalized mean."""
    W = 6
    cfgw = _tiny_cfg(window=W)
    cfg0 = dataclasses.replace(cfgw, window=0)
    params, _ = Lyr.init_attention(jax.random.PRNGKey(0), cfgw,
                                   "blocks.0.attn", 0, jnp.float32)
    B, KVh, dh = 2, cfgw.n_kv_heads, cfgw.d_head
    ring = (jnp.zeros((B, W, KVh, dh)), jnp.zeros((B, W, KVh, dh)))
    full = (jnp.zeros((B, 12, KVh, dh)), jnp.zeros((B, 12, KVh, dh)))
    with Lyr.use_decode_attn(kernel):
        for t in range(4):   # strictly pre-wrap: t < W
            x = jax.random.normal(jax.random.PRNGKey(100 + t),
                                  (B, 1, cfgw.d_model))
            rope = Lyr.rope_tables(1, cfgw.d_head, cfgw.rope_theta, offset=t)
            outw, cw = Lyr.attn_apply(params, None, cfgw, x, rope=rope,
                                      window=W, prefix="blocks.0.attn",
                                      cache=ring + (jnp.int32(t),))
            out0, c0 = Lyr.attn_apply(params, None, cfg0, x, rope=rope,
                                      window=0, prefix="blocks.0.attn",
                                      cache=full + (jnp.int32(t),))
            ring, full = (cw[0], cw[1]), (c0[0], c0[1])
            np.testing.assert_allclose(
                np.asarray(outw), np.asarray(out0), rtol=1e-5, atol=1e-5,
                err_msg=f"fresh windowed decode diverged from full-causal "
                        f"at pos {t} (unwritten ring rows got weight?)")


# --------------------------------------------------- engine token identity
@pytest.mark.parametrize("mode", ["dense", "pruned_s50", "packed_b4"])
def test_engine_token_identity_kernel_on_vs_off(mode):
    """Engine decode with the flash-decode kernel is token-identical to
    the legacy einsum path on the same weights/prompts/seed — per serving
    mode (the kernel must compose with SlimPlan head counts and packed
    codes). Exactly the `serve --smoke --decode-attn-parity` contract."""
    from repro.launch.serve import decode_attn_parity_check
    kw = {
        "dense": {},
        "pruned_s50": dict(compressed=True, pruned=True, sparsity=0.5),
        "packed_b4": dict(packed=True, bits_init=4.0),
    }[mode]
    out = decode_attn_parity_check("internlm2-1.8b", True, [7, 4], 6,
                                   max_slots=2, verbose=False, **kw)
    assert sorted(out) == [0, 1]
    assert all(len(t) == 6 for t in out.values())

"""Shared GEMM core: epilogue configs vs the pure-jnp oracles, the backend
dispatch registry, and the fused joint-stage projection — all across odd
(non-block-multiple) shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, gemm_core, ops
from repro.kernels.ref import (fq_matmul_ref, masked_matmul_ref, matmul_ref,
                               quant_matmul_ref)

# deliberately non-MXU-aligned (m, k, n) sweeps
ODD_SHAPES = [(1, 7, 5), (13, 130, 257), (100, 130, 200), (57, 384, 129),
              (128, 256, 384)]
BACKENDS = ["pallas-interpret", "xla-ref"]


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


@pytest.mark.parametrize("mkn", ODD_SHAPES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_dense_matmul_parity(mkn, backend):
    m, k, n = mkn
    x, w = _rand(0, (m, k)), _rand(1, (k, n))
    y = ops.matmul_op(x, w, backend=backend)
    np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mkn", ODD_SHAPES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_masked_matmul_parity(mkn, backend):
    m, k, n = mkn
    x, w = _rand(2, (m, k)), _rand(3, (k, n))
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (n,)) > 0.4).astype(
        jnp.float32)
    y = ops.masked_matmul_op(x, w, mask, backend=backend)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(masked_matmul_ref(x, w, mask)),
                               rtol=1e-4, atol=1e-4)
    zero_cols = np.nonzero(np.asarray(mask) < 0.5)[0]
    assert np.all(np.asarray(y)[:, zero_cols] == 0.0)


@pytest.mark.parametrize("mkn", ODD_SHAPES)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("code_dtype", [jnp.int8, jnp.int16])
def test_quant_matmul_parity(mkn, backend, code_dtype):
    m, k, n = mkn
    x = _rand(5, (m, k))
    codes = jax.random.randint(jax.random.PRNGKey(6), (k, n), -127,
                               127).astype(code_dtype)
    scale = jax.random.uniform(jax.random.PRNGKey(7), (n,)) * 0.05
    y = ops.quant_matmul_op(x, codes, scale, backend=backend)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(quant_matmul_ref(x, codes, scale)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mkn", ODD_SHAPES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_fq_masked_matmul_parity(mkn, backend):
    """Acceptance: the fused x @ (fake_quant(w) * mask) kernel matches the
    XLA reference to <= 1e-4 on non-aligned shapes."""
    m, k, n = mkn
    x, w = _rand(8, (m, k)), _rand(9, (k, n)) * 1.5
    mask = (jax.random.uniform(jax.random.PRNGKey(10), (n,)) > 0.3).astype(
        jnp.float32)
    d, qm, t = jnp.float32(0.05), jnp.float32(1.4), jnp.float32(0.85)
    y = ops.fq_masked_matmul_op(x, w, mask, d, qm, t, backend=backend)
    yr = fq_matmul_ref(x, w, d, qm, t, mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)

    y2 = ops.fq_matmul_op(x, w, d, qm, t, backend=backend)
    np.testing.assert_allclose(np.asarray(y2),
                               np.asarray(fq_matmul_ref(x, w, d, qm, t)),
                               rtol=1e-4, atol=1e-4)


def test_op_composition_order():
    """RhsOps compose left-to-right: dequant then mask == mask of dequant."""
    x = _rand(11, (16, 40))
    codes = jax.random.randint(jax.random.PRNGKey(12), (40, 24), -127,
                               127).astype(jnp.int8)
    scale = jax.random.uniform(jax.random.PRNGKey(13), (24,)) * 0.1
    mask = (jnp.arange(24) % 3 > 0).astype(jnp.float32)
    y = gemm_core.gemm(
        x, codes,
        (gemm_core.dequant(scale), gemm_core.col_mask(mask)),
        backend="pallas-interpret")
    yr = quant_matmul_ref(x, codes, scale) * mask[None, :]
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_training_grads_match_reference(backend):
    """Custom VJPs of the routed matmuls agree with autodiff of the jnp
    composition (STE semantics through the quantizer)."""
    from repro.core.quant import fake_quant
    x, w = _rand(14, (24, 40)), _rand(15, (40, 32))
    mask = (jnp.arange(32) % 4 > 0).astype(jnp.float32)
    d, qm, t = jnp.float32(0.08), jnp.float32(1.1), jnp.float32(1.0)
    g = _rand(16, (24, 32))

    def loss_op(x, w, d, qm, t):
        return jnp.sum(ops.fq_masked_matmul_op(x, w, mask, d, qm, t,
                                               backend=backend) * g)

    def loss_ref(x, w, d, qm, t):
        wq = fake_quant(w, d, qm, t) * mask[None, :]
        return jnp.sum((x @ wq) * g)

    got = jax.grad(loss_op, argnums=(0, 1, 2, 3, 4))(x, w, d, qm, t)
    want = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(x, w, d, qm, t)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)

    gm = jax.grad(lambda x: jnp.sum(
        ops.masked_matmul_op(x, w, mask, backend=backend)))(x)
    gm_ref = jax.grad(lambda x: jnp.sum(x @ (w * mask[None, :])))(x)
    np.testing.assert_allclose(np.asarray(gm), np.asarray(gm_ref), rtol=1e-4,
                               atol=1e-5)


# ------------------------------------------------------------- dispatch
def test_dispatch_resolution_order():
    assert dispatch.resolve("xla-ref") == "xla-ref"
    assert dispatch.resolve(None, True) == "pallas-interpret"
    assert dispatch.resolve(None, False) == "pallas-tpu"
    # legacy positional slot carrying a backend name
    assert dispatch.resolve(None, "xla-ref") == "xla-ref"
    with dispatch.use_backend("pallas-interpret"):
        assert dispatch.resolve() == "pallas-interpret"
        assert dispatch.resolve("xla-ref") == "xla-ref"  # per-call wins
    assert dispatch.resolve() == dispatch.platform_default()
    with pytest.raises(ValueError):
        dispatch.resolve("no-such-backend")


def test_dense_proj_routing():
    """layers.dense_proj picks the right op per weight representation."""
    from repro.core.quant import init_quant_params, quantize_int
    from repro.models import layers as Lyr

    x = _rand(17, (2, 5, 40))
    w = _rand(18, (40, 24)) * 0.5
    mask = (jnp.arange(24) % 2).astype(jnp.float32)
    qp = {"w.wq": init_quant_params(w, bits=8.0)}

    # dense
    y = Lyr.dense_proj(x, {"w": w}, None, "w")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4,
                               atol=1e-4)
    # fused fake-quant + colmask riding the param dict
    y = Lyr.dense_proj(x, {"w": w, "w.colmask": mask}, qp, "w")
    q = qp["w.wq"]
    wq = np.asarray(fq_matmul_ref(x.reshape(-1, 40), w, q.d, q.q_m, q.t,
                                  mask)).reshape(2, 5, 24)
    np.testing.assert_allclose(np.asarray(y), wq, rtol=1e-4, atol=1e-4)
    # int codes (compressed serving)
    codes, d = quantize_int(w, q)
    y = Lyr.dense_proj(x, {"w.codes": codes.astype(jnp.int8), "w.scale": d},
                       None, "w")
    yr = quant_matmul_ref(x.reshape(-1, 40), codes.astype(jnp.int8),
                          jnp.broadcast_to(d, (24,))).reshape(2, 5, 24)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    # flag off -> plain composition, same numbers
    Lyr.set_kernel_dispatch(False)
    try:
        y_off = Lyr.dense_proj(x, {"w": w}, None, "w")
    finally:
        Lyr.set_kernel_dispatch(True)
    np.testing.assert_allclose(np.asarray(y_off), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)

"""Slim serving: the engine runs on physically pruned LM shapes.

The contract under test (the transformer analogue of
`test_qadg.test_cnn_masks_preserve_forward_of_kept_units`): a masked unit
contributes *exact zeros* to every downstream tensor, so slicing it away
(`PruningSpace.materialize` -> `derive_slim_plan` -> `LM.apply_slim_plan`)
must not change a single logit on the kept units — dense fake-quant AND
compressed int-code decode, forward AND cached decode, all the way up to
the continuous-batching engine, whose pruned decode must be
token-identical to the masked dense reference while its KV arena and
served params shrink with realized sparsity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.groups import GroupFamily, Member, PruningSpace
from repro.core.qadg import build_qadg
from repro.core.subnet import (compress_lm, compression_report,
                               default_min_keep, derive_slim_plan,
                               magnitude_keep_masks, prepare_serving,
                               prune_lm, tree_bytes)
from repro.launch.engine import (build_engine, build_masked_reference_engine,
                                 synthetic_prompts)
from repro.models.transformer import LM

ARCH = "internlm2-1.8b"
SPARSITY = 0.5


def _f32_lm(arch=ARCH):
    cfg = get_arch(arch, smoke=True)
    if cfg.dtype != "float32":       # tight parity needs f32 weights
        cfg = dataclasses.replace(cfg, dtype="float32")
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return lm, params


def _masks(lm, params, sparsity=SPARSITY):
    qadg = build_qadg(lm.build_graph().graph)
    return qadg, magnitude_keep_masks(qadg.space, params, sparsity,
                                      min_keep=default_min_keep(lm.cfg))


# -------------------------------------------------- masked vs sliced parity
@pytest.mark.parametrize("compressed", [False, True],
                         ids=["dense", "compressed"])
def test_lm_masked_vs_sliced_logit_parity(compressed):
    """Masked LM and physically sliced LM produce identical logits on the
    kept units — attention-head and MLP-hidden families pruned, the
    residual family untouched (it is pinned non-prunable by embed/head)."""
    lm, params = _f32_lm()
    qparams = lm.init_qparams(params)
    qadg, masks = _masks(lm, params)

    kinds = {f.kind for f in qadg.space.prunable_families()}
    assert kinds == {"head_group", "channel"}   # attn heads + mlp hidden
    # every prunable family actually lost units at this sparsity
    assert all(int(jnp.sum(masks[f.name])) < f.units
               for f in qadg.space.prunable_families())
    # the residual family exists and is non-prunable (so logits keep shape)
    resid = [f for f in qadg.space.families
             if not f.prunable and any(m.param == "embed" for m in f.members)]
    assert resid, "residual space lost its embed producer"

    masked = qadg.space.apply_masks(params, masks)

    slim = LM(lm.cfg)
    p_slim, q_slim, meta = prepare_serving(
        slim, dict(params), quantized=True, compressed=compressed,
        keep_masks=masks)
    assert meta["sparsity"] == pytest.approx(SPARSITY, abs=0.05)
    assert meta["param_bytes"] < tree_bytes(params)

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0, lm.cfg.vocab)
    lg_masked = lm.forward(masked, qparams, toks)
    lg_slim = slim.forward(p_slim, q_slim, toks)
    assert lg_slim.shape == lg_masked.shape    # head/residual not pruned
    np.testing.assert_allclose(np.asarray(lg_slim), np.asarray(lg_masked),
                               rtol=2e-4, atol=2e-4)
    assert np.array_equal(np.argmax(np.asarray(lg_slim), -1),
                          np.argmax(np.asarray(lg_masked), -1))


@pytest.mark.parametrize("compressed", [False, True],
                         ids=["dense", "compressed"])
def test_lm_masked_vs_sliced_decode_parity(compressed):
    """Cached decode through the sliced KV arena matches the masked dense
    reference step for step (greedy tokens identical)."""
    lm, params = _f32_lm()
    qparams = lm.init_qparams(params)
    qadg, masks = _masks(lm, params)
    masked = qadg.space.apply_masks(params, masks)

    slim = LM(lm.cfg)
    p_slim, q_slim, _ = prepare_serving(
        slim, dict(params), quantized=True, compressed=compressed,
        keep_masks=masks)

    def greedy(model, p, q, steps=6):
        caches = model.init_cache(2, 16, dtype=jnp.float32)
        tok = jnp.zeros((2, 1), jnp.int32)
        step = jax.jit(model.decode_step)
        out = []
        for i in range(steps):
            lg, caches = step(p, q, caches, tok, jnp.int32(i))
            tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
            out.append(np.asarray(tok[:, 0]))
        return np.stack(out)

    np.testing.assert_array_equal(greedy(slim, p_slim, q_slim),
                                  greedy(lm, masked, qparams))


def test_slim_plan_shapes_and_kv_arena():
    """The derived SlimPlan reports the surviving widths, the model
    reshapes at them, and init_cache allocates KV rows for surviving
    kv heads only (proportional byte shrink)."""
    lm, params = _f32_lm()
    cfg = lm.cfg
    slim = LM(cfg)
    sliced, plan = prune_lm(slim, dict(params), sparsity=SPARSITY)
    shp = plan.layer_shapes[0]
    assert shp.n_kv_heads < cfg.n_kv_heads
    assert shp.n_heads == shp.n_kv_heads * cfg.gqa_group
    assert shp.d_ff < cfg.d_ff
    kept = plan.kept_units[f"blocks.0.attn.kv_groups"]
    assert len(kept) == shp.n_kv_heads
    # sliced params carry the plan's widths
    assert sliced["blocks.0.attn.wk"].shape[-1] == shp.n_kv_heads * cfg.d_head
    assert sliced["blocks.0.mlp.w_gate"].shape[-1] == shp.d_ff

    full = LM(cfg).init_cache(2, 16, dtype=jnp.float32)
    slimc = slim.init_cache(2, 16, dtype=jnp.float32)
    assert tree_bytes(slimc) == \
        tree_bytes(full) * shp.n_kv_heads // cfg.n_kv_heads


def test_prune_then_compress_stacks():
    """Pruning composes with int-code compression: codes are emitted at
    the *sliced* shapes and the dequant-epilogue decode runs on them."""
    lm, params = _f32_lm()
    qparams = lm.init_qparams(params)
    slim = LM(lm.cfg)
    sliced, plan = prune_lm(slim, dict(params), sparsity=SPARSITY)
    subnet = compress_lm(slim, sliced, qparams)
    assert subnet.int_weights
    for name, codes in subnet.int_weights.items():
        assert codes.shape == sliced[name].shape, name


@pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-1.5-large-398b"])
def test_pruned_decode_stateful_families(arch):
    """SSM/RWKV/hybrid(+MoE) subnets decode at sliced state widths: the
    recurrent caches (mamba h/conv, rwkv wkv) shrink with the plan and
    the decode stays finite. (MoE masked-vs-sliced parity is out of
    contract: a zeroed router column still wins softmax mass — see
    DESIGN.md §4.7.)"""
    lm, params = _f32_lm(arch)
    slim = LM(lm.cfg)
    p_slim, q_slim, meta = prepare_serving(
        slim, dict(params), quantized=False, prune_sparsity=0.4)
    assert meta["sparsity"] > 0.2
    assert tree_bytes(slim.init_cache(1, 16, dtype=jnp.float32)) < \
        tree_bytes(LM(lm.cfg).init_cache(1, 16, dtype=jnp.float32))
    caches = slim.init_cache(1, 16, dtype=jnp.float32)
    tok = jnp.zeros((1, 1), jnp.int32)
    step = jax.jit(slim.decode_step)
    for i in range(3):
        lg, caches = step(p_slim, q_slim, caches, tok, jnp.int32(i))
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        assert np.all(np.isfinite(np.asarray(lg)))


# ------------------------------------------------------- engine end to end
@pytest.mark.parametrize("compressed", [False, True],
                         ids=["dense", "compressed"])
def test_engine_pruned_matches_masked_reference(compressed):
    """Acceptance: engine decode from a sparsity-0.5-pruned transformer is
    token-identical to the masked dense reference, with the KV arena and
    served param bytes reduced proportionally to realized sparsity."""
    lens, gen, slots = [6, 4, 5], 7, 2
    max_seq = max(lens) + gen
    eng, lm = build_engine(ARCH, True, compressed=compressed, pruned=True,
                           sparsity=SPARSITY, max_slots=slots,
                           max_seq=max_seq)
    ref, _ = build_masked_reference_engine(ARCH, True, sparsity=SPARSITY,
                                           max_slots=slots, max_seq=max_seq)
    for p in synthetic_prompts(lm.cfg, lens):
        eng.submit(p, gen)
        ref.submit(p, gen)
    out, want = eng.run(), ref.run()
    assert sorted(out) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(out[rid], want[rid],
                                      err_msg=f"request {rid}")
    # realized-shape wins: KV rows for surviving kv heads only, and the
    # prunable block weights shrink proportionally to sparsity (embed/head
    # are non-prunable and dominate the smoke model's total)
    sp = eng.serving_meta["sparsity"]
    blk = lambda e: tree_bytes({k: v for k, v in e.params.items()
                                if k.startswith("blocks.")})
    assert eng.kv_bytes() == ref.kv_bytes() // 2      # 1 of 2 kv groups
    assert blk(eng) <= blk(ref) * (1.0 - sp) + 2**12
    assert eng.param_bytes() < ref.param_bytes()
    assert eng.serving_meta["kv_bytes"] == eng.kv_bytes()


def test_engine_pruned_slot_reuse_and_mixed_lengths():
    """Continuous batching invariants survive the slim shapes: per-slot
    positions, admission into freed slots, mixed budgets."""
    eng, lm = build_engine(ARCH, True, pruned=True, sparsity=SPARSITY,
                           max_slots=1, max_seq=16)
    alone, _ = build_engine(ARCH, True, pruned=True, sparsity=SPARSITY,
                            max_slots=1, max_seq=16)
    prompts = synthetic_prompts(lm.cfg, [5, 3, 5])
    want = alone.submit(prompts[2], 6)
    want = alone.run()[want]
    for p, g in zip(prompts, (4, 6, 6)):
        eng.submit(p, g)
    out = eng.run()
    np.testing.assert_array_equal(out[2], want)


# ------------------------------------------------------------- satellites
def test_materialize_rejects_out_of_range_layout():
    """A mis-specified layout must raise (naming family and member), not
    silently truncate to a wrong slice."""
    w = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    # family claims 4 units x unit_size 2 = 8 elements on an axis of 6
    fam = GroupFamily("bad.family", 4, [Member("w", 0, unit_size=2)])
    space = PruningSpace([fam])
    mask = jnp.ones((4,)).at[0].set(0.0)
    with pytest.raises(ValueError, match="bad.family.*w"):
        space.materialize({"w": w}, {"bad.family": mask})


def test_compress_lm_records_skipped_sites():
    """Non-routed weights (MoE einsum tensors) stay dense; their names
    must land in Subnet.meta['skipped_sites'] and show in the report."""
    lm, params = _f32_lm("grok-1-314b")
    qparams = lm.init_qparams(params)
    subnet = compress_lm(lm, params, qparams)
    skipped = subnet.meta["skipped_sites"]
    assert skipped and all(".moe." in n for n in skipped)
    assert not any(n in subnet.int_weights for n in skipped)
    report = compression_report("grok-1-314b", subnet.meta)
    assert f"{len(skipped)} non-routed sites kept dense" in report


def test_derive_slim_plan_validates_kept_units():
    """A kept_units dict inconsistent with the sliced shapes is a hard
    error, not a silently wrong plan."""
    lm, params = _f32_lm()
    slim = LM(lm.cfg)
    sliced, plan = prune_lm(slim, dict(params), sparsity=SPARSITY)
    bad = dict(plan.kept_units)
    fam = "blocks.0.attn.kv_groups"
    bad[fam] = bad[fam][:-1] if len(bad[fam]) > 1 else np.array([0, 1])
    with pytest.raises(ValueError, match="kv_groups"):
        derive_slim_plan(slim, sliced, bad)


def test_moe_floor_keeps_top_k_experts():
    """Magnitude masks never prune the expert family below the router's
    top_k (a top-k over fewer experts than k cannot execute)."""
    lm, params = _f32_lm("grok-1-314b")
    qadg = build_qadg(lm.build_graph().graph)
    masks = magnitude_keep_masks(qadg.space, params, 0.95,
                                 min_keep=default_min_keep(lm.cfg))
    for fam in qadg.space.prunable_families():
        if fam.kind == "expert":
            assert int(jnp.sum(masks[fam.name])) >= lm.cfg.moe.top_k

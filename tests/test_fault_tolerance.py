"""Fault tolerance: checkpoint/restart, deterministic replay, straggler
flagging, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.synthetic import image_batch, lm_batch
from repro.distributed.fault import (FaultConfig, FaultTolerantLoop,
                                     HeartbeatRegistry, StragglerMonitor)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "t": (jnp.int32(7), jnp.zeros(())),
            }
    save_checkpoint(str(tmp_path), 42, tree)
    assert latest_step(str(tmp_path)) == 42
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 42
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_async_and_latest(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    t = save_checkpoint(str(tmp_path), 1, tree, async_write=True)
    t.join()
    save_checkpoint(str(tmp_path), 5, {"w": jnp.ones((4, 4)) * 5})
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 5
    assert float(restored["w"][0, 0]) == 5.0


def test_checkpoint_atomicity(tmp_path):
    """A tmp dir without manifest is never considered a checkpoint."""
    os.makedirs(tmp_path / ".tmp_step_9")
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 3, {"w": jnp.zeros(2)})
    assert latest_step(str(tmp_path)) == 3


def test_data_pipeline_deterministic_replay():
    """batch(seed, step) is a pure function — exact replay after restart."""
    b1 = lm_batch(0, 17, 4, 32, 1000)
    b2 = lm_batch(0, 17, 4, 32, 1000)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = lm_batch(0, 18, 4, 32, 1000)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    i1 = image_batch(0, 5, 4)
    i2 = image_batch(0, 5, 4)
    np.testing.assert_array_equal(np.asarray(i1["images"]),
                                  np.asarray(i2["images"]))


def test_fault_loop_recovers_and_replays(tmp_path):
    """Injected failure -> restore from checkpoint -> identical final state
    to an uninterrupted run (determinism through restarts)."""

    def make_run(fail_at):
        trace = []

        def step_fn(state, i):
            if fail_at is not None and i == fail_at[0]:
                fail_at[0] = None  # fire once
                raise RuntimeError("injected failure")
            b = lm_batch(0, i, 2, 8, 100)
            state = state + float(jnp.sum(b["tokens"]))
            trace.append(i)
            return state

        store = {}

        def save_fn(state, step):
            store["ckpt"] = (state, step)

        def restore_fn():
            return store.get("ckpt")

        loop = FaultTolerantLoop(FaultConfig(checkpoint_every=3), step_fn,
                                 save_fn, restore_fn)
        final, result = loop.run(0.0, 10)
        return final, result

    clean, r0 = make_run(None)
    faulty, r1 = make_run([7])
    assert r0.restarts == 0
    assert r1.restarts == 1
    assert clean == pytest.approx(faulty)


def test_fault_loop_gives_up_after_max_restarts():
    def step_fn(state, i):
        raise RuntimeError("permafail")

    loop = FaultTolerantLoop(FaultConfig(max_restarts=2), step_fn,
                             lambda s, i: None, lambda: None)
    with pytest.raises(RuntimeError):
        loop.run(0, 5)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(factor=2.0, patience=2)
    for _ in range(10):
        mon.record("fast", 0.1)
    mon.record("slow", 1.0)
    mon.record("slow", 1.0)
    assert "slow" in mon.flagged
    assert "fast" not in mon.flagged


def test_heartbeat_timeout():
    reg = HeartbeatRegistry(["a", "b"], timeout=10.0)
    reg.beat("a", now=100.0)
    reg.beat("b", now=100.0)
    assert reg.dead_hosts(now=105.0) == []
    reg.beat("a", now=120.0)
    assert reg.dead_hosts(now=125.0) == ["b"]


def test_elastic_reshard_restore(tmp_path):
    """Restore places arrays with NEW shardings (mesh change simulated by
    restoring with explicit single-device shardings)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, step = restore_checkpoint(str(tmp_path), tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]))


@pytest.mark.slow
def test_train_loop_end_to_end_with_failure(tmp_path):
    """The real GETA train loop survives an injected node failure."""
    from repro.launch.train import train_loop
    state, qadg, qasso, losses = train_loop(
        "internlm2-1.8b", smoke=True, steps=24, batch=2, seq=16,
        ckpt_dir=str(tmp_path), inject_failure_at=13, verbose=False)
    assert len(losses) >= 24
    assert np.isfinite(losses[-1])

"""Property-based speculative-rollback invariants (hypothesis).

For hypothesis-drawn request mixes (prompt lengths, token budgets, slot
pressure, draft window), after every speculative round both KV arenas
must be bitwise indistinguishable from a never-drafted engine: rows
beyond each active slot's pos are zero (the zero-rollback contract of
`launch.speculative.rollback_rows` on full arenas), pos/last_tok track
the committed stream exactly, and the drained output matches the plain
engine token-for-token. The engine under test carries a *garbage* draft
(different random init), so nearly every round rejects at some depth —
the draws explore rollback depths and admission/eviction interleavings,
not model quality. Runs under the conftest "repro" derandomized profile;
the deterministic sweep in tests/test_speculative.py drives the same
`run_rollback_case` when hypothesis is absent.
"""
import pytest

pytest.importorskip("hypothesis")  # property-based tests; see requirements-dev.txt
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from test_speculative import run_rollback_case  # noqa: E402


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_rollback_restores_never_drafted_state_random(data):
    n = data.draw(st.integers(1, 3), label="n_requests")
    lens = data.draw(st.lists(st.integers(2, 6), min_size=n, max_size=n),
                     label="prompt_lens")
    gens = data.draw(st.lists(st.integers(1, 8), min_size=n, max_size=n),
                     label="gens")
    draft_k = data.draw(st.sampled_from([1, 2, 4, 8]), label="draft_k")
    run_rollback_case(lens, gens, draft_k)

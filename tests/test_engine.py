"""Continuous-batching engine: one-shot prefill parity and engine-vs-static
decode parity.

Two invariants keep the engine honest:
1. `LM.prefill` (one full-sequence forward that fills the caches) must be
   numerically interchangeable with the sequential decode-step prefill —
   same logits, same caches, same greedy tokens — dense AND compressed.
2. The engine's continuous-batching decode (per-slot positions, admission/
   eviction, slot cache arena) must emit token-identical output to the
   static lockstep `serve_loop` for the same request set.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.subnet import prepare_serving
from repro.launch.engine import Engine, build_engine, synthetic_prompts
from repro.launch.serve import serve_loop
from repro.models.transformer import LM

ARCH = "internlm2-1.8b"


def _serving_lm(arch=ARCH, compressed=False, quantized=True):
    cfg = get_arch(arch, smoke=True)
    if cfg.dtype != "float32":      # tight parity needs f32 weights
        cfg = dataclasses.replace(cfg, dtype="float32")
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    params, qparams, _ = prepare_serving(
        lm, params, quantized=quantized, compressed=compressed)
    return lm, params, qparams


def _sequential_prefill(lm, params, qparams, toks, max_seq):
    """The reference cache-building path: one decode_step per token."""
    caches = lm.init_cache(toks.shape[0], max_seq, dtype=jnp.float32)
    step = jax.jit(lm.decode_step)
    logits = []
    for p in range(toks.shape[1]):
        lg, caches = step(params, qparams, caches, toks[:, p:p + 1],
                          jnp.int32(p))
        logits.append(lg)
    return jnp.concatenate(logits, axis=1), caches


# ------------------------------------------------------------ prefill parity
@pytest.mark.parametrize("compressed", [False, True],
                         ids=["dense", "compressed"])
def test_prefill_matches_sequential_decode(compressed):
    lm, params, qparams = _serving_lm(compressed=compressed)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0, lm.cfg.vocab)
    lg_seq, c_seq = _sequential_prefill(lm, params, qparams, toks, 16)
    c_pre = lm.init_cache(2, 16, dtype=jnp.float32)
    lg_pre, c_pre = jax.jit(lm.prefill)(params, qparams, c_pre, toks)

    assert np.array_equal(np.argmax(np.asarray(lg_pre), -1),
                          np.argmax(np.asarray(lg_seq), -1))
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_seq),
                               rtol=1e-4, atol=1e-4)
    for k in c_seq:
        np.testing.assert_allclose(np.asarray(c_pre[k]), np.asarray(c_seq[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-1.5-large-398b"])
def test_prefill_matches_sequential_decode_stateful_families(arch):
    """SSM/RWKV/hybrid(+MoE) caches are recurrent states, not KV rows — the
    one-shot prefill must leave exactly the state S sequential steps
    would. MoE routing must not drop prompt tokens (one-token decode never
    overflows an expert, so a dropping prefill silently diverges)."""
    lm, params, qparams = _serving_lm(arch, quantized=False)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, lm.cfg.vocab)
    lg_seq, c_seq = _sequential_prefill(lm, params, qparams, toks, 16)
    c_pre = lm.init_cache(2, 16, dtype=jnp.float32)
    lg_pre, c_pre = jax.jit(lm.prefill)(params, qparams, c_pre, toks)
    assert np.array_equal(np.argmax(np.asarray(lg_pre), -1),
                          np.argmax(np.asarray(lg_seq), -1))
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_seq),
                               rtol=1e-4, atol=1e-4)
    for k in c_seq:
        np.testing.assert_allclose(np.asarray(c_pre[k]), np.asarray(c_seq[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)


# ----------------------------------------------------- engine vs serve_loop
@pytest.mark.parametrize("compressed", [False, True],
                         ids=["dense", "compressed"])
def test_engine_matches_static_serve_loop(compressed):
    """Acceptance: continuous-batching decode emits token-identical output
    to the static lockstep loop for the same request set — with fewer
    slots than requests, so admission/eviction runs mid-decode."""
    batch, prompt_len, gen = 3, 6, 8
    eng, lm = build_engine(ARCH, True, compressed=compressed,
                           max_slots=2, max_seq=prompt_len + gen)
    prompts = synthetic_prompts(lm.cfg, [prompt_len] * batch)
    # identical requests by construction: the static loop consumes the
    # same prompt matrix the engine was fed
    seq = serve_loop(ARCH, True, batch, prompt_len, gen,
                     compressed=compressed, verbose=False,
                     prompts=np.stack(prompts))
    for p in prompts:
        eng.submit(p, gen)
    out = eng.run()
    assert sorted(out) == [0, 1, 2]
    for rid in out:
        np.testing.assert_array_equal(out[rid], np.asarray(seq)[rid],
                                      err_msg=f"request {rid}")
    # eviction freed slots for the queued third request
    assert eng.stats["evicted"] == batch
    assert eng.stats["decode_steps"] > gen - 1   # two waves of decode


def test_engine_mixed_lengths_match_per_request_reference():
    """Slots at different positions share one decode dispatch; each
    request's tokens must match its own single-request static decode."""
    lm, params, qparams = _serving_lm()
    lens = [7, 3, 5, 4]
    gens = [6, 9, 4, 7]
    prompts = synthetic_prompts(lm.cfg, lens)
    eng = Engine(lm, params, qparams, max_slots=2, max_seq=16)
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    out = eng.run()

    step = jax.jit(lm.decode_step)
    for rid, (p, g) in enumerate(zip(prompts, gens)):
        caches = lm.init_cache(1, 16, dtype=jnp.float32)
        toks = jnp.asarray(p)[None]
        for q in range(len(p)):
            lg, caches = step(params, qparams, caches, toks[:, q:q + 1],
                              jnp.int32(q))
        ref = [int(jnp.argmax(lg[0, -1]))]
        for q in range(g - 1):
            tok = jnp.asarray([[ref[-1]]], jnp.int32)
            lg, caches = step(params, qparams, caches, tok,
                              jnp.int32(len(p) + q))
            ref.append(int(jnp.argmax(lg[0, -1])))
        np.testing.assert_array_equal(out[rid], np.asarray(ref, np.int32),
                                      err_msg=f"request {rid}")


def test_engine_slot_reuse_isolated():
    """A request admitted into a freed slot must decode exactly as if it
    had the slot from the start — no state bleeds through eviction."""
    lm, params, qparams = _serving_lm()
    prompts = synthetic_prompts(lm.cfg, [5, 5, 5])
    alone = Engine(lm, params, qparams, max_slots=1, max_seq=16)
    rid = alone.submit(prompts[2], 6)
    want = alone.run()[rid]

    eng = Engine(lm, params, qparams, max_slots=1, max_seq=16)
    for p in prompts:
        eng.submit(p, 6)
    out = eng.run()
    np.testing.assert_array_equal(out[2], want)


def test_engine_admission_guards():
    lm, params, qparams = _serving_lm()
    eng = Engine(lm, params, qparams, max_slots=2, max_seq=8)
    with pytest.raises(ValueError):
        eng.submit(np.arange(6), 4)     # needs 6 + 4 - 1 = 9 rows > 8
    with pytest.raises(ValueError):
        eng.submit(np.arange(3), 0)
    with pytest.raises(ValueError):
        eng.submit(np.zeros((0,)), 2)
    # one-token request completes at admission, never holding a slot
    rid = eng.submit(np.arange(4), 1)
    out = eng.run()
    assert len(out[rid]) == 1
    assert eng.stats["decode_steps"] == 0


def test_engine_admits_exact_capacity_request():
    """A request needing exactly max_seq cache rows must be admitted: S
    prompt rows plus N-1 decode writes touch rows [0, S+N-1) — the first
    generated token comes from the prefill and writes nothing. The old
    `S + N > max_seq` guard rejected this boundary request (off-by-one),
    silently shrinking every engine's usable budget by one token."""
    lm, params, qparams = _serving_lm()
    prompts = synthetic_prompts(lm.cfg, [5])
    eng = Engine(lm, params, qparams, max_slots=1, max_seq=8)
    rid = eng.submit(prompts[0], 4)     # rows needed: 5 + 4 - 1 = 8 == 8
    out = eng.run()
    assert len(out[rid]) == 4
    # and the boundary decode is trustworthy: identical to a roomy arena
    big = Engine(lm, params, qparams, max_slots=1, max_seq=16)
    brid = big.submit(prompts[0], 4)
    np.testing.assert_array_equal(out[rid], big.run()[brid])


def test_engine_admission_guards_one_past_capacity():
    lm, params, qparams = _serving_lm()
    prompts = synthetic_prompts(lm.cfg, [5])
    eng = Engine(lm, params, qparams, max_slots=1, max_seq=8)
    with pytest.raises(ValueError):
        eng.submit(prompts[0], 5)       # 5 + 5 - 1 = 9 rows > 8


def test_run_drains_only_new_completions():
    """A reused engine must not re-report earlier batches (or retain them:
    `done` is released at each drain)."""
    lm, params, qparams = _serving_lm()
    prompts = synthetic_prompts(lm.cfg, [4, 4])
    eng = Engine(lm, params, qparams, max_slots=2, max_seq=16)
    r0 = eng.submit(prompts[0], 3)
    assert set(eng.run()) == {r0}
    r1 = eng.submit(prompts[1], 3)
    assert set(eng.run()) == {r1}
    assert not eng.done


def test_draft_prefill_time_rides_its_own_counters(monkeypatch):
    """The draft arena's admission prefill is draft work: its wall time
    and token count must land in draft_prefill_* — folding it into
    prefill_s (as it used to) inflated the target prefill denominator
    and corrupted prefill_tok_per_s for every speculative serve. A fake
    clock that ticks 1.0 per time() call makes every timed block weigh
    exactly 1.0, so the split is assertable without real timing."""
    import itertools
    import types

    import repro.launch.engine as engine_mod
    eng, lm = build_engine(ARCH, True, speculative=True, draft_k=2,
                           max_slots=2, max_seq=16)
    prompts = synthetic_prompts(lm.cfg, [5, 7])
    for p in prompts:
        eng.submit(p, 4)
    eng.warmup()
    ticks = itertools.count()
    monkeypatch.setattr(engine_mod, "time",
                        types.SimpleNamespace(
                            time=lambda: float(next(ticks))))
    eng.run()
    s = eng.stats
    assert s["prefills"] == 2 and s["prefill_tokens"] == 12
    assert s["draft_prefills"] == 2 and s["draft_prefill_tokens"] == 12
    # one timed block each per admission — target and draft prefill time
    # no longer pool into one counter
    assert s["prefill_s"] == pytest.approx(2.0)
    assert s["draft_prefill_s"] == pytest.approx(2.0)


def test_kv_bytes_counts_both_arenas():
    """kv_bytes() is the headline 'KV HBM this serve pins' stat: a
    speculative engine's draft arena is pinned HBM too, so excluding it
    (the old behavior) under-reported every --speculative serve."""
    from repro.core.subnet import tree_bytes
    eng, _ = build_engine(ARCH, True, speculative=True, max_slots=2,
                          max_seq=16)
    t, d = tree_bytes(eng.caches), tree_bytes(eng.dcaches)
    assert d > 0
    assert eng.kv_bytes() == t + d
    assert eng.serving_meta["kv_bytes"] == eng.kv_bytes()
    assert eng.kv_pool_bytes() == eng.kv_bytes()   # contiguous: no pool gap
    non, _ = build_engine(ARCH, True, max_slots=2, max_seq=16)
    assert non.kv_bytes() == tree_bytes(non.caches)


def test_one_token_request_does_not_stall_the_queue():
    """A request that completes at admission must hand its slot to the
    next queued request in the same round — on a single slot, draining
    [1-token, 8-token] used to raise 'queue stuck with no active slots'."""
    lm, params, qparams = _serving_lm()
    prompts = synthetic_prompts(lm.cfg, [4, 4, 4])
    eng = Engine(lm, params, qparams, max_slots=1, max_seq=16)
    rids = [eng.submit(prompts[0], 1), eng.submit(prompts[1], 8),
            eng.submit(prompts[2], 1)]
    out = eng.run()
    assert [len(out[r]) for r in rids] == [1, 8, 1]

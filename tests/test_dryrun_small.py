"""Small-mesh dry-run integration test (subprocess so XLA_FLAGS apply).

Proves the dryrun machinery (mesh build, specs, lower+compile, roofline
parse) works end-to-end with 8 placeholder devices. The 512-device
production matrix is exercised by `python -m repro.launch.dryrun` and
recorded in EXPERIMENTS.md.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.launch.dryrun as DR
from repro.launch.mesh import make_mesh
from repro.roofline import analysis as RA

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {}
for arch, shape, step in [("internlm2-1.8b", "train_4k", "geta"),
                          ("rwkv6-3b", "decode_32k", "geta")]:
    lowered, cfg, meta = DR.build_cell(arch, shape, mesh, step,
                                       depth=1, microbatches=2)
    compiled = lowered.compile()
    cost = RA.cost_from_compiled(compiled)
    out[f"{arch}/{shape}"] = {
        "flops": cost.flops, "wire": cost.wire_bytes,
        "colls": cost.coll_counts,
        "temp": compiled.memory_analysis().temp_size_in_bytes,
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    train = data["internlm2-1.8b/train_4k"]
    assert train["flops"] > 1e9
    assert train["wire"] > 0          # DP gradient collectives present
    assert any(k in train["colls"] for k in ("all-reduce", "all-gather",
                                             "reduce-scatter"))
    decode = data["rwkv6-3b/decode_32k"]
    assert decode["flops"] > 0


def test_collective_parser():
    from repro.roofline.analysis import parse_collectives
    hlo = """
  %ar = bf16[256,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,64]{1,0} all-gather(%y), replica_groups=[4,8]<=[32], dimensions={0}
  %rs = f32[8,32]{1,0} reduce-scatter(%z), replica_groups={{0,1},{2,3}}, dimensions={0}
  %cp = s8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    stats = parse_collectives(hlo)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1, "collective-permute": 1}
    ar = 2 * 3 / 4 * 256 * 1024 * 2
    ag = 7 / 8 * 64 * 64 * 4
    rs = 1 * 8 * 32 * 4
    cp = 128
    assert stats.wire_bytes == pytest.approx(ar + ag + rs + cp)


def test_model_flops_formula():
    from repro.configs import SHAPES, get_arch
    from repro.roofline.analysis import model_flops_for
    cfg = get_arch("internlm2-1.8b")
    f_train = model_flops_for(cfg, SHAPES["train_4k"])
    # 6*N*D ~ 6 * 1.9e9 * 1e6 ~ 1.2e16 plus attention
    assert 1e16 < f_train < 4e16
    f_dec = model_flops_for(cfg, SHAPES["decode_32k"])
    assert f_dec < f_train / 1000

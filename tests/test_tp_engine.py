"""Tensor-parallel serving tier (DESIGN.md §4.12).

The contract: an N-device engine is TOKEN-IDENTICAL to the 1-device
engine across the whole serving stack — dense, pruned (sliced shapes),
sub-byte packed, paged KV, speculative — because TP sharding is
column/head-parallel by construction: every output column and KV head
lives wholly on one device, no contraction is split across devices, no
cross-device reduction reassociates a sum. And the memory claim: a
device's share of params and KV arena shrinks ~1/tp (replication
fallbacks excepted).

The 4-device cases need fake host devices:

    REPRO_MULTI_DEVICE=1 \
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m pytest tests/test_tp_engine.py

and skip themselves on 1-device hosts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import (kv_cache_specs, make_plan,
                                        serving_axes_for,
                                        serving_param_specs)
from repro.kernels import decode_attn as da
from repro.kernels import gemm_core, ops
from repro.launch.engine import build_engine, engine_serve
from repro.launch.mesh import make_tp_mesh

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs REPRO_MULTI_DEVICE=1 "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4")

ARCH = "internlm2-1.8b"


# ------------------------------------------------------------ kernel layer
@needs4
def test_tp_gemm_dense_exact():
    mesh = make_tp_mesh(4)
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (8, 96), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (96, 128), jnp.float32)
    want = gemm_core.gemm(x, w, backend="xla-ref")
    got = gemm_core.tp_gemm(x, w, mesh=mesh, backend="xla-ref")
    # column-parallel: each output column is computed by exactly one
    # device running the single-device kernel — bitwise equality
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs4
def test_tp_gemm_epilogues_exact():
    mesh = make_tp_mesh(4)
    k = jax.random.PRNGKey(1)
    x = jax.random.normal(k, (4, 64), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (64, 128), jnp.float32)
    mask = (jax.random.uniform(jax.random.fold_in(k, 2), (128,)) > 0.5
            ).astype(jnp.float32)
    scale = jax.random.uniform(jax.random.fold_in(k, 3), (128,)) + 0.5
    for rhs_ops in [(gemm_core.col_mask(mask),),
                    (gemm_core.dequant(scale),),
                    (gemm_core.dequant(scale), gemm_core.col_mask(mask))]:
        want = gemm_core.gemm(x, w, rhs_ops, backend="xla-ref")
        got = gemm_core.tp_gemm(x, w, rhs_ops, mesh=mesh, backend="xla-ref")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs4
def test_tp_gemm_packed_exact():
    from repro.core.quant import pack_codes
    mesh = make_tp_mesh(4)
    k = jax.random.PRNGKey(2)
    K, N, bits = 64, 128, 4
    x = jax.random.normal(k, (4, K), jnp.float32)
    codes = jax.random.randint(jax.random.fold_in(k, 1), (K, N), -8, 8,
                               jnp.int32)
    scale = jax.random.uniform(jax.random.fold_in(k, 2), (N,)) + 0.5
    packed = pack_codes(codes, bits)
    want = gemm_core.gemm(x, packed,
                          (gemm_core.unpack_dequant(bits, scale),),
                          backend="xla-ref")
    got = gemm_core.tp_gemm(x, packed,
                            (gemm_core.unpack_dequant(bits, scale),),
                            mesh=mesh, backend="xla-ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs4
def test_tp_gemm_rejects_indivisible_n():
    mesh = make_tp_mesh(4)
    x = jnp.zeros((4, 32), jnp.float32)
    w = jnp.zeros((32, 66), jnp.float32)    # 66 % 4 != 0
    with pytest.raises(ValueError):
        gemm_core.tp_gemm(x, w, mesh=mesh, backend="xla-ref")


@needs4
def test_tp_decode_attn_exact():
    mesh = make_tp_mesh(4)
    k = jax.random.PRNGKey(3)
    B, S, KVh, dh, g = 2, 32, 4, 16, 2
    q = jax.random.normal(k, (B, KVh, g, dh), jnp.float32)
    kc = jnp.zeros((B, S, KVh, dh), jnp.float32)
    vc = jnp.zeros((B, S, KVh, dh), jnp.float32)
    kc = kc.at[:, :20].set(
        jax.random.normal(jax.random.fold_in(k, 1), (B, 20, KVh, dh)))
    vc = vc.at[:, :20].set(
        jax.random.normal(jax.random.fold_in(k, 2), (B, 20, KVh, dh)))
    pos = jnp.asarray([19, 11], jnp.int32)
    want = ops.decode_attn_op(q, kc, vc, pos, backend="xla-ref")
    got = da.tp_decode_attn(q, kc, vc, pos, mesh=mesh, backend="xla-ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs4
def test_tp_decode_attn_rejects_indivisible_heads():
    mesh = make_tp_mesh(4)
    q = jnp.zeros((1, 3, 2, 8), jnp.float32)      # 3 KV heads % 4 != 0
    kc = jnp.zeros((1, 16, 3, 8), jnp.float32)
    with pytest.raises(ValueError):
        da.tp_decode_attn(q, kc, kc, jnp.zeros((1,), jnp.int32),
                          mesh=mesh)


# ----------------------------------------------------------- spec mapping
@needs4
def test_serving_param_specs_maps_derived_keys():
    mesh = make_tp_mesh(4)
    plan = make_plan(mesh, mode="tp")
    axes = {"blocks.0.mlp.w1": ("embed", "mlp")}
    params = {"blocks.0.mlp.w1.codes": np.zeros((128, 256), np.int8),
              "blocks.0.mlp.w1.packed4": np.zeros((16, 256), np.int32),
              "blocks.0.mlp.w1.scale": np.zeros((2,), np.float32),
              "unrelated": np.zeros((7,), np.float32)}
    specs = serving_param_specs(plan, axes, params)
    # codes and packed words shard like the base weight (N on "model");
    # scales and unmapped leaves replicate
    assert specs["blocks.0.mlp.w1.codes"][1] == "model"
    assert specs["blocks.0.mlp.w1.packed4"][1] == "model"
    assert tuple(specs["blocks.0.mlp.w1.scale"]) in ((), (None,))
    assert tuple(specs["unrelated"]) in ((), (None,))


def test_serving_axes_for_suffixes():
    axes = {"w": ("embed", "mlp")}
    assert serving_axes_for("w", axes) == ("embed", "mlp")
    assert serving_axes_for("w.codes", axes) == ("embed", "mlp")
    assert serving_axes_for("w.packed4", axes) == ("embed", "mlp")
    assert serving_axes_for("w.scale", axes) == ("layers",)
    assert serving_axes_for("w.other", axes) is None
    assert serving_axes_for("missing.codes", axes) is None


@needs4
def test_kv_cache_specs_head_axis():
    mesh = make_tp_mesh(4)
    shapes = {"blocks.0.k": (2, 4, 64, 4, 16),       # KVh=4: shard
              "blocks.0.v": (2, 4, 64, 4, 16),
              "blocks.1.k": (2, 4, 64, 3, 16),       # KVh=3: replicate
              "blocks.0.k_scale": (2, 8, 16, 4),     # paged scale: shard
              "blocks.0.h": (2, 4, 32, 7)}           # recurrent state
    specs = kv_cache_specs(mesh, shapes)
    assert specs["blocks.0.k"][3] == "model"
    assert specs["blocks.0.v"][3] == "model"
    assert tuple(specs["blocks.1.k"]) in ((), (None,) * 5)
    assert specs["blocks.0.k_scale"][3] == "model"
    assert tuple(specs["blocks.0.h"]) in ((), (None,) * 4)


# ------------------------------------------------------------ engine layer
@needs4
@pytest.mark.parametrize("kw", [
    pytest.param({}, id="dense"),
    pytest.param(dict(pruned=True, sparsity=0.5), id="pruned_s50"),
    pytest.param(dict(packed=True, bits_init=4.0), id="packed_b4"),
    pytest.param(dict(paged=True, page_size=8), id="paged"),
])
def test_tp4_engine_token_identity(kw):
    base = engine_serve(ARCH, True, [12, 5], 8, verbose=False, **kw)
    tp = engine_serve(ARCH, True, [12, 5], 8, verbose=False, tp=4, **kw)
    assert sorted(base) == sorted(tp)
    for rid in base:
        np.testing.assert_array_equal(base[rid], tp[rid])


@needs4
def test_tp4_speculative_token_identity():
    base = engine_serve(ARCH, True, [12, 5], 8, verbose=False,
                        speculative=True, draft_k=4)
    tp = engine_serve(ARCH, True, [12, 5], 8, verbose=False,
                      speculative=True, draft_k=4, tp=4)
    for rid in base:
        np.testing.assert_array_equal(base[rid], tp[rid])


@needs4
def test_tp4_chunked_prefill_token_identity():
    base = engine_serve(ARCH, True, [12, 5, 21], 8, verbose=False)
    st = {}
    tp = engine_serve(ARCH, True, [12, 5, 21], 8, verbose=False, tp=4,
                      prefill_chunk=8, stats=st)
    for rid in base:
        np.testing.assert_array_equal(base[rid], tp[rid])
    assert st["decode_steps_mid_prefill"] > 0


@needs4
def test_tp2_per_device_bytes_shrink():
    # the smoke arch has 2 KV heads / 4 q heads / 256 mlp / 512 vocab:
    # every projection and the whole arena divide tp=2, so KV halves
    # exactly and params land within a few replicated norm vectors of 1/2
    eng, _ = build_engine(ARCH, True, tp=2)
    full = eng.param_bytes()
    per = eng.param_bytes(per_device=True)
    assert full / 2 <= per <= 0.55 * full, (per, full)
    assert eng.kv_bytes(per_device=True) * 2 == eng.kv_bytes()
    assert eng.serving_meta["tp"]["replicated_fallbacks"] == []


@needs4
def test_tp4_kv_replicates_when_heads_indivisible():
    # 2 KV heads % 4 != 0: the arena must replicate (per-device KV share
    # = full) while q-head/mlp/vocab params still shard — and decode must
    # stay token-identical regardless (covered by the matrix above)
    eng, _ = build_engine(ARCH, True, tp=4)
    assert eng.kv_bytes(per_device=True) == eng.kv_bytes()
    assert eng.param_bytes(per_device=True) < eng.param_bytes()


@needs4
def test_tp2_paged_per_device_kv_shrink():
    eng, _ = build_engine(ARCH, True, tp=2, paged=True, page_size=8)
    # pools are empty of live pages at build; compare the pinned pool
    full = sum(eng._leaf_nbytes(lf, False)
               for lf in jax.tree_util.tree_leaves(eng.caches))
    per = sum(eng._leaf_nbytes(lf, True)
              for lf in jax.tree_util.tree_leaves(eng.caches))
    assert per * 2 == full


@needs4
def test_make_tp_mesh_shape():
    mesh = make_tp_mesh(4)
    assert dict(mesh.shape) == {"data": 1, "model": 4}
    with pytest.raises(ValueError):
        make_tp_mesh(jax.device_count() + 1)

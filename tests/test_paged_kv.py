"""Paged + quantized KV arena (DESIGN.md §4.11).

Three tiers, matching how the arena is layered:

  kernel   — `paged_decode_attn_ref` must equal gather + slice +
             `decode_attn_ref` bitwise (the op's CPU dispatch resolves to
             xla-ref, so this is also the engine's CI numerics), and the
             int8/int4 page codecs must round-trip exactly on their own
             decode points (zero rows, re-encoded codes).
  allocator — `run_allocator_case` drives a PageAllocator against a
             simulated pool, asserting no page is handed out while someone
             holds it, refcounted shared pages survive any one owner's
             eviction, and released pages come back only after an explicit
             zeroing flush. tests/test_paging_properties.py feeds the same
             driver hypothesis-drawn scripts when hypothesis is installed.
  engine   — the hard contract: a paged engine is token-identical to the
             contiguous-arena engine across the dense / pruned / packed /
             speculative cells, prefix sharing skips prefills without
             changing a single token, quantized pages shrink the pool, and
             a drained engine leaves every unowned page bitwise zero.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import kv_quant_decode, kv_quant_encode
from repro.kernels.ops import decode_attn_ref, paged_decode_attn_op
from repro.launch import paging
from repro.launch.engine import build_engine, synthetic_prompts

ARCH = "internlm2-1.8b"


# -------------------------------------------------------------- page codecs
@pytest.mark.parametrize("bits", [8, 4])
def test_kv_quant_roundtrip_properties(bits):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((3, 5, 8, 6)), jnp.float32)
    codes, scale = kv_quant_encode(x, bits)
    assert codes.dtype == jnp.int8
    assert codes.shape[-1] == (x.shape[-1] // 2 if bits == 4 else x.shape[-1])
    y = kv_quant_decode(codes, scale, bits)
    # bounded error: one quantization step of the per-row absmax grid
    qmax = (1 << (bits - 1)) - 1
    bound = np.asarray(scale)[..., None] * np.ones(x.shape)
    np.testing.assert_array_less(np.abs(np.asarray(y - x)), bound + 1e-7)
    # decode points are fixed points: re-encoding decoded values is exact
    c2, s2 = kv_quant_encode(y, bits)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(kv_quant_decode(c2, s2, bits)),
                                  np.asarray(y))


@pytest.mark.parametrize("bits", [8, 4])
def test_kv_quant_zero_rows_stay_exact_zero(bits):
    """Unwritten arena rows are zero; their codes and decode must be too,
    or paged attention over a zero-backed page would leak noise."""
    x = jnp.zeros((2, 4, 8), jnp.float32)
    codes, scale = kv_quant_encode(x, bits)
    assert not np.asarray(codes).any() and not np.asarray(scale).any()
    assert not np.asarray(kv_quant_decode(codes, scale, bits)).any()


# ------------------------------------------------------------ kernel oracle
@pytest.mark.parametrize("kv_bits", [None, 8, 4],
                         ids=["fp", "int8", "int4"])
def test_paged_decode_attn_matches_gathered_reference(kv_bits):
    """Gather pages -> flatten -> slice to seq_len -> decode_attn_ref is
    the oracle; the paged op must match it bitwise (fp pages) or exactly
    on the decoded codes (quantized pages decode first, then both sides
    run identical attention math)."""
    B, KVh, g, dh, P, Lp, seq_len = 2, 2, 3, 8, 8, 3, 20
    n_pages = paging.N_RESERVED + B * Lp
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, KVh, g, dh)), jnp.float32)
    pos = jnp.asarray([5, 17], jnp.int32)
    pt = np.full((B, Lp), paging.ZERO_PAGE, np.int32)
    nxt = paging.N_RESERVED
    for b in range(B):
        npp = paging.pages_for_rows(int(pos[b]) + 1, P)
        pt[b, :npp] = range(nxt, nxt + npp)
        nxt += npp
    pt = jnp.asarray(pt)

    rows = np.zeros((n_pages * P, KVh, dh), np.float32)
    for b in range(B):
        for r in range(int(pos[b]) + 1):
            phys = int(pt[b, r // P]) * P + r % P
            rows[phys] = rng.standard_normal((KVh, dh))
    kpool = jnp.asarray(rows).reshape(n_pages, P, KVh, dh)
    vpool = jnp.asarray(
        rng.standard_normal((n_pages, P, KVh, dh)), jnp.float32)
    vpool = vpool * (jnp.abs(kpool) > 0)     # zero where unwritten
    kw = {}
    if kv_bits is not None:
        kpool, ks = kv_quant_encode(kpool, kv_bits)
        vpool, vs = kv_quant_encode(vpool, kv_bits)
        kw = dict(k_scale=ks, v_scale=vs)

    got = paged_decode_attn_op(q, kpool, vpool, pos, pt, page_size=P,
                               seq_len=seq_len, kv_bits=kv_bits, **kw)

    def flat(pool, scale=None):
        gathered = jnp.take(pool, pt, axis=0)
        if kv_bits is not None:
            gathered = kv_quant_decode(gathered,
                                       jnp.take(scale, pt, axis=0), kv_bits)
        return gathered.reshape(B, Lp * P, KVh, dh)[:, :seq_len]

    want = decode_attn_ref(q, flat(kpool, kw.get("k_scale")),
                           flat(vpool, kw.get("v_scale")), pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------- allocator
def run_allocator_case(script, n_pages=12, page_size=4):
    """Drive a PageAllocator through an op script against a simulated
    pool, asserting the structural invariants after every op:

      no double-hand-out — `alloc` never returns a page any owner holds;
      zero-before-reuse  — every page `alloc` returns reads all-zero in
                           the pool (released pages sit in the dirty
                           quarantine until an explicit flush zeroes
                           them, so skipping the flush starves `alloc`
                           rather than leaking stale rows);
      sharing            — `retain`ed pages survive any one owner's
                           release with their contents intact.

    Ops: ("alloc", owner, n) — may observe MemoryError when free pages
    run short; ("share", new, src) — new owner retains src's pages;
    ("release", owner); ("flush",).
    """
    alloc = paging.PageAllocator(n_pages, page_size)
    pool = np.zeros((n_pages, page_size), np.int64)  # simulated device pool
    holds: dict = {}          # owner -> list of (page, marker)
    marker = 0
    for op in script:
        if op[0] == "alloc":
            _, owner, n = op
            if owner in holds:
                continue
            if not alloc.can_alloc(n):
                with pytest.raises(MemoryError):
                    alloc.alloc(n)
                continue
            pages = alloc.alloc(n)
            held = {p for pages_ in holds.values() for p, _ in pages_}
            assert not held & set(pages), "page handed out while held"
            assert all(p >= paging.N_RESERVED for p in pages)
            for p in pages:
                assert not pool[p].any(), f"page {p} reused before zeroing"
            marker += 1
            pool[pages] = marker
            holds[owner] = [(p, marker) for p in pages]
        elif op[0] == "share":
            _, new, src = op
            if src not in holds or new in holds:
                continue
            pages = [p for p, _ in holds[src]]
            alloc.retain(pages)
            holds[new] = list(holds[src])
        elif op[0] == "release":
            _, owner = op
            if owner not in holds:
                continue
            dirty = alloc.release([p for p, _ in holds.pop(owner)])
            still_held = {p for pages_ in holds.values() for p, _ in pages_}
            assert not set(dirty) & still_held, \
                "shared page quarantined while another owner holds it"
        elif op[0] == "flush":
            dirty = alloc.take_dirty()
            pool[dirty] = 0
            alloc.mark_zeroed(dirty)
        else:                                        # pragma: no cover
            raise ValueError(op)
        alloc.check()
        # surviving holds read back their own marker — nobody scribbled
        for owner, pages_ in holds.items():
            for p, m in pages_:
                assert (pool[p] == m).all(), f"{owner}'s page {p} corrupted"
    alloc.check()


def test_allocator_reuse_requires_flush():
    run_allocator_case([
        ("alloc", "a", 5), ("alloc", "b", 5),
        ("release", "a"),
        ("alloc", "c", 5),          # free list short: MemoryError, no leak
        ("flush",),
        ("alloc", "c", 5),          # now succeeds, pages read back zero
        ("release", "b"), ("release", "c"), ("flush",),
        ("alloc", "d", 10),
    ])


def test_allocator_shared_pages_survive_one_owner():
    run_allocator_case([
        ("alloc", "a", 4),
        ("share", "b", "a"), ("share", "c", "a"),
        ("release", "a"), ("flush",),    # b and c still read their marker
        ("release", "b"), ("flush",),
        ("alloc", "d", 6),               # c's 4 pages must not be among d's
        ("release", "c"), ("flush",),
        ("alloc", "e", 10),
    ])


def test_allocator_rejects_bad_lifecycle_transitions():
    alloc = paging.PageAllocator(8, 4)
    pages = alloc.alloc(2)
    with pytest.raises(ValueError):
        alloc.retain([paging.ZERO_PAGE])        # reserved pages: no refcount
    dirty = alloc.release(pages)
    assert sorted(dirty) == sorted(pages)
    with pytest.raises(ValueError):
        alloc.retain(pages)                     # dirty pages are not live
    with pytest.raises(ValueError):
        alloc.mark_zeroed(pages)                # not taken yet
    assert sorted(alloc.take_dirty()) == sorted(pages)
    alloc.mark_zeroed(pages)
    alloc.check()


# ------------------------------------------------------- engine token parity
def _run_engine(paged, cell, prompts, gen, **kw):
    eng, lm = build_engine(ARCH, True, max_slots=2, max_seq=32,
                           paged=paged, **dict(cell, **kw))
    for p in prompts:
        eng.submit(p, gen)
    eng.warmup()
    return eng, eng.run()


CELLS = {
    "dense": {},
    "pruned_s50": dict(pruned=True, sparsity=0.5),
    "packed_b4": dict(packed=True, bits_init=4.0),
    "speculative": dict(speculative=True, draft_k=4),
}


@pytest.mark.parametrize("cell", sorted(CELLS), ids=sorted(CELLS))
def test_paged_engine_token_identical_to_contiguous(cell):
    """The arena swap changes where KV rows live, never what they hold:
    greedy tokens must match bit-for-bit in every serving cell."""
    _, lm = build_engine(ARCH, True, max_slots=2, max_seq=32, **CELLS[cell])
    prompts = synthetic_prompts(lm.cfg, [5, 9, 17, 3], seed=0)
    _, want = _run_engine(False, CELLS[cell], prompts, 8)
    eng, got = _run_engine(True, CELLS[cell], prompts, 8)
    assert sorted(got) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"{cell} request {rid}")
    assert eng.stats["evicted"] == len(prompts)


def test_prefix_sharing_skips_prefills_without_changing_tokens():
    """Duplicate prompts hit the whole-prompt prefix cache: the repeat
    admissions reuse the refcounted prompt pages and the memoized first
    token (no prefill dispatch at all), and still emit the exact token
    stream of a sharing-free engine."""
    _, lm = build_engine(ARCH, True, max_slots=2, max_seq=32)
    prompts = synthetic_prompts(lm.cfg, [9, 9, 9, 17], seed=0)
    prompts[1], prompts[2] = prompts[0].copy(), prompts[0].copy()
    ref, want = _run_engine(True, {}, prompts, 8, prefix_sharing=False)
    eng, got = _run_engine(True, {}, prompts, 8)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"request {rid}")
    assert ref.stats["prefills"] == 4 and ref.stats["prefix_hits"] == 0
    assert eng.stats["prefills"] == 2       # 9-token once, 17-token once
    assert eng.stats["prefix_hits"] == 2
    # a repeated one-token request is answered purely from the memo
    rid = eng.submit(prompts[0], 1)
    out = eng.run()
    assert out[rid][0] == want[0][0]
    assert eng.stats["prefills"] == 2 and eng.stats["prefix_hits"] == 3


def test_quantized_pages_shrink_the_pool_and_serve():
    """int8 pages halve (int4 quarter) the pool bytes of the f32 smoke
    arena; the serve still drains with full-length outputs (numerics are
    approximate by design, so no token-identity claim)."""
    _, lm = build_engine(ARCH, True, max_slots=2, max_seq=32)
    prompts = synthetic_prompts(lm.cfg, [5, 9], seed=0)
    fp, out_fp = _run_engine(True, {}, prompts, 6)
    q8, out_q8 = _run_engine(True, {}, prompts, 6, kv_bits=8)
    assert q8.kv_pool_bytes() < fp.kv_pool_bytes()
    assert all(len(out_q8[r]) == 6 for r in out_q8)
    # the first token comes from the (full-precision) prefill: identical
    for rid in out_fp:
        assert out_q8[rid][0] == out_fp[rid][0]


def test_drained_engine_leaves_unowned_pages_zero():
    """After a drain, every page not reserved and not held (by a slot or
    the prefix cache) must be bitwise zero in every pool — the
    allocator's zero-before-reuse contract, observed from the device."""
    _, lm = build_engine(ARCH, True, max_slots=2, max_seq=32)
    prompts = synthetic_prompts(lm.cfg, [5, 9, 17], seed=0)
    eng, _ = _run_engine(True, {}, prompts, 6, prefix_sharing=False)
    assert eng.alloc.n_live == 0            # sharing off: drain frees all
    unowned = [p for p in range(paging.N_RESERVED, eng.n_pages)
               if eng.alloc.refcount[p] == 0]
    assert unowned
    for key, leaf in eng.caches.items():
        if key.endswith(".k") or key.endswith(".v"):
            arr = np.asarray(leaf)
            assert not arr[:, unowned].any(), f"stale rows in {key}"
    # kv_bytes tracks allocation: an idle drained engine pins only the
    # reserved pages (plus table + state), far below the full pool
    assert eng.kv_bytes() < eng.kv_pool_bytes()

"""Kill-and-resume determinism + checkpoint validation tier.

A killed GETA run restored from its checkpoint must replay onto a
BITWISE-identical trajectory: the checkpoint carries the full state tree
(params, qparams, the whole QASSOState — base-optimizer moments, step
counter, partition masks — and the data-RNG key), restore preserves every
leaf dtype exactly (bf16 via the uint16 view, int counters untouched),
and the data pipeline is a pure function of (seed, step).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.distributed.fault import (DeviceLoss, FaultConfig,
                                     FaultTolerantLoop, is_device_loss)
from repro.launch.train import train_loop


def assert_tree_bitwise(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"tree structure differs: {ta} vs {tb}"
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, f"dtype drift: {x.dtype} vs {y.dtype}"
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------- kill-and-resume replay
def test_kill_and_resume_bitwise(tmp_path):
    """Train 10 steps; checkpoint at 5; die at 7; restore and replay.
    The final tree equals the uninterrupted run bit for bit."""
    kw = dict(smoke=True, steps=10, batch=2, seq=8, verbose=False)
    clean, _, _, _ = train_loop("internlm2-1.8b", **kw)
    faulty, _, _, losses = train_loop(
        "internlm2-1.8b", ckpt_dir=str(tmp_path), inject_failure_at=7,
        checkpoint_every=5, **kw)
    # steps 5 and 6 ran twice (once before the kill, once on replay)
    assert len(losses) == 12
    assert_tree_bitwise(clean, faulty)
    # ... and the state checkpointed at step 5 is still on disk, loadable
    assert latest_step(str(tmp_path)) in (5, 10)


def test_failure_before_first_checkpoint_restarts_fresh(tmp_path):
    """A failure with NO checkpoint on disk restarts from the INITIAL
    state (not the half-trained one): the loop counter, the QASSO stage
    schedule, the data stream and the checkpointed RNG key all re-sync at
    step 0, so the final tree still equals the uninterrupted run."""
    kw = dict(smoke=True, steps=8, batch=2, seq=8, verbose=False)
    clean, _, _, _ = train_loop("internlm2-1.8b", **kw)
    faulty, _, _, losses = train_loop(
        "internlm2-1.8b", ckpt_dir=str(tmp_path), inject_failure_at=3,
        checkpoint_every=5, **kw)
    # steps 0-2 ran, failure at 3 (pre-checkpoint), then a full 0-7 replay
    assert len(losses) == 11
    assert_tree_bitwise(clean, faulty)


def test_resume_covers_int_and_rng_leaves(tmp_path):
    """The saved tree includes the QASSO step counter (int32), the
    base-optimizer count and the fold_in data key (uint32) — all restored
    with their exact dtypes."""
    state, _, _, _ = train_loop(
        "internlm2-1.8b", smoke=True, steps=4, batch=2, seq=8,
        verbose=False, ckpt_dir=str(tmp_path), checkpoint_every=2)
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 4
    assert np.asarray(restored["qstate"].step).dtype == np.int32
    assert int(restored["qstate"].step) == 4
    assert np.asarray(restored["rng"]).dtype == np.uint32
    assert_tree_bitwise(state, restored)


# ------------------------------------------------- restore validation
def test_restore_preserves_dtypes_roundtrip(tmp_path):
    tree = {
        "f32": jnp.arange(6.0).reshape(2, 3),
        "bf16": (jnp.ones((5,), jnp.bfloat16) * 1.5),
        "i32": jnp.arange(4, dtype=jnp.int32),
        "u32": jnp.asarray([1, 2**31], jnp.uint32),
        "i8": jnp.asarray([-3, 7], jnp.int8),
    }
    save_checkpoint(str(tmp_path), 1, tree)
    restored, _ = restore_checkpoint(str(tmp_path), tree)
    assert_tree_bitwise(tree, restored)


def test_restore_preserves_dtypes_with_shardings(tmp_path):
    """The sharded-restore path must not cast leaves to the example's
    dtype (the old behaviour silently converted bf16/int leaves)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.ones((4, 4), jnp.bfloat16),
            "n": jnp.int32(7)}
    save_checkpoint(str(tmp_path), 1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None)), "n": None}
    # example deliberately carries the WRONG dtypes: saved dtypes win
    example = {"w": jnp.ones((4, 4), jnp.float32), "n": jnp.float32(0)}
    restored, _ = restore_checkpoint(str(tmp_path), example, shardings=sh)
    assert restored["w"].dtype == jnp.bfloat16
    assert np.asarray(restored["n"]).dtype == np.int32
    assert restored["w"].sharding == sh["w"]


def test_restore_rejects_structure_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 3, {"a": jnp.zeros(2), "b": jnp.ones(3)})
    with pytest.raises(ValueError, match="structure"):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2),
                                           "renamed": jnp.ones(3)})
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)})


def test_restore_rejects_missing_step(tmp_path):
    save_checkpoint(str(tmp_path), 3, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError, match="no checkpoint for step"):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)}, step=99)


# ------------------------------------------------- device-loss fault path
def test_device_loss_triggers_restore_not_crash():
    """A simulated device loss mid-step restores from the checkpoint and
    replays to the same final state as an uninterrupted run."""

    def make_run(fail_at):
        def step_fn(state, i):
            if fail_at is not None and i == fail_at[0]:
                fail_at[0] = None
                raise DeviceLoss("DATA_LOSS: device 2 dropped out of mesh")
            state = state + float(i) * 0.5
            return state

        store = {}
        loop = FaultTolerantLoop(
            FaultConfig(checkpoint_every=3), step_fn,
            lambda s, i: store.__setitem__("ckpt", (s, i)),
            lambda: store.get("ckpt"))
        return loop.run(0.0, 10)

    clean, r0 = make_run(None)
    recovered, r1 = make_run([7])
    assert r0.device_losses == 0
    assert r1.device_losses == 1
    assert r1.restarts == 1
    assert clean == pytest.approx(recovered)


def test_is_device_loss_classification():
    assert is_device_loss(DeviceLoss("gone"))
    assert is_device_loss(RuntimeError("DATA_LOSS: while running replica"))
    assert is_device_loss(RuntimeError("NCCL communicator aborted"))
    assert not is_device_loss(ValueError("shape mismatch"))
    assert not is_device_loss(RuntimeError("nan loss"))


def test_fault_loop_counts_generic_failures_separately():
    """A plain bug still restarts, but is not recorded as a device loss."""

    fail = [2]

    def step_fn(state, i):
        if fail and i == fail[0]:
            fail.pop()
            raise RuntimeError("injected software bug")
        return state + 1

    loop = FaultTolerantLoop(
        FaultConfig(checkpoint_every=100), step_fn,
        lambda s, i: None, lambda: None)
    state, result = loop.run(0, 5)
    assert result.restarts == 1
    assert result.device_losses == 0

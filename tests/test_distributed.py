"""Sharding rules, gradient compression, BOPs accounting, saliency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.bops import LayerMacs, model_bops
from repro.core.qadg import build_qadg
from repro.core.saliency import SaliencyConfig, global_redundancy_partition
from repro.distributed.collectives import (_dequantize_blockwise,
                                           _quantize_blockwise)
from repro.distributed.sharding import batch_spec, make_plan
from repro.launch.mesh import abstract_mesh, make_mesh
from repro.models.cnn import CNN, VGG7


def _mesh():
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def test_sharding_plan_divisibility_fallback():
    mesh = _mesh()
    plan = make_plan(mesh)
    # model axis is size 1 here: every spec must be valid (no exceptions)
    spec = plan.spec_for("w", ("embed", "mlp"), (64, 128))
    assert isinstance(spec, P)


def test_sharding_plan_records_fallbacks():
    # fake a mesh-like object with a model axis of 16 via abstract mesh
    mesh = abstract_mesh((16, 16), ("data", "model"))
    plan = make_plan(mesh)
    spec = plan.spec_for("w", ("embed", "kv_heads"), (64, 24))
    # 24 % 16 != 0 -> fallback recorded, axis replicated
    assert spec == P(None, None)
    assert any(a == "kv_heads" for _, a, _ in plan.fallbacks)


def test_fsdp_rules():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    plan = make_plan(mesh, fsdp=True)
    spec = plan.spec_for("w", ("embed", "mlp"), (8192, 32768))
    assert spec == P(("pod", "data"), "model")


def test_arch_overrides_respected():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    plan = make_plan(mesh, overrides={"fsdp": True, "experts_axis": None,
                                      "expert_mlp_axis": "model",
                                      "base_optimizer": "momentum"})
    spec = plan.spec_for("we", ("experts", "embed", "expert_mlp"),
                         (8, 6144, 32768))
    assert spec == P(None, "data", "model")


def test_batch_spec_sp():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert batch_spec(mesh) == P(("pod", "data"))
    assert batch_spec(mesh, shard_seq=True) == P(None, ("pod", "data"))


# ------------------------------------------------------ grad compression
def test_blockwise_quantization_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 3
    codes, scale = _quantize_blockwise(x)
    xr = _dequantize_blockwise(codes, scale)[: x.size]
    # int8 with per-block max scaling: error <= scale/2 per element
    err = np.abs(np.asarray(x) - xr)
    bound = np.repeat(np.asarray(scale)[:, 0], 256)[: x.size] * 0.5 + 1e-7
    assert np.all(err <= bound)


def test_compressed_psum_semantics():
    """compressed all-reduce ~= psum within int8 quantization error."""
    from repro.distributed.collectives import compressed_psum, shard_map
    n = len(jax.devices())
    mesh = make_mesh((n,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 512))

    def f(xs):
        return compressed_psum(xs[0], "data")

    out = shard_map(f, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
                    check_vma=False)(x)
    expect = jnp.sum(x, axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-2)


def test_error_feedback_accumulates():
    from repro.distributed.collectives import compressed_grad_allreduce
    n = len(jax.devices())
    mesh = make_mesh((n,), ("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (300,)) * 1e-3}
    mean, ef = compressed_grad_allreduce(g, mesh, axis_names=("data",))
    # sent + residual == original (error feedback identity)
    sent = g["w"] - ef["w"]
    np.testing.assert_allclose(np.asarray(sent + ef["w"]),
                               np.asarray(g["w"]), rtol=1e-6)


# -------------------------------------------------------------- BOPs
def test_bops_reduction_from_pruning_and_quant():
    m = CNN(VGG7)
    params = m.init(jax.random.PRNGKey(0))
    qadg = build_qadg(m.build_graph().graph)
    qparams = m.init_qparams(params, bits_init=32.0)
    macs = m.layer_macs(batch=1)

    full = model_bops(qadg, params, qparams, macs)
    assert full["rel_bops"] == pytest.approx(1.0, rel=1e-6)

    q8 = m.init_qparams(params, bits_init=8.0)
    quantized = model_bops(qadg, params, q8, macs)
    assert quantized["rel_bops"] == pytest.approx(0.25, rel=1e-2)

    masks = qadg.space.init_masks()
    masks = {k: v.at[: len(v) // 2].set(0.0) for k, v in masks.items()}
    pruned = model_bops(qadg, params, q8, macs, masks=masks)
    assert pruned["rel_bops"] < quantized["rel_bops"] * 0.6


# ---------------------------------------------------------- saliency
def test_partition_sizes_exact():
    m = CNN(VGG7)
    params = m.init(jax.random.PRNGKey(0))
    qadg = build_qadg(m.build_graph().graph)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape), params)
    for n_red in (0, 7, 100):
        part = global_redundancy_partition(qadg.space, params, grads,
                                           jnp.int32(n_red))
        total = sum(int(jnp.sum(v)) for v in part.values())
        assert total == n_red


def test_partition_pinned_sticky():
    m = CNN(VGG7)
    params = m.init(jax.random.PRNGKey(0))
    qadg = build_qadg(m.build_graph().graph)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    p1 = global_redundancy_partition(qadg.space, params, grads,
                                     jnp.int32(10))
    p2 = global_redundancy_partition(qadg.space, params, grads,
                                     jnp.int32(20), pinned=p1)
    for k in p1:
        # every previously-redundant unit remains redundant
        assert np.all(np.asarray(p2[k]) >= np.asarray(p1[k]))
    assert sum(int(jnp.sum(v)) for v in p2.values()) == 20

"""Sub-byte packed serving: pack/unpack exactness, the unpack-dequant GEMM
epilogue, and packed-vs-unpacked decode parity.

The contract: `unpack_codes(pack_codes(c, b), b) == c` exactly for every
storage width, so a packed decode runs the *same* dequantized weights as
the unpacked int8 path — logits agree to float tolerance and greedy
tokens bit-for-bit, while the packed containers occupy `b/8` of the int8
bytes (the ISSUE's ≤0.55x-at-4-bit acceptance). Also pins the satellite
fixes: `quantize_int` boundary clamping, the `compression_report`
sparsity-0.0 line, and `mean_storage_bits`.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import quant as Q
from repro.core.subnet import (compress_lm, compression_report,
                               prepare_serving, residual_qparams,
                               servable_params, tree_bytes)
from repro.kernels import gemm_core, ops
from repro.kernels.ref import packed_quant_matmul_ref, quant_matmul_ref
from repro.models.transformer import LM

BACKENDS = ("xla-ref", "pallas-interpret")


# ------------------------------------------------------------ pack/unpack
@pytest.mark.parametrize("bits", list(range(2, 9)))
def test_pack_unpack_roundtrip_exact(bits):
    """Round-trip is exact for every width 2-8, negative codes included,
    at word-aligned and non-aligned lengths, 1-D through stacked 3-D."""
    hi = 2 ** (bits - 1) - 1
    rng = np.random.RandomState(bits)
    for n in (1, 5, 31, 32, 33, 160):
        c = rng.randint(-hi, hi + 1, size=(n,)).astype(np.int32)
        u = np.asarray(Q.unpack_codes(Q.pack_codes(jnp.asarray(c), bits),
                                      bits, n))
        np.testing.assert_array_equal(u, c)
    # K-packed 2-D and scan-stacked 3-D (the weight layouts serving uses)
    for shape in ((13, 5), (3, 11, 4)):
        c = rng.randint(-hi, hi + 1, size=shape).astype(np.int32)
        p = Q.pack_codes(jnp.asarray(c), bits, axis=-2)
        assert p.dtype == jnp.int32
        cpw = 32 // bits
        assert p.shape[-2] == -(-shape[-2] // cpw)   # ceil(K / cpw) words
        u = np.asarray(Q.unpack_codes(p, bits, shape[-2], axis=-2))
        np.testing.assert_array_equal(u, c)


def test_pack_codes_extreme_values_sign_extend():
    """The full symmetric range ±(2^(b-1)-1) survives packing — the sign
    bit of every field must extend, not zero-fill."""
    for bits in (2, 3, 4, 8):
        hi = 2 ** (bits - 1) - 1
        c = jnp.asarray([-hi, -1, 0, 1, hi], jnp.int32)
        u = np.asarray(Q.unpack_codes(Q.pack_codes(c, bits), bits, 5))
        np.testing.assert_array_equal(u, np.asarray(c))


def test_packed_storage_bits_rounding():
    assert Q.packed_storage_bits(1.7) == 2
    assert Q.packed_storage_bits(2.0) == 2
    assert Q.packed_storage_bits(2.3) == 3
    assert Q.packed_storage_bits(4.0) == 4
    assert Q.packed_storage_bits(4.8) == 8
    assert Q.packed_storage_bits(8.0) == 8
    assert Q.packed_storage_bits(8.2) is None   # needs int16, unpacked


# --------------------------------------------------------- GEMM epilogue
@pytest.mark.parametrize("mkn", [(1, 1, 1), (3, 193, 17), (29, 31, 37),
                                 (130, 257, 131)],
                         ids=lambda s: f"{s[0]}x{s[1]}x{s[2]}")
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_unpack_dequant_backend_parity(bits, mkn):
    """Packed GEMM == unpacked dequant oracle on both backends, over
    ragged shapes (incl. bits=3, whose 10-codes-per-word stream forces
    the non-default bk=120 block)."""
    m, k, n = mkn
    hi = 2 ** (bits - 1) - 1
    rng = np.random.RandomState(bits * 1009 + k * 11 + n)
    codes = rng.randint(-hi, hi + 1, size=(k, n)).astype(np.int8)
    scale = ((rng.rand(n) + 0.5) * (2.0 / max(hi, 1))).astype(np.float32)
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    packed = Q.pack_codes(jnp.asarray(codes), bits, axis=0)
    want = quant_matmul_ref(x, jnp.asarray(codes), jnp.asarray(scale))
    ref = packed_quant_matmul_ref(x, packed, bits, jnp.asarray(scale))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    for backend in BACKENDS:
        got = ops.packed_quant_matmul_op(x, packed, bits,
                                         jnp.asarray(scale), backend=backend)
        assert got.shape == (m, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_unpack_dequant_composes_with_col_mask():
    """Epilogue order: unpack-dequant decodes the raw word tile first,
    later COL ops see the dense f32 tile (DESIGN.md §4.8)."""
    rng = np.random.RandomState(0)
    codes = rng.randint(-7, 8, size=(31, 37)).astype(np.int8)
    scale = np.full((37,), 0.1, np.float32)
    mask = (rng.rand(37) > 0.4).astype(np.float32)
    x = jnp.asarray(rng.randn(5, 31).astype(np.float32))
    packed = Q.pack_codes(jnp.asarray(codes), 4, axis=0)
    rhs_ops = (gemm_core.unpack_dequant(4, jnp.asarray(scale)),
               gemm_core.col_mask(jnp.asarray(mask)))
    want = quant_matmul_ref(x, jnp.asarray(codes),
                            jnp.asarray(scale * mask))
    for backend in BACKENDS:
        got = gemm_core.gemm(x, packed, rhs_ops, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ LM serving
def _f32_lm(arch="internlm2-1.8b", bits_init=8.0):
    cfg = get_arch(arch, smoke=True)
    if cfg.dtype != "float32":        # tight parity needs f32 weights
        cfg = dataclasses.replace(cfg, dtype="float32")
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    qparams = lm.init_qparams(params, bits_init=bits_init)
    return lm, params, qparams


def _decode(lm, params, qparams, steps=4, batch=2):
    caches = lm.init_cache(batch, 16, dtype=jnp.float32)
    tok = jnp.zeros((batch, 1), jnp.int32)
    outs = []
    step = jax.jit(lm.decode_step)
    for p in range(steps):
        logits, caches = step(params, qparams, caches, tok, jnp.int32(p))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs.append(logits)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-3b"])
@pytest.mark.parametrize("bits_init", [8.0, 4.0], ids=["b8", "b4"])
def test_packed_decode_matches_unpacked(arch, bits_init):
    """Packed and unpacked compressed decodes share codes and scales
    bit-for-bit, so logits agree (≤1e-4) and greedy tokens are identical
    — attn/MLP projections on the transformer, the rwkv time/channel-mix
    family on the SSM arch."""
    lm, params, qparams = _f32_lm(arch, bits_init=bits_init)
    plain = compress_lm(lm, params, qparams)
    packed = compress_lm(lm, params, qparams, packed=True)
    assert packed.packed_bits
    for name, sb in packed.packed_bits.items():
        assert sb == int(np.ceil(packed.bits[name + ".wq"]))
        assert packed.int_weights[name].dtype == jnp.int32
    rq = residual_qparams(packed, qparams)
    want = _decode(lm, servable_params(plain),
                   residual_qparams(plain, qparams))
    got = _decode(lm, servable_params(packed), rq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.argmax(np.asarray(got), -1),
                          np.argmax(np.asarray(want), -1))


def test_packed_pruned_stacking_parity():
    """Sliced + packed — the full GETA deployment artifact: the packed
    decode on physically pruned (stacked per-period) shapes matches the
    unpacked pruned decode, and the served bytes shrink twice over."""
    lm_a = LM(_f32_lm()[0].cfg)
    lm_b = LM(lm_a.cfg)
    params_a, _ = lm_a.init(jax.random.PRNGKey(0))
    params_b, _ = lm_b.init(jax.random.PRNGKey(0))
    p_plain, q_plain, meta_plain = prepare_serving(
        lm_a, params_a, compressed=True, prune_sparsity=0.5)
    p_packed, q_packed, meta_packed = prepare_serving(
        lm_b, params_b, packed=True, prune_sparsity=0.5)
    assert meta_packed["sparsity"] == meta_plain["sparsity"] > 0.2
    assert meta_packed["param_bytes"] <= meta_plain["param_bytes"]
    want = _decode(lm_a, p_plain, q_plain)
    got = _decode(lm_b, p_packed, q_packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.argmax(np.asarray(got), -1),
                          np.argmax(np.asarray(want), -1))


def test_servable_params_packed_keys():
    """Packed sites ride the dict as `<name>.packed{bits}` (static width
    in the key), never alongside a `.codes` or dense copy."""
    lm, params, qparams = _f32_lm()
    subnet = compress_lm(lm, params, qparams, packed=True)
    sp = servable_params(subnet)
    assert subnet.packed_bits
    for name, sb in subnet.packed_bits.items():
        assert f"{name}.packed{sb}" in sp
        assert name + ".codes" not in sp
        assert name not in sp
        assert name + ".scale" in sp
        w = sp[f"{name}.packed{sb}"]
        if w.ndim >= 3:   # stacked site: scale broadcast over the stack
            assert sp[name + ".scale"].shape[0] == w.shape[0]
    assert subnet.meta["packed_sites"] == subnet.packed_bits


def test_packed_bytes_ratio_at_4_bits():
    """Acceptance: a mean-4-bit subnet's packed containers occupy ≤0.55x
    the unpacked int8 container bytes (4-bit packs 8 codes per int32 word
    — exactly 0.5x plus partial-word padding), and the served param dict
    shrinks accordingly."""
    lm, params, qparams = _f32_lm(bits_init=4.0)
    subnet = compress_lm(lm, params, qparams, packed=True)
    m = subnet.meta
    assert m["mean_bits"] == pytest.approx(4.0, abs=1e-3)
    assert m["weight_bytes_compressed"] <= 0.55 * m["weight_bytes_unpacked"]
    plain = compress_lm(lm, params, qparams)
    assert (tree_bytes(servable_params(subnet))
            < tree_bytes(servable_params(plain)))


# ---------------------------------------------------------- satellite fixes
def test_quantize_int_boundary_clamp():
    """Regression: at the bit-constraint boundary `round(xt/d)` can land
    on 2^(b-1) (128 at 8 bits), which wrapped negative in the int8
    container before the clamp. With the container width pinned at 8 bits
    (the layerwise constraint), codes must clamp to ±127."""
    qp = Q.QuantParams(d=jnp.float32(1.0 / 127.6), q_m=jnp.float32(1.0),
                       t=jnp.float32(1.0))
    x = jnp.asarray([1.0, -1.0, 0.5, 0.0])
    codes, _ = Q.quantize_int(x, qp, bits=8.0)
    as_i8 = np.asarray(codes.astype(jnp.int8))
    np.testing.assert_array_equal(as_i8, [127, -127, 64, 0])
    # the derived-width default clamps too: codes always fit the ceil
    # container quantize_int itself would pick
    codes_d, _ = Q.quantize_int(x, qp)
    b = int(np.ceil(float(Q.bit_width(qp.d, qp.q_m, qp.t))))
    assert np.max(np.abs(np.asarray(codes_d))) <= 2 ** (b - 1) - 1


def test_compression_report_explicit_zero_sparsity():
    """`--pruned --sparsity 0` ran the pruning path and must say so: the
    report keys on `is not None`, not truthiness."""
    rep = compression_report("arch", {"sparsity": 0.0})
    assert "pruned to sparsity 0.00" in rep
    # a compress-only meta carries no sparsity claim at all
    lm, params, qparams = _f32_lm()
    subnet = compress_lm(lm, params, qparams)
    assert "sparsity" not in subnet.meta
    assert "pruned" not in compression_report("arch", subnet.meta)


def test_pruned_zero_sparsity_report_via_prepare_serving():
    """End to end: an all-keep pruning run still reports its (0.00)
    sparsity line next to realized bytes."""
    lm, params, _ = _f32_lm()
    _, _, meta = prepare_serving(LM(lm.cfg), dict(params), compressed=True,
                                 prune_sparsity=0.0)
    assert meta["sparsity"] == 0.0
    assert "pruned to sparsity 0.00" in compression_report("arch", meta)


def test_mean_storage_bits_reported():
    """Satellite: the meta pairs float `mean_bits` with the integer-ceil
    `mean_storage_bits` the containers are sized from, so the report's
    bits and bytes figures agree."""
    lm, params, qparams = _f32_lm()
    for packed in (False, True):
        subnet = compress_lm(lm, params, qparams, packed=packed)
        m = subnet.meta
        assert m["mean_storage_bits"] == pytest.approx(float(np.mean(
            [np.ceil(b) for b in subnet.bits.values()])))
        assert m["mean_storage_bits"] >= m["mean_bits"] - 1e-6
        assert m["mean_storage_bits"] == float(int(m["mean_storage_bits"])) \
            or len({int(np.ceil(b)) for b in subnet.bits.values()}) > 1
    assert "storage" in compression_report("arch", subnet.meta)

"""Step-scheduler policy + disaggregated chunked-prefill tier.

Pins three contracts from the PR-9 refactor:

1. **Policy extraction is behavior-preserving** — `Engine.step()` under
   the default `OneShotScheduler` is the classic admit-then-decode
   iteration (every pre-existing engine test keeps passing); a custom
   policy object can reshape the iteration without engine changes.
2. **Chunked prefill is token-identical to one-shot prefill** — staging
   through `LM.verify_chunk` at absolute positions writes the same KV
   rows the one-shot prefill writes, so greedy decode must not move by
   a single token, across plain/paged/speculative/TP stacks.
3. **Disaggregation actually disaggregates** — decode steps run while a
   prompt is mid-prefill (`decode_steps_mid_prefill`, asserted under a
   fake deterministic clock so the timing stats are exact), and the
   compiled-shape set stays pinned to `chunk_buckets(chunk)`.
"""
import numpy as np
import pytest

from repro.launch.engine import Engine, build_engine, engine_serve
from repro.launch.scheduler import (ChunkedPrefillScheduler,
                                    OneShotScheduler, chunk_buckets,
                                    chunk_plan)

ARCH = "internlm2-1.8b"


# ------------------------------------------------------------ chunk maths
def test_chunk_plan_sums_and_shapes():
    assert chunk_plan(21, 16) == [16, 4, 1]
    assert chunk_plan(16, 16) == [16]
    assert chunk_plan(5, 16) == [4, 1]
    assert chunk_plan(40, 8) == [8, 8, 8, 8, 8]
    assert chunk_plan(1, 16) == [1]
    for s in range(1, 70):
        for c in (1, 3, 8, 16):
            plan = chunk_plan(s, c)
            assert sum(plan) == s
            assert all(x in chunk_buckets(c) for x in plan), (s, c, plan)


def test_chunk_plan_validation():
    with pytest.raises(ValueError):
        chunk_plan(0, 16)
    with pytest.raises(ValueError):
        chunk_plan(8, 0)


def test_chunk_buckets():
    assert chunk_buckets(16) == [1, 2, 4, 8, 16]
    assert chunk_buckets(12) == [1, 2, 4, 8, 12]
    assert chunk_buckets(1) == [1]


def test_chunked_scheduler_validation():
    with pytest.raises(ValueError):
        ChunkedPrefillScheduler(chunk=0)


# --------------------------------------------------------- token identity
@pytest.mark.parametrize("kw", [
    pytest.param({}, id="plain"),
    pytest.param(dict(packed=True, bits_init=4.0), id="packed_b4"),
    pytest.param(dict(paged=True, page_size=8), id="paged"),
    pytest.param(dict(speculative=True, draft_k=4), id="speculative"),
])
def test_chunked_prefill_token_identity(kw):
    base = engine_serve(ARCH, True, [12, 5, 21], 8, verbose=False, **kw)
    got = engine_serve(ARCH, True, [12, 5, 21], 8, verbose=False,
                       prefill_chunk=8, **kw)
    assert sorted(base) == sorted(got)
    for rid in base:
        np.testing.assert_array_equal(base[rid], got[rid])


def test_chunked_prefill_chunk_one_token_identity():
    # chunk=1 degenerates to sequential per-token prefill — the maximally
    # adversarial chunk plan — and must still match one-shot exactly
    base = engine_serve(ARCH, True, [9, 4], 6, verbose=False)
    got = engine_serve(ARCH, True, [9, 4], 6, verbose=False,
                       prefill_chunk=1)
    for rid in base:
        np.testing.assert_array_equal(base[rid], got[rid])


# ------------------------------------------------------- disaggregation
class _FakeTime:
    """Deterministic clock: every time() call advances 1 ms. Makes the
    wall-time stats exact integers of the call count instead of host
    noise, so the interleaving assertions can't flake."""
    def __init__(self):
        self.t = 0.0

    def time(self):
        self.t += 0.001
        return self.t


def test_decode_runs_mid_prefill(monkeypatch):
    import repro.launch.engine as engine_mod
    monkeypatch.setattr(engine_mod, "time", _FakeTime())
    from repro.launch.engine import synthetic_prompts
    eng, lm = build_engine(ARCH, True, max_seq=64, prefill_chunk=4)
    prompts = synthetic_prompts(lm.cfg, [4, 33], seed=0)
    eng.submit(prompts[0], 20)    # short prompt: decoding early
    eng.submit(prompts[1], 8)     # long prompt: 9 chunks of prefill
    eng.warmup()
    out = eng.run()
    assert len(out) == 2
    # the long prompt needed ceil(33/4)=9 chunk dispatches, and request 0
    # decoded while they ran: disaggregation's whole point
    assert eng.stats["prefill_chunks"] >= 9
    assert eng.stats["decode_steps_mid_prefill"] >= 8
    assert eng.stats["chunked_prefills"] == 2
    assert eng.stats["prefills"] == 2
    # fake clock: every timed section advanced exactly 1 ms per
    # time()-pair, so the stats are pure call counts — nonzero and exact
    assert eng.stats["prefill_s"] == pytest.approx(
        0.001 * eng.stats["prefill_chunks"])
    assert eng.stats["decode_s"] == pytest.approx(
        0.001 * eng.stats["decode_steps"])


def test_oneshot_never_decodes_mid_prefill():
    st = {}
    engine_serve(ARCH, True, [12, 5, 21], 8, verbose=False, stats=st)
    assert st["decode_steps_mid_prefill"] == 0
    assert st["prefill_chunks"] == 0
    assert st["chunked_prefills"] == 0


# ----------------------------------------------------- compile-set pinning
def test_chunked_warmup_compile_set_pinned():
    from repro.launch.engine import synthetic_prompts
    eng, lm = build_engine(ARCH, True, max_seq=64, prefill_chunk=8)
    prompts = synthetic_prompts(lm.cfg, [21, 5, 12, 33], seed=0)
    for p in prompts:
        eng.submit(p, 8)
    eng.warmup()
    sizes = eng.compile_cache_sizes()
    assert sizes["_prefill_chunk"] == len(chunk_buckets(8))
    assert sizes["_decode"] == 1
    eng.run()
    # the serve dispatched only warmed shapes: zero recompiles
    after = eng.compile_cache_sizes()
    assert after["_prefill_chunk"] == len(chunk_buckets(8))
    assert after["_decode"] == 1


# --------------------------------------------------------- policy object
def test_default_scheduler_is_oneshot():
    eng, _ = build_engine(ARCH, True)
    assert isinstance(eng.scheduler, OneShotScheduler)
    assert eng.scheduler.plan_step(eng) == ("admit", "decode")
    assert eng._chunk is None


class _DecodeTwice:
    """A custom policy: two decode batches per step. Exists to prove the
    engine executes whatever the policy plans — the extension point the
    refactor bought."""
    chunk = None

    def plan_step(self, eng):
        return ("admit", "decode", "decode")


def test_custom_scheduler_drives_engine():
    from repro.launch.engine import synthetic_prompts
    eng, lm = build_engine(ARCH, True)
    eng.scheduler = _DecodeTwice()
    for p in synthetic_prompts(lm.cfg, [6, 6], seed=0):
        eng.submit(p, 9)
    while eng.pending:
        eng.step()
    assert len(eng.done) == 2
    # two decode batches ran per step(): steps counted them both
    assert eng.stats["decode_steps"] >= 8
    ref = engine_serve(ARCH, True, [6, 6], 9, verbose=False)
    for rid, req_tokens in ((r, eng.done[r].tokens) for r in eng.done):
        np.testing.assert_array_equal(np.asarray(req_tokens, np.int32),
                                      ref[rid])


# ------------------------------------------------------------ gating rails
def test_window_refuses_chunked_engine():
    eng, _ = build_engine(ARCH, True, prefill_chunk=4)
    with pytest.raises(RuntimeError, match="chunked"):
        eng._window()


def test_chunked_refuses_windowed_and_stateful_archs():
    """Chunked prefill stages through verify_chunk, which inherits its
    preconditions: full arenas and attention mixers everywhere. The
    engine must refuse at construction, not corrupt mid-serve."""
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.models.transformer import LM
    sched = ChunkedPrefillScheduler(chunk=4)

    cfg = get_arch(ARCH, smoke=True)
    wlm = LM(dataclasses.replace(cfg, window=8))
    wparams, _ = wlm.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="window"):
        Engine(wlm, wparams, None, max_seq=16, scheduler=sched)

    rcfg = get_arch("rwkv6-3b", smoke=True)
    rlm = LM(rcfg)
    rparams, _ = rlm.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention mixers"):
        Engine(rlm, rparams, None, max_seq=16, scheduler=sched)


def test_pending_tracks_staging(monkeypatch):
    eng, lm = build_engine(ARCH, True, prefill_chunk=4)
    assert not eng.pending
    from repro.launch.engine import synthetic_prompts
    eng.submit(synthetic_prompts(lm.cfg, [9], seed=0)[0], 4)
    assert eng.pending
    eng.step()           # chunk 1 of [4, 4, 1] staged, queue empty
    assert not eng.queue and eng._prefill_job is not None
    assert eng.pending   # mid-prefill work must keep run() draining
    while eng.pending:
        eng.step()
    assert len(eng.done) == 1

"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import (fake_quant_bwd_ref, fake_quant_fwd_ref,
                               masked_matmul_ref, quant_matmul_ref)

SHAPES = [(8, 128), (57, 200), (256, 512), (1, 384), (130, 129)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fake_quant_fwd_sweep(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 2).astype(dtype)
    d, qm, t = jnp.float32(0.05), jnp.float32(1.4), jnp.float32(0.85)
    y = ops.fake_quant_op(x, d, qm, t, True)
    yr = fake_quant_fwd_ref(x, d, qm, t)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(64, 256), (33, 140)])
def test_fake_quant_bwd_sweep(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape) * 1.5
    g = jax.random.normal(jax.random.PRNGKey(2), shape)
    d, qm, t = jnp.float32(0.08), jnp.float32(1.1), jnp.float32(1.0)

    def loss(x, d, qm, t):
        return jnp.sum(ops.fake_quant_op(x, d, qm, t, True) * g)

    dx, dd, dqm, dt = jax.grad(loss, argnums=(0, 1, 2, 3))(x, d, qm, t)
    rdx, rdd, rdqm, rdt = fake_quant_bwd_ref(x, d, qm, t, g)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rdx), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(float(dd), float(rdd), rtol=1e-3)
    np.testing.assert_allclose(float(dqm), float(rdqm), rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(float(dt), float(rdt), rtol=1e-3)


MM_SHAPES = [(16, 128, 128), (64, 256, 384), (100, 130, 200), (8, 512, 64)]


@pytest.mark.parametrize("mnk", MM_SHAPES)
def test_masked_matmul_sweep(mnk):
    m, k, n = mnk
    x = jax.random.normal(jax.random.PRNGKey(3), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(4), (k, n))
    mask = (jax.random.uniform(jax.random.PRNGKey(5), (n,)) > 0.4).astype(
        jnp.float32)
    y = ops.masked_matmul_op(x, w, mask, interpret=True)
    yr = masked_matmul_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    # pruned columns are exactly zero
    zero_cols = np.nonzero(np.asarray(mask) < 0.5)[0]
    assert np.all(np.asarray(y)[:, zero_cols] == 0.0)


@pytest.mark.parametrize("mnk", MM_SHAPES)
@pytest.mark.parametrize("code_dtype", [jnp.int8, jnp.int32])
def test_quant_matmul_sweep(mnk, code_dtype):
    m, k, n = mnk
    x = jax.random.normal(jax.random.PRNGKey(6), (m, k))
    codes = jax.random.randint(jax.random.PRNGKey(7), (k, n), -127,
                               127).astype(code_dtype)
    scale = jax.random.uniform(jax.random.PRNGKey(8), (n,)) * 0.05
    y = ops.quant_matmul_op(x, codes, scale, interpret=True)
    yr = quant_matmul_ref(x, codes, scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)


def test_fake_quant_bf16_threedim():
    """Leading dims folded correctly."""
    x = (jax.random.normal(jax.random.PRNGKey(9), (4, 33, 257))).astype(
        jnp.bfloat16)
    d, qm, t = jnp.float32(0.1), jnp.float32(2.0), jnp.float32(1.0)
    y = ops.fake_quant_op(x, d, qm, t, True)
    yr = fake_quant_fwd_ref(x, d, qm, t)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=2e-2,
                               atol=2e-2)

"""Serving path: Subnet int-code generation -> compressed decode parity.

The compressed decode executes `x @ (codes * scale)` through the
quant-dequant GEMM epilogue; the dense QAT decode executes
`x @ fake_quant(w)`. By Eqs (1)-(2) these are the *same* effective weight
(codes = round(clip^t(|w|)/d) * sgn(w), x_Q = codes * d), so on an f32
config the two decode paths must agree to numerical tolerance — the test
that the deployment path runs the math the training path learned."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.qadg import build_qadg
from repro.core.quant import fake_quant
from repro.core.subnet import (compress_lm, construct_subnet,
                               residual_qparams, servable_params)
from repro.models.transformer import LM


def _smoke_lm(arch="internlm2-1.8b"):
    import dataclasses
    cfg = get_arch(arch, smoke=True)   # 2 layers, d=128
    if cfg.dtype != "float32":         # tight parity needs f32 weights
        cfg = dataclasses.replace(cfg, dtype="float32")
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    qparams = lm.init_qparams(params, bits_init=8.0)
    return lm, params, qparams


def _decode(lm, params, qparams, steps=4, batch=2):
    caches = lm.init_cache(batch, 16, dtype=jnp.float32)
    tok = jnp.zeros((batch, 1), jnp.int32)
    outs = []
    step = jax.jit(lm.decode_step)
    for p in range(steps):
        logits, caches = step(params, qparams, caches, tok, jnp.int32(p))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs.append(logits)
    return jnp.concatenate(outs, axis=1)


def test_compress_lm_decode_parity():
    lm, params, qparams = _smoke_lm()
    dense_logits = _decode(lm, params, qparams)

    subnet = compress_lm(lm, params, qparams)
    assert subnet.int_weights, "no sites compressed"
    for name, codes in subnet.int_weights.items():
        assert codes.dtype == jnp.int8, (name, codes.dtype)  # 8-bit init
        assert name not in subnet.params
    comp_logits = _decode(lm, servable_params(subnet),
                          residual_qparams(subnet, qparams))

    np.testing.assert_allclose(np.asarray(comp_logits),
                               np.asarray(dense_logits), rtol=2e-4, atol=2e-4)
    # greedy decode chooses identical tokens
    assert np.array_equal(np.argmax(np.asarray(comp_logits), -1),
                          np.argmax(np.asarray(dense_logits), -1))


def test_compress_lm_codes_match_fake_quant():
    """codes * scale reconstructs exactly the fake-quant effective weight."""
    lm, params, qparams = _smoke_lm()
    subnet = compress_lm(lm, params, qparams)
    for name, codes in subnet.int_weights.items():
        qp = qparams[name + ".wq"]
        wq = np.asarray(fake_quant(params[name], qp.d, qp.q_m, qp.t))
        scale = np.reshape(np.asarray(subnet.scales[name], np.float32),
                           (-1,) + (1,) * (codes.ndim - 1))
        rebuilt = np.asarray(codes, np.float32) * scale
        np.testing.assert_allclose(rebuilt, wq, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "grok-1-314b"])
def test_construct_subnet_decode_parity(arch):
    """Full pipeline: QADG -> keep-all construct_subnet -> servable decode
    matches the dense fake-quant decode within quantization tolerance.

    grok covers the MoE case: construct_subnet quantizes the expert einsum
    weights too, but the decode reads them dense — servable_params must not
    emit their codes and residual_qparams must keep their fake-quant sites,
    or compressed and dense logits silently diverge."""
    lm, params, qparams = _smoke_lm(arch)
    qadg = build_qadg(lm.build_graph().graph)
    keep = qadg.space.init_masks()          # keep-all
    subnet = construct_subnet(qadg, params, qparams, keep)
    assert subnet.meta["sparsity"] == pytest.approx(0.0)
    assert subnet.int_weights

    sp = servable_params(subnet)
    for name in subnet.int_weights:
        # codes emitted iff routed; dense copy dropped alongside
        assert (name + ".codes" in sp) == (name not in sp)

    dense_logits = _decode(lm, params, qparams)
    comp_logits = _decode(lm, sp, residual_qparams(subnet, qparams))
    np.testing.assert_allclose(np.asarray(comp_logits),
                               np.asarray(dense_logits), rtol=2e-4, atol=2e-4)


def test_compress_lm_nonrouted_component_not_dropped():
    """Asking compress_lm for a component the decode cannot execute from
    codes (MoE einsum weights) must not drop those weights from the served
    param dict — they stay dense and keep their fake-quant site."""
    lm, params, qparams = _smoke_lm("grok-1-314b")
    subnet = compress_lm(lm, params, qparams,
                         components=("attn", "mlp", "moe"))
    sp = servable_params(subnet)
    moe_names = [n for n in params if ".moe." in n]
    assert moe_names
    for n in moe_names:
        assert n in sp, n                       # dense copy survives
        assert n + ".codes" not in sp
    rq = residual_qparams(subnet, qparams)
    assert any(s.startswith(moe_names[0].rsplit(".", 1)[0]) for s in rq)
    # and the decode still runs
    logits = _decode(lm, sp, rq, steps=2)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_serve_loop_compressed_smoke():
    from repro.launch.serve import serve_loop
    seq = serve_loop("internlm2-1.8b", smoke=True, batch=2, prompt_len=4,
                     gen=6, compressed=True, verbose=False)
    assert seq.shape == (2, 6)
    assert np.all(np.asarray(seq) >= 0)

"""QASSO (Algorithms 2-4): stage schedule, white-box constraint
satisfaction, descent-direction property (Prop 5.1/B.1)."""
import pytest

pytest.importorskip("hypothesis")  # property-based tests; see requirements-dev.txt
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import quant as Q
from repro.core.graph import GraphBuilder
from repro.core.qadg import build_qadg
from repro.core.qasso import QASSO, QASSOConfig
from repro.optim.schedules import constant


def _mlp_problem(seed=0, hidden=32):
    gb = GraphBuilder()
    gb.input("in")
    gb.linear("fc1", "fc1.w", bias="fc1.b", out_dim=hidden)
    gb.act("relu1")
    gb.linear("fc2", "fc2.w", out_dim=8, non_prunable=True)
    gb.output("out")
    gb.attach_weight_quant("fc1", "fc1.w.wq")
    gb.attach_weight_quant("fc2", "fc2.w.wq")
    gb.insert_act_quant("relu1", "fc2", "act1.aq")
    qadg = build_qadg(gb.graph)

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = {"fc1.w": jax.random.normal(k1, (8, hidden)) * 0.3,
              "fc1.b": jnp.zeros((hidden,)),
              "fc2.w": jax.random.normal(k2, (hidden, 8)) * 0.3}
    qparams = {
        "fc1.w.wq": Q.init_quant_params(params["fc1.w"], bits=16.0),
        "fc2.w.wq": Q.init_quant_params(params["fc2.w"], bits=16.0),
        "act1.aq": Q.init_quant_params(q_m=4.0, bits=16.0),
    }
    X = jax.random.normal(k3, (64, 8))
    Y = X @ jax.random.normal(jax.random.PRNGKey(99), (8, 8))

    def forward(p, q, x):
        w1 = Q.fake_quant(p["fc1.w"], q["fc1.w.wq"].d, q["fc1.w.wq"].q_m,
                          q["fc1.w.wq"].t)
        h = jax.nn.relu(x @ w1 + p["fc1.b"])
        h = Q.fake_quant(h, q["act1.aq"].d, q["act1.aq"].q_m,
                         q["act1.aq"].t)
        w2 = Q.fake_quant(p["fc2.w"], q["fc2.w.wq"].d, q["fc2.w.wq"].q_m,
                          q["fc2.w.wq"].t)
        return h @ w2

    def loss_fn(p, q):
        return jnp.mean((forward(p, q, X) - Y) ** 2)

    return qadg, params, qparams, loss_fn


CFG = QASSOConfig(target_sparsity=0.5, bit_lower=4, bit_upper=16,
                  warmup_steps=10, projection_periods=3, projection_steps=6,
                  bit_reduction=2, pruning_periods=4, pruning_steps=8,
                  cooldown_steps=15, base_optimizer="adam", lr_quant=1e-3)


def _run(cfg=CFG, seed=0, steps=None):
    qadg, params, qparams, loss_fn = _mlp_problem(seed)
    qasso = QASSO(qadg.space, qadg.sites, cfg, constant(5e-3))
    state = qasso.init(params, qparams)

    @jax.jit
    def step(params, qparams, state):
        loss, (gx, gq) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            params, qparams)
        p, q, s, m = qasso.update(params, qparams, gx, gq, state)
        return p, q, s, m, loss

    hist = []
    for i in range(steps or cfg.total_steps):
        params, qparams, state, metrics, loss = step(params, qparams, state)
        hist.append({k: float(v) for k, v in metrics.items()}
                    | {"loss": float(loss)})
    return qadg, qasso, params, qparams, state, hist


def test_stage_schedule():
    qadg, qasso, *_ , hist = _run()
    stages = [h["stage"] for h in hist]
    assert stages[0] == 0
    assert stages[CFG.warmup_end] == 1
    assert stages[CFG.projection_end] == 2
    assert stages[CFG.joint_end] == 3
    assert sorted(set(stages)) == [0, 1, 2, 3]


def test_exact_sparsity_control():
    """White-box Eq 7b: hard sparsity == K (within one-unit rounding)."""
    qadg, qasso, params, qparams, state, hist = _run()
    sp = float(qasso.space.sparsity(state.keep_mask))
    total = qasso.space.total_units()
    assert abs(sp - CFG.target_sparsity) <= 1.0 / total + 1e-6


def test_bit_constraints_satisfied():
    """White-box Eq 7c: every site lands in [b_l, b_u_final]."""
    qadg, qasso, params, qparams, state, hist = _run()
    for s in qadg.sites:
        qp = qparams[s.name]
        b = float(Q.bit_width(qp.d, qp.q_m, qp.t))
        assert CFG.bit_lower - 1e-3 <= b <= CFG.bit_upper_final + 1e-3, \
            (s.name, b)


def test_pruned_units_exactly_zero_and_stay_zero():
    qadg, qasso, params, qparams, state, hist = _run()
    fam = qasso.space.prunable_families()[0]
    keep = np.asarray(state.keep_mask[fam.name])
    pruned = np.nonzero(keep < 0.5)[0]
    assert len(pruned) > 0
    w1 = np.asarray(params["fc1.w"])
    b1 = np.asarray(params["fc1.b"])
    w2 = np.asarray(params["fc2.w"])
    assert np.allclose(w1[:, pruned], 0.0)
    assert np.allclose(b1[pruned], 0.0)
    assert np.allclose(w2[pruned, :], 0.0)


def test_loss_decreases_overall():
    *_, hist = _run()
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_quant_params_frozen_in_cooldown():
    qadg, params, qparams0, loss_fn = _mlp_problem()
    cfg = CFG
    qasso = QASSO(qadg.space, qadg.sites, cfg, constant(5e-3))
    state = qasso.init(params, qparams0)

    @jax.jit
    def step(params, qparams, state):
        loss, (gx, gq) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            params, qparams)
        return qasso.update(params, qparams, gx, gq, state)

    qparams = qparams0
    snap = None
    for i in range(cfg.total_steps):
        params, qparams, state, _ = step(params, qparams, state)
        if i == cfg.joint_end:
            snap = jax.tree_util.tree_map(np.asarray, qparams)
    final = jax.tree_util.tree_map(np.asarray, qparams)
    for va, vb in zip(jax.tree_util.tree_leaves(snap),
                      jax.tree_util.tree_leaves(final)):
        np.testing.assert_allclose(va, vb)


# ----------------------------------------------------------- Prop 5.1/B.1
@given(n=st.integers(4, 64), seed=st.integers(0, 10_000),
       alpha=st.floats(1e-4, 1e-1), kp=st.integers(1, 50),
       k=st.integers(0, 49))
@settings(max_examples=60, deadline=None)
def test_descent_direction_property(n, seed, alpha, kp, k):
    """For random (w, g) and the Eq 16/17 rules, <grad, s(x)> < 0 on the
    redundant group (Proposition 5.1), including after Alg 4 rescaling."""
    if k >= kp:
        k = kp - 1
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (n,)) * 1.5
    g = jax.random.normal(k2, (n,)) + 1e-4
    qm = jnp.float32(1.0)
    t = jnp.float32(1.0)
    d0 = Q.step_size_for_bits(qm, t, jnp.float32(8.0))
    eta, xi = 0.9, 0.999

    sign = jnp.sign(w)
    clipv = sign * Q.clip_qmt(jnp.abs(w), qm, t)
    n_g = float(jnp.linalg.norm(g))
    n_clip = float(jnp.linalg.norm(clipv))
    cos_g = float(jnp.dot(g, clipv)) / max(n_g * n_clip, 1e-12)
    clip_mean = float(jnp.mean(jnp.abs(clipv)))

    if clip_mean <= 1e-8:
        return  # case 0: projection to zero, trivially fine
    if cos_g >= 0:
        gamma = 1.0 / (kp - k)
    else:
        gamma = -(1 - eta) * alpha * n_g / (cos_g * max(n_clip, 1e-12))

    resv = sign * Q.residual(jnp.abs(w), d0, qm, t)
    n_res = float(jnp.linalg.norm(resv))
    cos_d = float(jnp.dot(g, resv)) / max(n_g * max(n_res, 1e-12), 1e-12)
    if cos_d >= 0:
        d = float(Q.step_size_for_bits(qm, t, jnp.float32(4.0)))
    else:
        d = -(xi * eta * alpha * n_g) / (gamma * cos_d * max(n_res, 1e-12))

    # Prop 5.1 is proved on the decomposition x_Q = sgn*clip + d*sgn*R
    # (Eq 12) with R evaluated at the step size the angles were measured
    # at — Eq 17 selects d FROM cos(theta_d), so the guarantee is for this
    # linearization (re-evaluating R at the new d can flip its sign; the
    # paper's Alg 4 handles feasibility, not that re-evaluation).
    if cos_d >= 0:
        # any positive d keeps the residual term benign only in the
        # cos>=0 branch of the *measured* residual; check the clip bound
        # (Eq 20) which is unconditional.
        s_clip = -alpha * np.asarray(g, np.float64) \
            - gamma * np.asarray(clipv, np.float64)
        descent = float(np.dot(np.asarray(g, np.float64), s_clip))
        slack = 1e-6 * (alpha * n_g ** 2 + abs(gamma) * n_g * n_clip)
        assert descent < -eta * alpha * n_g ** 2 + slack
    else:
        xq_lin = np.asarray(clipv, np.float64) \
            + d * np.asarray(resv, np.float64)
        s_dir = -alpha * np.asarray(g, np.float64) - gamma * xq_lin
        descent = float(np.dot(np.asarray(g, np.float64), s_dir))
        slack = 1e-6 * (alpha * n_g ** 2
                        + abs(gamma) * n_g * max(n_clip, d * n_res))
        assert descent < slack

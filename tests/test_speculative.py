"""Speculative decoding test tier: the greedy token-identity oracle, the
dual-arena rollback property, and the engine scheduler invariants.

Greedy self-speculative decode commits only the *target's* argmaxes, so
token identity with a never-drafted engine is structural — any draft, at
any quality, must reproduce the plain engine's stream bit-for-bit. That
makes identity the one oracle that needs no tolerance: the matrix below
pins it for every (target arch x draft config x k) cell, including
budgets that end mid-draft-window.

The rollback property is the second hard invariant: full (window == 0)
arenas keep every row beyond the written prefix at zero init, so after
any accept/reject history both arenas must be bitwise equal to a
never-drafted engine's state — rows >= pos all-zero, pos/last_tok in
lockstep with the committed stream. `_assert_never_drafted_state` checks
it after every speculative round; the deterministic sweep here drives it
over fixed request mixes, and `tests/test_speculative_properties.py`
drives the same assertion under hypothesis-drawn mixes (derandomized via
the conftest "repro" profile).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.subnet import prepare_serving
from repro.launch.engine import Engine, build_engine, engine_serve, \
    synthetic_prompts
from repro.launch.speculative import build_checkpoint_engines, build_draft, \
    pow2_floor, rollback_rows
from repro.models.transformer import LM

ARCH = "internlm2-1.8b"
LENS, GEN = [6, 4], 12          # gen-1 = 11: budgets end mid-draft-window
MAX_SEQ = max(LENS) + GEN

# target serving modes x draft aggressiveness: the identity oracle must
# hold when the *target itself* is a compressed artifact (pruned slice /
# packed sub-byte codes), not just dense fake-quant
TARGETS = {
    "dense": {},
    "pruned_s50": dict(pruned=True, sparsity=0.5),
    "packed_b4": dict(packed=True, bits_init=4.0),
}
DRAFTS = {
    # s0/b8 packed subnet == the target function (PR 4/5 parity): ~all
    # proposals accept, exercising full-window commits + the k_eff cap
    "faithful": dict(draft_sparsity=0.0, draft_bits=8.0),
    # s50/b2: near-zero acceptance, maximal rollback traffic
    "aggressive": dict(draft_sparsity=0.5, draft_bits=2.0),
}

_REF: dict[str, dict[int, np.ndarray]] = {}


def _reference(target: str) -> dict[int, np.ndarray]:
    """Never-drafted engine output per target mode, computed once."""
    if target not in _REF:
        _REF[target] = engine_serve(ARCH, True, LENS, GEN, max_slots=2,
                                    verbose=False, **TARGETS[target])
    return _REF[target]


# ------------------------------------------------------- identity oracle
@pytest.mark.parametrize("draft_tag", sorted(DRAFTS))
@pytest.mark.parametrize("target", sorted(TARGETS))
def test_speculative_token_identity(target, draft_tag):
    """Every (target x draft x k in {1,2,4,8}) cell emits the plain
    engine's exact token stream. One engine per cell pair; k varies by
    mutating draft_k between drains (the jitted spec-step set is shared,
    so the matrix costs 6 builds, not 24)."""
    ref = _reference(target)
    eng, lm = build_engine(ARCH, True, max_slots=2, max_seq=MAX_SEQ,
                           speculative=True, draft_k=8,
                           **TARGETS[target], **DRAFTS[draft_tag])
    prompts = synthetic_prompts(lm.cfg, LENS)
    for k in (1, 2, 4, 8):
        eng.draft_k = k
        rids = [eng.submit(p, GEN) for p in prompts]
        out = eng.run()
        assert sorted(out) == sorted(rids)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(
                out[rid], ref[i],
                err_msg=f"target={target} draft={draft_tag} k={k} req={i}")


def test_speculative_token_identity_moe_target():
    """verify_chunk's full-capacity MoE routing: a chunked verify pass
    must route exactly like the one-token decode steps it replaces."""
    arch, lens, gen = "llama4-maverick-400b-a17b", [5, 3], 6
    ref = engine_serve(arch, True, lens, gen, max_slots=2, verbose=False)
    out = engine_serve(arch, True, lens, gen, max_slots=2, verbose=False,
                       speculative=True, draft_k=4,
                       draft_sparsity=0.5, draft_bits=4.0)
    assert sorted(out) == sorted(ref)
    for rid in ref:
        np.testing.assert_array_equal(out[rid], ref[rid],
                                      err_msg=f"request {rid}")


def test_budget_smaller_than_draft_window():
    """max_new_tokens hit mid-window: gen=2 leaves one remaining token
    after admission, so every round runs the k_eff=0 degenerate verify —
    and a 3-token budget rides a single k_eff=1 window. Both must match
    the plain engine and never overshoot the budget."""
    eng, lm = build_engine(ARCH, True, max_slots=2, max_seq=MAX_SEQ,
                           speculative=True, draft_k=8,
                           **DRAFTS["faithful"])
    prompts = synthetic_prompts(lm.cfg, LENS)
    for gen in (2, 3):
        plain = engine_serve(ARCH, True, LENS, gen, max_slots=2,
                             verbose=False)
        rids = [eng.submit(p, gen) for p in prompts]
        out = eng.run()
        for i, rid in enumerate(rids):
            assert len(out[rid]) == gen
            np.testing.assert_array_equal(out[rid], plain[i],
                                          err_msg=f"gen={gen} req={i}")


# -------------------------------------------------------------- rollback
_ROLLBACK: dict = {}


def _rollback_engines():
    """One spec engine with a *garbage* draft (different random init:
    proposals are noise, so nearly every round rejects and rolls back)
    plus its never-drafted twin — built once, reused across cases
    (admission overwrites whole arena rows, so reuse is exactly the
    slot-recycling the engine already guarantees)."""
    if not _ROLLBACK:
        cfg = get_arch(ARCH, smoke=True)
        if cfg.dtype != "float32":
            cfg = dataclasses.replace(cfg, dtype="float32")
        lm = LM(cfg)
        params, _ = lm.init(jax.random.PRNGKey(0))
        garbage, _ = LM(cfg).init(jax.random.PRNGKey(7))
        draft = build_draft(ARCH, True, garbage, sparsity=0.5, bits=2.0)
        params, qparams, _ = prepare_serving(lm, params)
        _ROLLBACK["spec"] = Engine(lm, params, qparams, max_slots=2,
                                   max_seq=16, draft=draft, draft_k=4)
        _ROLLBACK["plain"] = Engine(lm, params, qparams, max_slots=2,
                                    max_seq=16)
        _ROLLBACK["lm"] = lm
    return _ROLLBACK["spec"], _ROLLBACK["plain"], _ROLLBACK["lm"]


def _assert_never_drafted_state(spec: Engine) -> None:
    """For every active slot: both arenas' rows >= pos are bitwise zero
    (the never-drafted state — fresh arenas never wrote them, admission
    inserts whole rows built in zeroed prefill caches, and rollback
    re-zeroes every rejected row), and pos/last_tok agree with the
    committed stream."""
    for slot, req in enumerate(spec.active):
        if req is None:
            continue
        pos = int(spec.pos[slot])
        # admission emits one token before any arena row exists for it:
        # last_tok is fed (and its row written) at pos
        assert pos == req.prompt.size + len(req.tokens) - 1
        assert int(spec.last_tok[slot]) == req.tokens[-1]
        for arena in (spec.caches, spec.dcaches):
            for c in jax.tree_util.tree_leaves(arena):
                tail = np.asarray(c[:, slot, pos:])
                assert not np.any(tail), \
                    f"slot {slot}: non-zero rows beyond pos={pos}"


def run_rollback_case(lens, gens, draft_k) -> None:
    """Drive one request mix through the garbage-draft engine one
    speculative round at a time, asserting the never-drafted-state
    invariant after every round and final token identity at drain.
    Shared with the hypothesis module, which draws the arguments."""
    spec, plain, lm = _rollback_engines()
    spec.draft_k = draft_k
    prompts = synthetic_prompts(lm.cfg, list(lens))
    for p, g in zip(prompts, gens):
        spec.submit(p, g)
        plain.submit(p, g)
    while spec.pending:
        spec.step()
        _assert_never_drafted_state(spec)
    out, ref = spec.run(), plain.run()
    for (_, got), (_, want) in zip(sorted(out.items()),
                                   sorted(ref.items())):
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("lens,gens,draft_k", [
    ([5], [8], 4),                  # deep rollbacks on one slot
    ([2, 6], [8, 3], 4),            # staggered budgets, mid-flight evict
    ([4, 4, 5], [1, 7, 4], 8),      # queue > slots, k_eff sweeps down
    ([3, 3], [2, 2], 1),            # k_eff in {0, 1} only
])
def test_rollback_restores_never_drafted_state(lens, gens, draft_k):
    run_rollback_case(lens, gens, draft_k)


def test_rollback_rows_unit():
    """rollback_rows zeroes exactly [lo, hi] per slot and nothing else."""
    c = {"x": jnp.ones((2, 3, 8, 2), jnp.float32)}
    out = rollback_rows(c, lo=[2, 5, 8], hi=[4, 5, 7])["x"]
    out = np.asarray(out)
    want = np.ones((8,), np.float32)
    for slot, (lo, hi) in enumerate([(2, 4), (5, 5), (8, 7)]):
        w = want.copy()
        w[lo:hi + 1] = 0.0               # slot 2: empty range, no-op
        np.testing.assert_array_equal(out[:, slot, :, :],
                                      np.broadcast_to(w[None, :, None],
                                                      (2, 8, 2)))


# ------------------------------------------------- scheduler invariants
def test_spec_slot_reuse_isolated():
    """A request admitted into a recycled slot of a speculative engine
    decodes exactly as if it ran alone — draft-arena state included."""
    eng, lm = build_engine(ARCH, True, max_slots=1, max_seq=16,
                           speculative=True, draft_k=4,
                           **DRAFTS["aggressive"])
    prompts = synthetic_prompts(lm.cfg, [5, 5, 5])
    rid = eng.submit(prompts[2], 6)
    want = eng.run()[rid]
    rids = [eng.submit(p, 6) for p in prompts]
    out = eng.run()
    np.testing.assert_array_equal(out[rids[2]], want)


def test_spec_eviction_mid_draft():
    """Mixed budgets on fewer slots than requests: requests finish and
    evict between speculative rounds, later requests are admitted into
    the freed slots mid-flight — stream still matches the plain engine,
    and min-remaining k_eff never overshoots any slot's budget."""
    gens = [2, 9, 5]
    eng, lm = build_engine(ARCH, True, max_slots=2, max_seq=16,
                           speculative=True, draft_k=8,
                           **DRAFTS["faithful"])
    plain, _ = build_engine(ARCH, True, max_slots=2, max_seq=16)
    prompts = synthetic_prompts(lm.cfg, [4, 4, 4])
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    prids = [plain.submit(p, g) for p, g in zip(prompts, gens)]
    out, ref = eng.run(), plain.run()
    for r, pr, g in zip(rids, prids, gens):
        assert len(out[r]) == g
        np.testing.assert_array_equal(out[r], ref[pr])
    assert eng.stats["evicted"] == len(gens)


def test_spec_throughput_counts_accepted_not_drafted():
    """decode_tokens (and so the headline tok/s) counts only committed
    tokens; drafted-but-rejected work is visible only as the
    spec_drafted/spec_accepted gap."""
    eng, lm = build_engine(ARCH, True, max_slots=2, max_seq=MAX_SEQ,
                           speculative=True, draft_k=4,
                           **DRAFTS["aggressive"])
    prompts = synthetic_prompts(lm.cfg, LENS)
    rids = [eng.submit(p, GEN) for p in prompts]
    out = eng.run()
    total = sum(len(out[r]) for r in rids)
    # admission emits each request's first token outside decode counting
    assert eng.stats["decode_tokens"] == total - len(rids)
    assert eng.stats["spec_accepted"] <= eng.stats["spec_drafted"]
    assert eng.stats["spec_steps"] > 0
    th = eng.throughput()
    assert th["accepted_tok_per_s"] == th["decode_tok_per_s"]
    assert 0.0 <= th["acceptance_rate"] <= 1.0


def test_spec_accounting_exact():
    """Deterministic accounting trace with a faithful (always-accepted)
    draft, one slot, prompt 5 / budget 7 / draft_k 4:
      admit: tokens=[t0]                         (not a decode token)
      round 1: rem=6 -> k_eff=4, all accepted -> commit 5
      round 2: rem=1 -> k_eff=0 (plain verify) -> commit 1, done
    """
    eng, lm = build_engine(ARCH, True, max_slots=1, max_seq=16,
                           speculative=True, draft_k=4,
                           **DRAFTS["faithful"])
    rid = eng.submit(synthetic_prompts(lm.cfg, [5])[0], 7)
    out = eng.run()
    assert len(out[rid]) == 7
    s = eng.stats
    assert s["spec_steps"] == 2
    assert s["decode_steps"] == (4 + 1) + (0 + 1)
    assert s["decode_tokens"] == 6
    assert s["spec_drafted"] == 4
    assert s["spec_accepted"] == 4
    assert eng.throughput()["acceptance_rate"] == 1.0


def test_spec_warmup_compiled_shape_set_bounded():
    """warmup() compiles exactly the {0} + powers-of-two <= draft_k
    spec-step set; no workload mix may add a compile afterwards (the
    k_eff pow2 quantization is what guarantees it)."""
    eng, lm = build_engine(ARCH, True, max_slots=2, max_seq=MAX_SEQ,
                           speculative=True, draft_k=8,
                           **DRAFTS["aggressive"])
    assert eng._spec_ks() == [0, 1, 2, 4, 8]
    eng.warmup()
    compiled = eng._spec._cache_size()
    assert compiled == len(eng._spec_ks())
    prompts = synthetic_prompts(lm.cfg, LENS)
    for gen in (1, 2, 5, 9, GEN):          # every k_eff regime
        for p in prompts:
            eng.submit(p, gen)
        eng.run()
    assert eng._spec._cache_size() == compiled


def test_window_raises_on_speculative_engine():
    """_window's fused scan schedules events assuming one token per slot
    per step; a spec round commits 1..k+1, so the engine must refuse it
    (run() routes speculative engines through step())."""
    eng, lm = build_engine(ARCH, True, max_slots=1, max_seq=16,
                           speculative=True, draft_k=2,
                           **DRAFTS["aggressive"])
    eng.submit(synthetic_prompts(lm.cfg, [4])[0], 4)
    with pytest.raises(RuntimeError, match="one token per slot"):
        eng._window()
    assert len(eng.run()[0]) == 4          # step()-driven drain still works


# ------------------------------------------------------------ gating
def test_spec_rejects_windowed_and_stateful_archs():
    """Ring arenas (window > 0) and recurrent mixers cannot be rolled
    back; the engine (and verify_chunk itself) must refuse, not corrupt."""
    draft = build_draft(ARCH, True, sparsity=0.5, bits=2.0)
    cfg = get_arch(ARCH, smoke=True)
    lm = LM(dataclasses.replace(cfg, window=8))
    params, _ = lm.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="window"):
        Engine(lm, params, None, max_seq=16, draft=draft)

    rcfg = get_arch("rwkv6-3b", smoke=True)
    rlm = LM(rcfg)
    rparams, _ = rlm.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention mixers"):
        Engine(rlm, rparams, None, max_seq=16, draft=draft)
    with pytest.raises(ValueError, match="rolled back"):
        rlm.verify_chunk(rparams, None, None,
                         jnp.zeros((1, 2), jnp.int32),
                         jnp.zeros((1,), jnp.int32))

    lm2 = LM(cfg)
    params2, _ = lm2.init(jax.random.PRNGKey(0))
    for bad_k in (0, 16):
        with pytest.raises(ValueError, match="draft_k"):
            Engine(lm2, params2, None, max_seq=16, draft=draft,
                   draft_k=bad_k)


def test_pow2_floor():
    assert [pow2_floor(k) for k in (0, 1, 2, 3, 4, 7, 8, 9)] == \
        [0, 1, 2, 2, 4, 4, 8, 8]


# --------------------------------------------- checkpoint-surrogate pair
def test_checkpoint_engines_high_acceptance_and_identity():
    """The GETA deployment configuration: masked (cooldown-style)
    checkpoint as target, its own sliced b8 subnet as draft. The subnet
    *is* the target at the surviving widths, so acceptance must be ~1
    while identity holds — the speculative speedup's existence proof."""
    spec, base, lm = build_checkpoint_engines(ARCH, True, sparsity=0.5,
                                              draft_bits=8.0, draft_k=4,
                                              max_slots=2, max_seq=24)
    prompts = synthetic_prompts(lm.cfg, [6, 4])
    for p in prompts:
        spec.submit(p, 12)
        base.submit(p, 12)
    out, ref = spec.run(), base.run()
    for (_, got), (_, want) in zip(sorted(out.items()),
                                   sorted(ref.items())):
        np.testing.assert_array_equal(got, want)
    assert spec.throughput()["acceptance_rate"] >= 0.9

"""Per-architecture smoke tests (required deliverable f):

Every assigned arch instantiates its REDUCED config and runs one forward +
one GETA train step + one decode step on CPU, asserting output shapes and
finiteness. The FULL configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_arch, get_overrides
from repro.configs.base import CompressionConfig
from repro.core.qadg import build_qadg
from repro.data.synthetic import batch_for
from repro.launch.train import build_geta, make_geta_train_step
from repro.models.transformer import LM

COMP = CompressionConfig(
    target_sparsity=0.4, bit_lower=4, bit_upper=16, act_quant=False,
    warmup_steps=2, projection_periods=1, projection_steps=2,
    bit_reduction=2, pruning_periods=2, pruning_steps=2, cooldown_steps=2)


@pytest.mark.slow   # ~10s/arch: jits one full GETA train step per config
@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch, smoke=True)
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    # every param has a logical-axes entry of matching rank
    for name, arr in params.items():
        assert name in axes, name
        assert len(axes[name]) == arr.ndim, (name, axes[name], arr.shape)
    qparams = lm.init_qparams(params, bits_init=16.0)
    batch = batch_for(cfg, seed=0, step=0, batch=2, seq=16)

    logits = lm.forward(params, qparams, batch["tokens"],
                        batch.get("vision_embeds"))
    S_total = 16 if cfg.family != "vlm" else 16 + cfg.vision_patches - \
        cfg.vision_patches + 16  # text + patches handled inside
    assert logits.shape[0] == 2
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    base_opt = get_overrides(arch).get("base_optimizer", "adamw")
    qadg, qasso = build_geta(lm, COMP, lr=1e-3, base_optimizer=base_opt)
    qadg.space.validate(params)
    qstate = qasso.init(params, qparams)
    step = jax.jit(make_geta_train_step(lm, qasso))
    p2, q2, s2, metrics = step(params, qparams, qstate, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(s2.step) == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_arch(arch, smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    caches = lm.init_cache(2, 32, dtype=jnp.float32)
    tok_shape = (2, 1, cfg.num_codebooks) if cfg.num_codebooks else (2, 1)
    tok = jnp.zeros(tok_shape, jnp.int32)
    logits, caches2 = jax.jit(lm.decode_step)(params, None, caches, tok,
                                              jnp.int32(0))
    assert logits.shape[0] == 2
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # caches updated in place-shape
    for k in caches:
        assert caches2[k].shape == caches[k].shape


def test_decode_matches_forward_dense():
    """Token-by-token decode reproduces the teacher-forced forward logits
    (dense arch, no quant)."""
    cfg = get_arch("internlm2-1.8b", smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    full = lm.forward(params, None, toks)
    caches = lm.init_cache(1, 16, dtype=jnp.float32)
    outs = []
    for p in range(8):
        lg, caches = lm.decode_step(params, None, caches, toks[:, p:p+1],
                                    jnp.int32(p))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3,
                               atol=2e-3)


def test_decode_matches_forward_rwkv():
    cfg = get_arch("rwkv6-3b", smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab)
    full = lm.forward(params, None, toks)
    caches = lm.init_cache(1, 16, dtype=jnp.float32)
    outs = []
    for p in range(6):
        lg, caches = lm.decode_step(params, None, caches, toks[:, p:p+1],
                                    jnp.int32(p))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3,
                               atol=2e-3)


def test_decode_matches_forward_hybrid():
    import dataclasses
    cfg = get_arch("jamba-1.5-large-398b", smoke=True)
    # parity check needs drop-free routing: the teacher-forced forward
    # routes all tokens jointly (capacity can bind), decode routes one
    # token at a time (capacity never binds) — raise the capacity factor
    # so both paths keep every token.
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab)
    full = lm.forward(params, None, toks)
    caches = lm.init_cache(1, 16, dtype=jnp.float32)
    outs = []
    for p in range(6):
        lg, caches = lm.decode_step(params, None, caches, toks[:, p:p+1],
                                    jnp.int32(p))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=5e-3,
                               atol=5e-3)


def test_blockwise_attention_matches_dense():
    from repro.models.layers import attention_blockwise, attention_dense
    B, S, H, KV, dh = 2, 256, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, dh))
    yd = attention_dense(q, k, v)
    yb = attention_blockwise(q, k, v, block=64)
    np.testing.assert_allclose(np.asarray(yb), np.asarray(yd), rtol=2e-4,
                               atol=2e-4)

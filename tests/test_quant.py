"""Quantization math (paper §3): forward, STE gradients, bit-width algebra."""
import pytest

pytest.importorskip("hypothesis")  # property-based tests; see requirements-dev.txt
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import quant as Q
from repro.kernels.ref import fake_quant_bwd_ref, fake_quant_fwd_ref

jax.config.update("jax_enable_x64", False)


def test_bit_width_roundtrip():
    """Eq 3 and its inverse agree across the whole operating range."""
    for bits in (2.0, 4.0, 6.0, 8.0, 12.0, 16.0, 32.0):
        for q_m in (0.1, 1.0, 3.7):
            for t in (0.5, 1.0, 1.5):
                d = Q.step_size_for_bits(jnp.float32(q_m), jnp.float32(t),
                                         jnp.float32(bits))
                b = Q.bit_width(d, jnp.float32(q_m), jnp.float32(t))
                assert abs(float(b) - bits) < 1e-4


@given(q_m=st.floats(0.05, 8.0), t=st.floats(0.3, 2.0),
       b_l=st.floats(2.0, 6.0), span=st.floats(1.0, 10.0),
       d=st.floats(1e-6, 10.0))
@settings(max_examples=80, deadline=None)
def test_projection_enforces_bit_range(q_m, t, b_l, span, d):
    """PPSG projection (Alg 3): after projecting d, b in [b_l, b_u]."""
    b_u = b_l + span
    qp = Q.QuantParams(d=jnp.float32(d), q_m=jnp.float32(q_m),
                       t=jnp.float32(t))
    qp2 = Q.project_step_size(qp, b_l, b_u)
    b = float(Q.bit_width(qp2.d, qp2.q_m, qp2.t))
    assert b_l - 1e-3 <= b <= b_u + 1e-3
    # q_m and t untouched (only d is projected — paper §5.1)
    assert float(qp2.q_m) == pytest.approx(q_m)
    assert float(qp2.t) == pytest.approx(t)


def test_fake_quant_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 96)) * 2.0
    y = Q.fake_quant(x, jnp.float32(0.1), jnp.float32(1.2), jnp.float32(0.8))
    yr = fake_quant_fwd_ref(x, 0.1, 1.2, 0.8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6)


def test_fake_quant_levels_match_bits():
    """The number of distinct quantization levels obeys the derived bits."""
    x = jnp.linspace(-2.0, 2.0, 4001)
    d = Q.step_size_for_bits(jnp.float32(1.0), jnp.float32(1.0),
                             jnp.float32(4.0))
    y = Q.fake_quant(x, d, jnp.float32(1.0), jnp.float32(1.0))
    levels = np.unique(np.asarray(y))
    # b=4 -> 2^(b-1)-1 = 7 positive levels + 0 + 7 negative = 15
    assert len(levels) <= 2 ** 4 - 1
    assert len(levels) >= 2 ** 4 - 3


def test_ste_gradients_match_paper_formulas():
    """custom_vjp gradients == Eqs 4-6 (via the ref implementation)."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (37, 53)) * 1.5
    g = jax.random.normal(jax.random.PRNGKey(2), x.shape)
    d, qm, t = jnp.float32(0.07), jnp.float32(1.1), jnp.float32(0.9)

    def loss(x, d, qm, t):
        return jnp.sum(Q.fake_quant(x, d, qm, t) * g)

    dx, dd, dqm, dt = jax.grad(loss, argnums=(0, 1, 2, 3))(x, d, qm, t)
    rdx, rdd, rdqm, rdt = fake_quant_bwd_ref(x, d, qm, t, g)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rdx), rtol=1e-5)
    np.testing.assert_allclose(float(dd), float(rdd), rtol=1e-4)
    np.testing.assert_allclose(float(dqm), float(rdqm), rtol=1e-4)
    np.testing.assert_allclose(float(dt), float(rdt), rtol=1e-4)


def test_grad_qm_zero_inside_clip():
    """Eq 6: dL/dq_m = 0 when all |x| <= q_m."""
    x = jnp.ones((8, 8)) * 0.3
    dqm = jax.grad(
        lambda qm: jnp.sum(Q.fake_quant(x, jnp.float32(0.01), qm,
                                        jnp.float32(1.0))))(jnp.float32(2.0))
    assert float(dqm) == 0.0


def test_quantize_int_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(3), (32, 32))
    qp = Q.init_quant_params(w, bits=8.0)
    codes, d = Q.quantize_int(w, qp)
    xq = Q.dequantize_int(codes, d)
    yq = Q.fake_quant(w, qp.d, qp.q_m, qp.t)
    np.testing.assert_allclose(np.asarray(xq), np.asarray(yq), rtol=1e-5,
                               atol=1e-6)
    # codes fit in the derived bit budget
    maxcode = float(jnp.max(jnp.abs(codes)))
    assert maxcode <= 2 ** 7  # 8 bits symmetric


@given(bits=st.floats(3.0, 12.0))
@settings(max_examples=25, deadline=None)
def test_init_matches_requested_bits(bits):
    w = jax.random.normal(jax.random.PRNGKey(4), (16, 16))
    qp = Q.init_quant_params(w, bits=bits)
    b = float(Q.bit_width(qp.d, qp.q_m, qp.t))
    assert abs(b - bits) < 1e-3


@given(bits=st.integers(2, 8), data=st.data())
@settings(max_examples=120, deadline=None)
def test_pack_unpack_roundtrip_property(bits, data):
    """unpack(pack(c, b), b) == c exactly for every width 2-8 — negative
    codes, the full ±(2^(b-1)-1) range, and non-word-aligned lengths
    (trailing partial words) included."""
    hi = 2 ** (bits - 1) - 1
    codes = data.draw(st.lists(st.integers(-hi, hi), min_size=1,
                               max_size=67))
    c = jnp.asarray(codes, jnp.int32)
    packed = Q.pack_codes(c, bits)
    assert packed.dtype == jnp.int32
    cpw = 32 // bits
    assert packed.shape[0] == -(-len(codes) // cpw)
    u = np.asarray(Q.unpack_codes(packed, bits, len(codes)))
    np.testing.assert_array_equal(u, np.asarray(codes, np.int32))

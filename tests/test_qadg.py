"""QADG (Algorithm 1) + dependency analysis + pruning-space invariants."""
import pytest

pytest.importorskip("hypothesis")  # property-based tests; see requirements-dev.txt
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core.graph import GraphBuilder
from repro.core.qadg import build_qadg
from repro.models.cnn import CNN, RESNET20, VGG7


def _toy_graph(act_quant=True):
    gb = GraphBuilder()
    gb.input("in")
    gb.conv("conv1", "conv1.w", bias="conv1.b", out_dim=16)
    gb.bn("bn1", "bn1.scale", "bn1.bias")
    gb.act("relu1")
    gb.conv("conv2", "conv2.w", out_dim=16, after="relu1")
    gb.bn("bn2", "bn2.scale", "bn2.bias")
    gb.add("add1", ["bn2", "relu1"])
    gb.act("relu2")
    gb.pool("gap")
    gb.linear("fc", "fc.w", bias="fc.b", out_dim=10, non_prunable=True)
    gb.output("out")
    gb.attach_weight_quant("conv1", "conv1.w.wq")
    gb.attach_weight_quant("conv2", "conv2.w.wq")
    gb.attach_weight_quant("fc", "fc.w.wq")
    if act_quant:
        gb.insert_act_quant("relu1", "conv2", "relu1.aq")
    return gb


def test_attached_branches_merged():
    gb = _toy_graph()
    n_quant_before = len(gb.graph.quant_vertices())
    assert n_quant_before > 0
    qadg = build_qadg(gb.graph)
    # Alg 1 removes every quant vertex
    assert len(qadg.graph.quant_vertices()) == 0
    # one site per attached/inserted branch
    kinds = sorted(s.kind for s in qadg.sites)
    assert kinds == ["act", "weight", "weight", "weight"]


def test_inserted_branch_preserves_connectivity():
    gb = _toy_graph()
    qadg = build_qadg(gb.graph)
    # the graph is still a DAG reaching the output
    order = qadg.graph.topo_order()
    assert order[-1] in ("out",) or "out" in order


def test_residual_ties_spaces():
    """The residual add must tie conv1-out, conv2-out/in, and BN params
    into one family (the paper's minimally-removable structure)."""
    qadg = build_qadg(_toy_graph().graph)
    fams = qadg.space.prunable_families()
    assert len(fams) == 1
    members = {(m.param, m.axis) for m in fams[0].members}
    assert ("conv1.w", 3) in members
    assert ("conv2.w", 3) in members
    assert ("conv2.w", 2) in members          # in-channels tied
    assert ("bn1.scale", 0) in members
    assert ("fc.w", 0) in members             # consumer after GAP


def test_site_targets_weight_only():
    qadg = build_qadg(_toy_graph().graph)
    for s in qadg.sites:
        if s.kind == "weight":
            assert all(p.endswith(".w") for p in s.quantized_params)


@pytest.mark.parametrize("spec", [VGG7, RESNET20])
def test_cnn_masks_preserve_forward_of_kept_units(spec):
    """Masking all-ones == no-op; materialize yields identical logits for
    a mask with pruned units (the functional-subnetwork invariant)."""
    m = CNN(spec)
    params = m.init(jax.random.PRNGKey(0))
    qadg = build_qadg(m.build_graph().graph)
    qadg.space.validate(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))

    masks = qadg.space.init_masks()
    y_full = m.apply(params, None, x)
    y_masked = m.apply(qadg.space.apply_masks(params, masks), None, x)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_masked),
                               rtol=1e-5, atol=1e-5)

    # prune 25% of units in every family; masked-model == materialized-model
    masks2 = {k: v.at[: max(len(v) // 4, 1)].set(0.0)
              for k, v in masks.items()}
    mp = qadg.space.apply_masks(params, masks2)
    y_soft = m.apply(mp, None, x)
    sub, kept = qadg.space.materialize(params, masks2)
    # the materialized subnet has smaller tensors
    total_sub = sum(v.size for v in sub.values())
    total_full = sum(v.size for v in params.values())
    assert total_sub < total_full
    assert np.all(np.isfinite(np.asarray(y_soft)))


@given(units=st.integers(2, 12), unit_size=st.integers(1, 4),
       frac=st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_mask_apply_materialize_consistency(units, unit_size, frac):
    """Property: zeroed-then-materialized slices == slices of the masked
    tensor (both layouts)."""
    from repro.core.groups import GroupFamily, Member, PruningSpace
    for layout in ("contiguous", "interleaved"):
        fam = GroupFamily("f", units,
                          [Member("w", 0, unit_size, layout)])
        space = PruningSpace([fam])
        w = jnp.arange(units * unit_size * 3, dtype=jnp.float32).reshape(
            units * unit_size, 3)
        params = {"w": w}
        space.validate(params)
        n_zero = int(frac * units)
        mask = jnp.ones((units,)).at[:n_zero].set(0.0)
        masked = space.apply_masks(params, {"f": mask})["w"]
        sub, kept = space.materialize(params, {"f": mask})
        assert sub["w"].shape[0] == (units - n_zero) * unit_size
        # every surviving element appears unchanged
        surv = np.asarray(masked)
        surv = surv[np.abs(surv).sum(1) > 0] if n_zero else surv
        assert np.all(np.isfinite(np.asarray(sub["w"])))
        s = float(space.sparsity({"f": mask}))
        assert s == pytest.approx(n_zero / units)


def test_lm_graph_all_families_valid():
    from repro.configs import ASSIGNED_ARCHS, get_arch
    from repro.models.transformer import LM
    for arch in ASSIGNED_ARCHS:
        cfg = get_arch(arch, smoke=True)
        lm = LM(cfg)
        params, _ = lm.init(jax.random.PRNGKey(0))
        qadg = build_qadg(lm.build_graph(act_quant=True).graph)
        qadg.space.validate(params)
        assert len(qadg.sites) > 0, arch
        assert qadg.space.total_units() > 0, arch

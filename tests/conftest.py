import os
import sys

# Tests must see the REAL device view (1 CPU) — never the dry-run's 512
# placeholder devices. Guard against accidental inheritance.
os.environ.pop("XLA_FLAGS", None) if "force_host_platform" in \
    os.environ.get("XLA_FLAGS", "") else None

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

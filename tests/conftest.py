"""Suite-wide determinism: the tests must produce identical numerics on
any host, TPU or not.

- The JAX platform is pinned (default: cpu) *before* jax import so that
  nothing downstream — `kernels.dispatch.platform_default()` included —
  platform-sniffs its way onto a different backend between hosts. Tests
  that exercise kernel logic select `pallas-interpret` / `xla-ref`
  explicitly per call; an explicit `JAX_PLATFORMS` in the environment
  still wins (that's how a TPU host opts the suite onto hardware).
- Hypothesis runs the derandomized profile: examples are a pure function
  of the test, not of a per-run entropy source.
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Never inherit the dry-run's 512 fake host devices into real tests.
if "force_host_platform" in os.environ.get("XLA_FLAGS", ""):
    os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings

    settings.register_profile("repro", derandomize=True, deadline=None)
    settings.load_profile("repro")
except ImportError:  # hypothesis-based tests importorskip themselves
    pass

"""Suite-wide determinism: the tests must produce identical numerics on
any host, TPU or not.

- The JAX platform is pinned (default: cpu) *before* jax import so that
  nothing downstream — `kernels.dispatch.platform_default()` included —
  platform-sniffs its way onto a different backend between hosts. Tests
  that exercise kernel logic select `pallas-interpret` / `xla-ref`
  explicitly per call; an explicit `JAX_PLATFORMS` in the environment
  still wins (that's how a TPU host opts the suite onto hardware).
- Hypothesis runs the derandomized profile: examples are a pure function
  of the test, not of a per-run entropy source.
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Never inherit the dry-run's 512 fake host devices into real tests —
# EXCEPT when the multi-device tier opts in explicitly: the sharded-parity
# and resume tests (tests/test_sharded_training.py) run under
#   REPRO_MULTI_DEVICE=1 XLA_FLAGS=--xla_force_host_platform_device_count=4
# and skip themselves when fewer than 4 devices are visible.
if ("force_host_platform" in os.environ.get("XLA_FLAGS", "")
        and not os.environ.get("REPRO_MULTI_DEVICE")):
    os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings

    settings.register_profile("repro", derandomize=True, deadline=None)
    settings.load_profile("repro")
except ImportError:  # hypothesis-based tests importorskip themselves
    pass

"""Native pytree optimizers (no optax dependency).

API (functional, jit/pjit friendly):

    opt = adam(b1=0.9, b2=0.999)
    state = opt.init(params)
    delta, state = opt.update(grads, state, params, lr)
    params = tree_add(params, delta)

`delta` already includes the -lr factor (params + delta applies the step),
so QASSO can compose extra terms (the forget direction) onto it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_zeros_f32(a):
    """f32 optimizer-state zeros regardless of (possibly bf16) param dtype."""
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(jnp.shape(x), jnp.float32), a)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    name: str = "opt"


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        delta = jax.tree_util.tree_map(
            lambda g, p: (-lr * g.astype(jnp.float32)).astype(p.dtype),
            grads, params)
        return delta, state

    return Optimizer(init, update, "sgd")


def momentum(mu: float = 0.9, nesterov: bool = False) -> Optimizer:
    # moments live in f32 even for bf16 params (training stability)
    def init(params):
        return tree_zeros_f32(params)

    def update(grads, state, params, lr):
        new_m = jax.tree_util.tree_map(
            lambda m, g: mu * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            step_dir = jax.tree_util.tree_map(
                lambda m, g: g.astype(jnp.float32) + mu * m, new_m, grads)
        else:
            step_dir = new_m
        delta = jax.tree_util.tree_map(
            lambda d, p: (-lr * d).astype(p.dtype), step_dir, params)
        return delta, new_m

    return Optimizer(init, update, "momentum")


class AdamState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, decoupled: bool = True) -> Optimizer:
    """Adam / AdamW (decoupled=True gives AdamW)."""

    def init(params):
        return AdamState(jnp.zeros((), jnp.int32), tree_zeros_f32(params),
                         tree_zeros_f32(params))

    def update(grads, state, params, lr):
        count = state.count + 1
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        if weight_decay and not decoupled:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(jnp.float32),
                grads, params)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state.v, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def dstep(m_, v_, p):
            d = -lr * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay and decoupled:
                d = d - lr * weight_decay * p.astype(jnp.float32)
            return d.astype(p.dtype)

        delta = jax.tree_util.tree_map(dstep, m, v, params)
        return delta, AdamState(count, m, v)

    return Optimizer(init, update, "adamw" if weight_decay else "adam")


def adamw(lr_unused=None, b1=0.9, b2=0.999, eps=1e-8,
          weight_decay=0.01) -> Optimizer:
    return adam(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
                decoupled=True)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return tree_scale(grads, scale), gnorm


OPTIMIZERS = {"sgd": sgd, "momentum": momentum, "adam": adam, "adamw": adamw}


def get_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)

from repro.optim.base import (Optimizer, adam, adamw, clip_by_global_norm,
                              get_optimizer, momentum, sgd, tree_add)
from repro.optim.schedules import SCHEDULES, constant, cosine, step_lr

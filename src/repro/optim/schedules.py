"""Learning-rate schedules (step -> lr), all jit-traceable."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def step_lr(lr: float, step_size: int, gamma: float = 0.1):
    """StepLR of the paper's CNN experiments (Appendix C)."""
    def sched(step):
        k = jnp.floor_divide(step, step_size).astype(jnp.float32)
        return jnp.float32(lr) * jnp.float32(gamma) ** k
    return sched


def cosine(lr: float, total_steps: int, warmup: int = 0, min_ratio: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(lr) * jnp.where(step < warmup, warm, cos)
    return sched


SCHEDULES = {"constant": constant, "step": step_lr, "cosine": cosine}

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)

"""Checkpointing with elastic re-shard on restore.

Format: <dir>/step_<N>/arrays.npz  (flat name -> host numpy array)
        <dir>/step_<N>/manifest.json (step, mesh shape, tree structure,
                                      dtypes, logical axes)
Writes go to a tmp directory that is atomically renamed once complete, so a
crash mid-write never corrupts the latest checkpoint (restore scans for the
newest complete manifest). An optional background thread makes saves async
(train step N+1 overlaps the host write of step N).

Restore is *elastic*: arrays are loaded on host and re-placed with the
sharding of the CURRENT mesh (which may differ from the saving mesh), so a
512-chip run can resume on 256 chips and vice versa.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
        if not tree:
            out[prefix + "__empty__"] = np.zeros((0,))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _to_numpy(x):
    """bf16 has no numpy dtype — store as a uint16 view + dtype tag."""
    a = np.asarray(x)
    if a.dtype == jax.dtypes.bfloat16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def save_checkpoint(directory: str, step: int, tree: Any,
                    meta: Optional[dict] = None, async_write: bool = False):
    """tree: arbitrary pytree of arrays (params/opt/qasso state)."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    host, dtypes = zip(*[_to_numpy(x) for x in flat]) if flat else ((), ())

    def write():
        tmp = os.path.join(directory, f".tmp_step_{step}")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_arrays": len(host),
            "dtypes": list(dtypes),
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)       # atomic publish

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            man = os.path.join(directory, name, "manifest.json")
            if os.path.exists(man):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, example_tree: Any,
                       shardings: Any = None,
                       step: Optional[int] = None
                       ) -> Optional[tuple[Any, int]]:
    """Restore into the structure of `example_tree`, placing each leaf with
    the matching entry of `shardings` (same structure, NamedSharding or
    None). Returns (tree, step) or None if no checkpoint exists."""
    step = latest_step(directory) if step is None else step
    if step is None:
        return None
    path = os.path.join(directory, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    dtypes = manifest.get("dtypes", [])
    flat_ex, treedef = jax.tree_util.tree_flatten(example_tree)
    arrays = []
    for i in range(len(flat_ex)):
        a = data[f"a{i}"]
        if i < len(dtypes) and dtypes[i] == "bfloat16":
            a = a.view(jax.dtypes.bfloat16)
        arrays.append(a)
    if shardings is not None:
        flat_sh, _ = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None
            or isinstance(x, jax.sharding.Sharding))
        placed = []
        for a, ex, sh in zip(arrays, flat_ex, flat_sh):
            a = a.astype(np.asarray(ex).dtype) if hasattr(ex, "dtype") else a
            placed.append(jax.device_put(a, sh) if sh is not None
                          else jnp.asarray(a))
        arrays = placed
    else:
        arrays = [jnp.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays), step

"""Checkpointing with elastic re-shard on restore.

Format: <dir>/step_<N>/arrays.npz  (flat name -> host numpy array)
        <dir>/step_<N>/manifest.json (step, mesh shape, tree structure,
                                      dtypes, logical axes)
Writes go to a tmp directory that is atomically renamed once complete, so a
crash mid-write never corrupts the latest checkpoint (restore scans for the
newest complete manifest). An optional background thread makes saves async
(train step N+1 overlaps the host write of step N).

Restore is *elastic*: arrays are loaded on host and re-placed with the
sharding of the CURRENT mesh (which may differ from the saving mesh), so a
512-chip run can resume on 256 chips and vice versa.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _to_numpy(x):
    """bf16 has no numpy dtype — store as a uint16 view + dtype tag."""
    a = np.asarray(x)
    if a.dtype == jax.dtypes.bfloat16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def save_checkpoint(directory: str, step: int, tree: Any,
                    meta: Optional[dict] = None, async_write: bool = False):
    """tree: arbitrary pytree of arrays (params/opt/qasso state)."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    host, dtypes = zip(*[_to_numpy(x) for x in flat]) if flat else ((), ())

    def write():
        tmp = os.path.join(directory, f".tmp_step_{step}")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_arrays": len(host),
            "dtypes": list(dtypes),
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)       # atomic publish

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            man = os.path.join(directory, name, "manifest.json")
            if os.path.exists(man):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, example_tree: Any,
                       shardings: Any = None,
                       step: Optional[int] = None
                       ) -> Optional[tuple[Any, int]]:
    """Restore into the structure of `example_tree`, placing each leaf with
    the matching entry of `shardings` (same structure, NamedSharding or
    None). Returns (tree, step) or None if no checkpoint exists.

    The manifest's step / leaf-count / treedef are validated against the
    request before any leaf is rebuilt — a structure drift (renamed param,
    changed optimizer) raises with both structures named instead of
    silently zipping flattened leaves into the wrong slots. Leaf dtypes
    round-trip exactly as saved (bf16 via the uint16 view, int/uint
    counters and masks untouched): restore never casts to the example's
    dtype, so a resumed run replays a bitwise-identical trajectory."""
    step = latest_step(directory) if step is None else step
    if step is None:
        return None
    path = os.path.join(directory, f"step_{step}")
    if not os.path.exists(os.path.join(path, "manifest.json")):
        raise ValueError(f"no checkpoint for step {step} under {directory} "
                         f"(latest complete step: {latest_step(directory)})")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("step", step) != step:
        raise ValueError(
            f"checkpoint {path} manifest claims step "
            f"{manifest.get('step')} but was requested as step {step}")
    dtypes = manifest.get("dtypes", [])
    flat_ex, treedef = jax.tree_util.tree_flatten(example_tree)
    n_saved = manifest.get("n_arrays", len(flat_ex))
    if n_saved != len(flat_ex):
        raise ValueError(
            f"checkpoint {path} holds {n_saved} leaves but the requested "
            f"tree has {len(flat_ex)} — the state structure changed since "
            f"this checkpoint was written")
    saved_td = manifest.get("treedef")
    if saved_td is not None and saved_td != str(treedef):
        raise ValueError(
            f"checkpoint {path} tree structure does not match the "
            f"requested tree.\n  saved:     {saved_td}\n  requested: "
            f"{treedef} — leaves would be zipped into the wrong slots")
    arrays = []
    for i in range(len(flat_ex)):
        a = data[f"a{i}"]
        if i < len(dtypes) and dtypes[i] == "bfloat16":
            a = a.view(jax.dtypes.bfloat16)
        arrays.append(a)
    if shardings is not None:
        flat_sh, _ = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None
            or isinstance(x, jax.sharding.Sharding))
        if len(flat_sh) != len(flat_ex):
            raise ValueError(
                f"shardings tree has {len(flat_sh)} leaves, state tree has "
                f"{len(flat_ex)}")
        arrays = [jax.device_put(a, sh) if sh is not None else jnp.asarray(a)
                  for a, sh in zip(arrays, flat_sh)]
    else:
        arrays = [jnp.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays), step

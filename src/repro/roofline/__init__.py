from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, CellCost,
                                     cost_from_compiled, model_flops_for,
                                     parse_collectives, scan_corrected)

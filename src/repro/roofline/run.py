import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# ^^ must precede jax import (see launch/dryrun.py).

"""Roofline harness: per (arch x shape) on the single-pod production mesh,
derive the three roofline terms from compiled dry-run artifacts with scan
trip-count correction (depth-1/depth-2 differencing + analytic inner-scan
adjustment). Writes experiments/roofline.json + a markdown table.

  PYTHONPATH=src python -m repro.roofline.run --arch all --step geta
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, all_cells, get_arch
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import layer_plan
from repro.roofline import analysis as RA


def roofline_cell(arch: str, shape_name: str, mesh, step: str,
                  microbatches: int = 4, mode: str = "tp",
                  serve_quant: str = "qat", serve_attn: str = "auto") -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    plan, n_blocks = layer_plan(cfg)
    n_dev = mesh.size
    mb = microbatches if shape.kind == "train" else 1

    rec = {"arch": arch, "shape": shape_name, "step": step,
           "n_blocks": n_blocks, "microbatches": mb, "mode": mode}
    t0 = time.time()
    try:
        lowered, _, _ = build_cell(arch, shape_name, mesh, step,
                                   microbatches=microbatches, mode=mode,
                                   serve_quant=serve_quant,
                                   serve_attn=serve_attn)
        full = RA.cost_from_compiled(lowered.compile())
        if n_blocks >= 2 and shape.kind != "decode":
            l1, _, _ = build_cell(arch, shape_name, mesh, step, depth=1,
                                  microbatches=microbatches, mode=mode)
            l2, _, _ = build_cell(arch, shape_name, mesh, step, depth=2,
                                  microbatches=microbatches, mode=mode)
            c1 = RA.cost_from_compiled(l1.compile())
            c2 = RA.cost_from_compiled(l2.compile())
            cost = RA.scan_corrected(c1, c2, n_blocks, full=full)
        else:
            cost = full
        # decode runs the layer stack under scan too: correct by n_blocks
        if shape.kind == "decode" and n_blocks >= 2:
            l1, _, _ = build_cell(arch, shape_name, mesh, step, depth=1,
                                  mode=mode, serve_quant=serve_quant,
                                  serve_attn=serve_attn)
            l2, _, _ = build_cell(arch, shape_name, mesh, step, depth=2,
                                  mode=mode, serve_quant=serve_quant,
                                  serve_attn=serve_attn)
            c1 = RA.cost_from_compiled(l1.compile())
            c2 = RA.cost_from_compiled(l2.compile())
            cost = RA.scan_corrected(c1, c2, n_blocks, full=full)
        # gradient-accumulation loop is also a scan: one microbatch counted
        if mb > 1:
            cost.flops *= mb
            cost.bytes_accessed *= mb
            cost.wire_bytes *= mb
        # sequence-chunk scans inside a layer: analytic adjustment
        cost.flops += RA.inner_scan_flops(cfg, shape, n_dev)

        model_flops = RA.model_flops_for(cfg, shape)
        row = RA.make_row(arch, shape, "1pod", step, cost, model_flops,
                          n_dev)
        rec.update(
            ok=True,
            compute_s=row.compute_s, memory_s=row.memory_s,
            collective_s=row.collective_s, dominant=row.dominant,
            model_flops=row.model_flops,
            hlo_flops_global=row.hlo_flops_global,
            useful_ratio=row.useful_ratio,
            device_gb=row.device_gb,
            coll_counts=row.coll_counts,
            wall_s=round(time.time() - t0, 1))
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-1500:])
    return rec


def to_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | step | compute s | memory s | coll s | "
           "dominant | MODEL/HLO | dev GB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['step']} | "
                       f"FAIL: {r.get('error','')[:60]} | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['device_gb']:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--step", default="geta", choices=["geta", "base"])
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--mode", default="tp", choices=["tp", "zero"])
    ap.add_argument("--serve-quant", default="qat",
                    choices=["qat", "prequant"])
    ap.add_argument("--serve-attn", default="auto",
                    choices=["auto", "psum", "seqshard"])
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    cells = all_cells()
    if args.arch != "all":
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape != "all":
        cells = [c for c in cells if c[1] == args.shape]

    rows = []
    for arch, shape in cells:
        r = roofline_cell(arch, shape, mesh, args.step, args.microbatches,
                          mode=args.mode, serve_quant=args.serve_quant,
                          serve_attn=args.serve_attn)
        rows.append(r)
        if r.get("ok"):
            print(f"[{len(rows):2d}/{len(cells)}] {arch:26s} {shape:12s} "
                  f"c={r['compute_s']:.4f}s m={r['memory_s']:.4f}s "
                  f"w={r['collective_s']:.4f}s dom={r['dominant']:10s} "
                  f"useful={r['useful_ratio']:.2f} gb={r['device_gb']:.1f}",
                  flush=True)
        else:
            print(f"[{len(rows):2d}/{len(cells)}] {arch:26s} {shape:12s} "
                  f"FAIL {r['error']}", flush=True)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    with open(args.out.replace(".json", ".md"), "w") as f:
        f.write(to_markdown(rows) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

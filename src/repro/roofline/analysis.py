"""Roofline derivation from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh), all in seconds/step:

  compute    = HLO_FLOPs        / peak_FLOPs_per_chip     (197e12 bf16)
  memory     = HLO_bytes        / HBM_bw_per_chip         (819e9)
  collective = wire_bytes       / ICI_link_bw_per_chip    (50e9)

`cost_analysis()` of a GSPMD-partitioned module reports *per-device*
FLOPs/bytes (verified empirically), so no chip division is needed. Wire
bytes are parsed from the compiled HLO text with ring-collective costing
on local shard shapes:

  all-reduce(N)        -> 2*(k-1)/k * N
  all-gather(N_out)    ->   (k-1)/k * N_out
  reduce-scatter(N_out)->   (k-1)   * N_out      (input = k*N_out)
  all-to-all(N)        ->   (k-1)/k * N
  collective-permute(N)->              N

Scan trip-count correction: XLA's HloCostAnalysis visits a while body ONCE
(measured), so a depth-L scanned layer stack under-reports by ~L. We lower
the same cell at n_blocks=1 and n_blocks=2; per-block cost = C(2) - C(1);
corrected = C(1) + (n_blocks - 1) * per_block. The same differencing
corrects collective bytes inside the body. Residual under-count from
sequence-chunk scans inside a layer (blockwise attention / mamba chunks /
rwkv token scan) is corrected analytically via `inner_scan_flops`.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

# --- TPU v5e constants (per chip) ---
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<type>\([^=]*?\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|"
                       r"pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[[0-9,]+\]<="
                        r"\[[0-9]+\])")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    # iota format [G,k]<=[N]
    dims = g[1:g.index("]")].split(",")
    return int(dims[-1])


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict
    wire_bytes: float

    def total(self) -> float:
        return self.wire_bytes


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    rbytes: dict = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if "-done" in line.split("=")[1][:40]:
            continue
        nb = _shape_bytes(m.group("type"))
        k = _group_size(line)
        if k <= 1:
            continue
        if op == "all-reduce":
            w = 2.0 * (k - 1) / k * nb
        elif op == "all-gather":
            w = (k - 1) / k * nb
        elif op == "reduce-scatter":
            w = float(k - 1) * nb
        elif op == "all-to-all":
            w = (k - 1) / k * nb
        else:  # collective-permute
            w = float(nb)
        counts[op] = counts.get(op, 0) + 1
        rbytes[op] = rbytes.get(op, 0.0) + nb
        wire += w
    return CollectiveStats(counts, rbytes, wire)


@dataclasses.dataclass
class CellCost:
    flops: float               # per device
    bytes_accessed: float      # per device
    wire_bytes: float          # per device
    coll_counts: dict
    arg_bytes: float = 0.0
    temp_bytes: float = 0.0
    out_bytes: float = 0.0

    @property
    def device_bytes(self) -> float:
        return self.arg_bytes + self.temp_bytes + self.out_bytes


def cost_from_compiled(compiled) -> CellCost:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older JAX wraps the dict in a list
        ca = ca[0] if ca else {}
    coll = parse_collectives(compiled.as_text())
    ma = compiled.memory_analysis()
    return CellCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        wire_bytes=coll.wire_bytes,
        coll_counts=coll.counts,
        arg_bytes=float(ma.argument_size_in_bytes),
        temp_bytes=float(ma.temp_size_in_bytes),
        out_bytes=float(ma.output_size_in_bytes),
    )


def scan_corrected(c1: CellCost, c2: CellCost, n_blocks: int,
                   full: Optional[CellCost] = None) -> CellCost:
    """Trip-count correction via depth differencing.

    c1/c2: costs lowered at n_blocks=1/2. Memory fields come from `full`
    (the real-depth compile) when given."""
    per = CellCost(
        flops=max(c2.flops - c1.flops, 0.0),
        bytes_accessed=max(c2.bytes_accessed - c1.bytes_accessed, 0.0),
        wire_bytes=max(c2.wire_bytes - c1.wire_bytes, 0.0),
        coll_counts={})
    out = CellCost(
        flops=c1.flops + (n_blocks - 1) * per.flops,
        bytes_accessed=c1.bytes_accessed + (n_blocks - 1) * per.bytes_accessed,
        wire_bytes=c1.wire_bytes + (n_blocks - 1) * per.wire_bytes,
        coll_counts=(full or c2).coll_counts,
        arg_bytes=(full or c2).arg_bytes,
        temp_bytes=(full or c2).temp_bytes,
        out_bytes=(full or c2).out_bytes,
    )
    return out


def inner_scan_flops(cfg, shape, n_devices: int) -> float:
    """Analytic per-device FLOPs hidden inside *sequence* scans (counted
    once by HloCostAnalysis): blockwise-attention KV loop, mamba chunk
    loop, rwkv token loop. Returns the missing amount to ADD."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    missing = 0.0
    if kind == "decode":
        return 0.0   # decode has no sequence scans (single token)
    toks = float(B) * S
    n_attn = n_mamba = n_rwkv = 0
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        n_mamba = cfg.n_layers - n_attn
    elif cfg.family == "ssm_rwkv":
        n_rwkv = cfg.n_layers
    elif cfg.n_heads:
        n_attn = cfg.n_layers

    fb = 3.0 if kind == "train" else 1.0   # fwd+bwd vs fwd
    # blockwise attention is a q-block map around a kv-block scan: the HLO
    # counts 1 of (nq * nkv) block pairs
    if n_attn and S > cfg.attn_block_threshold \
            and S % cfg.attn_block_size == 0:
        att = 2.0 * toks * S * cfg.n_heads * cfg.d_head  # causal halved
        nb = S // cfg.attn_block_size
        pairs = nb * nb
        missing += n_attn * att * fb * (pairs - 1) / pairs
    if n_mamba:
        Di = cfg.mamba.expand * cfg.d_model
        N = cfg.mamba.d_state
        ssm = 6.0 * toks * Di * N      # assoc-scan combine ~3 mul-add
        chunks = max(S // cfg.mamba.chunk, 1)
        missing += n_mamba * ssm * fb * (chunks - 1) / chunks
    if n_rwkv:
        D = cfg.d_model
        dh = cfg.rwkv.head_size
        wkv = 3.0 * toks * D * dh      # state update + readout per head
        missing += n_rwkv * wkv * fb * (S - 1) / S
    return missing / n_devices


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    step: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    device_gb: float
    coll_counts: dict

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the dominant-term-bound step achieves on its
        *useful* model FLOPs: (model_flops / chips / peak) / bound."""
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return (self.compute_s / max(bound, 1e-30))


def make_row(arch: str, shape_cfg, mesh_name: str, step: str,
             cost: CellCost, model_flops: float, n_devices: int
             ) -> RooflineRow:
    return RooflineRow(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, step=step,
        compute_s=cost.flops / PEAK_FLOPS,
        memory_s=cost.bytes_accessed / HBM_BW,
        collective_s=cost.wire_bytes / ICI_BW,
        model_flops=model_flops,
        hlo_flops_global=cost.flops * n_devices,
        device_gb=cost.device_bytes / 1e9,
        coll_counts=cost.coll_counts)


@dataclasses.dataclass
class DecodeAttnRow:
    """Analytic roofline for one fused flash-decode attention step.

    Decode attention is HBM-bound at any realistic arena length: the
    kernel streams the whole K and V arena once (the dominant term, grows
    with context) plus the current token's cache write and q/out
    activations, against 2 MACs per streamed element (QK^T + PV) — an
    arithmetic intensity of ~2 flops/byte at bf16 caches, far under the
    v5e ridge (~240). The interesting number is therefore attained HBM
    bandwidth vs the 819 GB/s roof, not FLOP utilization."""
    batch: int
    ctx: float                 # mean valid cache length over the decode
    bytes_hbm: float           # KV read + cache write + q/out, per step
    flops: float               # 2·S·dh·H MACs x 2 GEMMs, per step
    roof_s: float              # best-case step time at the HBM roof

    def attained_gbps(self, measured_s: float) -> float:
        """Achieved HBM bandwidth if the measured step moved only this
        row's bytes — a lower bound on the real attained bandwidth (the
        step also runs its projection GEMMs)."""
        return self.bytes_hbm / max(measured_s, 1e-12) / 1e9

    def frac_of_roof(self, measured_s: float) -> float:
        return self.roof_s / max(measured_s, 1e-12)


def decode_attn_row(batch: int, ctx: float, n_heads: int, n_kv_heads: int,
                    d_head: int, n_layers: int = 1, *,
                    cache_bytes: int = 2, act_bytes: int = 4
                    ) -> DecodeAttnRow:
    """Decode-attention roofline row (per decode step, `n_layers` attention
    sublayers).

    bytes = KV arena read (K and V, `ctx` valid rows per slot) + the
    token's cache write + q/out activations; flops = 2 GEMMs x
    2·ctx·d_head·H MACs per sequence. `ctx` is the mean valid cache
    length across the decode (ragged slots average out); pass the pruned
    `LayerShapes` head counts for sliced subnets — the arena only holds
    surviving kv heads."""
    kv_read = 2.0 * batch * ctx * n_kv_heads * d_head * cache_bytes
    cache_write = 2.0 * batch * n_kv_heads * d_head * cache_bytes
    q_out = 2.0 * batch * n_heads * d_head * act_bytes
    bytes_hbm = n_layers * (kv_read + cache_write + q_out)
    flops = n_layers * 2.0 * 2.0 * batch * ctx * n_heads * d_head
    roof_s = max(bytes_hbm / HBM_BW, flops / PEAK_FLOPS)
    return DecodeAttnRow(batch=batch, ctx=ctx, bytes_hbm=bytes_hbm,
                         flops=flops, roof_s=roof_s)


def model_flops_for(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) + attention term — global."""
    toks = float(shape.global_batch) * shape.seq_len
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        base = 6.0 * n_active * toks
        att_f = 3.0
    elif shape.kind == "prefill":
        base = 2.0 * n_active * toks
        att_f = 1.0
    else:  # decode: one token per sequence
        toks = float(shape.global_batch)
        base = 2.0 * n_active * toks
        att_f = 1.0
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
    elif cfg.family == "ssm_rwkv":
        n_attn = 0
    if n_attn and cfg.n_heads:
        ctx = shape.seq_len
        if shape.kind == "decode":
            att = 4.0 * toks * ctx * cfg.n_heads * cfg.d_head
        else:
            att = 2.0 * toks * ctx * cfg.n_heads * cfg.d_head  # causal/2
        base += n_attn * att * att_f
    return base

"""Single tiled-GEMM core with composable RHS-transform epilogues.

Every matmul-shaped kernel in this package — structured-mask matmul
(training joint stage), int-code dequant matmul (compressed serving), and
the fused fake-quant + mask projection — is the *same* (bm, bn, bk)
MXU-aligned pipeline differing only in how the weight tile is transformed
after the HBM->VMEM load. This module owns that pipeline once:

  y = x @ T(w),    T = op_n ∘ ... ∘ op_1      (applied to RHS tiles in VMEM)

with pad-to-block / slice-back handled in exactly one place. The legacy
entry points (`masked_matmul.py`, `quant_matmul.py`) are thin op-configs
over `gemm()`.

Blocking: classic (bm, bn, bk) tiling with f32 accumulation into the output
block across the K grid axis. K is the innermost / fastest-varying grid
dimension, so revisits of an (i, j) output block are consecutive and the
accumulator pattern is valid on TPU.

Each `RhsOp` declares its operands as either a per-output-column vector
("col", shape (N,), delivered as a (1, bn) VMEM block riding the j grid
axis) or a scalar ("scalar", delivered as a (1, 1) block mapped to every
grid step). `op.apply` consumes jnp values, so the same callable serves the
Pallas kernel body *and* the xla-ref oracle backend.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import _EPS, clip_qmt, unpack_codes
from repro.kernels import dispatch, introspect

DEFAULT_BLOCKS = (128, 128, 128)  # bm, bn, bk

COL, SCALAR = "col", "scalar"


@dataclasses.dataclass(frozen=True)
class RhsOp:
    """One composable transform of the (bk, bn) RHS tile.

    kinds: operand kinds, each COL ((N,) vector, blocked (1, bn)) or
           SCALAR ((1, 1) everywhere).
    apply: (w_f32, *operand_values) -> w_f32; operand values arrive as
           (1, bn) / (1, 1) f32 arrays (full-width (1, N) on xla-ref).
    k_pack: >1 marks a bit-unpacking op: the RHS array is stored packed
           along K (`k_pack` codes per int32 word), `apply` receives the
           *raw integer* word tile of shape (bk/k_pack, bn) and must
           return the decoded f32 (bk, bn) tile. Only the first op may
           unpack (later ops see the dense decoded tile).
    """
    name: str
    kinds: tuple[str, ...]
    apply: Callable[..., jax.Array]
    operands: tuple[jax.Array, ...]
    k_pack: int = 1

    def __post_init__(self):
        assert len(self.kinds) == len(self.operands), (self.name, self.kinds)


# ------------------------------------------------------------- op factories
def col_mask(mask: jax.Array) -> RhsOp:
    """w *= mask[None, :] — structured column (pruning-group) mask."""
    return RhsOp("col_mask", (COL,), lambda w, m: w * m, (mask,))


def dequant(scale: jax.Array) -> RhsOp:
    """w = codes * scale[None, :] — int-code dequantization."""
    return RhsOp("dequant", (COL,), lambda w, s: w * s, (scale,))


def unpack_dequant(bits: int, scale: jax.Array) -> RhsOp:
    """Sub-byte decode: int32 K-packed words -> f32 codes * scale.

    The RHS streams HBM->VMEM as `core.quant.pack_codes` words (32//bits
    codes per word, LSB field first, packed along K); the tile is
    unpacked — shift, mask, sign-extend — and dequantized entirely inside
    VMEM, so a 4-bit site moves half the HBM bytes of its int8 container.
    Composes with later COL ops (`col_mask`) exactly like `dequant`."""
    bits = int(bits)
    if not 2 <= bits <= 8:
        raise ValueError(f"unpack_dequant bits must be in [2, 8]: {bits}")
    cpw = 32 // bits

    def apply(words, s):
        # words: (Wk, n) int32 — raw packed tile (k_pack routes it here
        # uncast); returns the decoded (Wk * cpw, n) f32 tile. The
        # shift/mask/sign-extend decode lives in `core.quant.unpack_codes`
        # only (pure jnp, kernel-body compatible), so the packing format
        # has exactly one definition.
        codes = unpack_codes(words, bits, words.shape[0] * cpw, axis=0)
        return codes.astype(jnp.float32) * s

    return RhsOp(f"unpack_dequant_b{bits}", (COL,), apply, (scale,),
                 k_pack=cpw)


def _fq_apply(w, dv, qmv, tv):
    # Reuses core.quant.clip_qmt so the in-tile rounding decisions match
    # the XLA quantizer bit-for-bit (a reimplementation that differs by
    # 1 ulp flips round ties by a whole step of d).
    d = jnp.maximum(dv[0, 0], _EPS)
    xt = clip_qmt(jnp.abs(w), qmv[0, 0], tv[0, 0])
    return d * jnp.round(xt / d) * jnp.sign(w)


def fake_quant_rhs(d: jax.Array, q_m: jax.Array, t: jax.Array) -> RhsOp:
    """w = fake_quant(w; d, q_m, t) — paper Eqs (1)-(2) on the weight tile."""
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(())
    return RhsOp("fake_quant", (SCALAR,) * 3, _fq_apply,
                 (scal(d), scal(q_m), scal(t)))


def fq_mask_ops(d, q_m, t, mask) -> tuple[RhsOp, ...]:
    """The GETA joint-stage RHS: fake_quant(w) * mask in one HBM pass."""
    return (fake_quant_rhs(d, q_m, t), col_mask(mask))


# ----------------------------------------------------------------- kernel
def _make_kernel(ops: tuple[RhsOp, ...]):
    def kernel(*refs):
        x_ref, w_ref = refs[0], refs[1]
        op_refs = refs[2:-1]
        o_ref = refs[-1]
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        x = x_ref[...].astype(jnp.float32)
        w = w_ref[...]
        if not (ops and ops[0].k_pack > 1):
            w = w.astype(jnp.float32)   # unpack ops consume the raw ints
        i = 0
        for op in ops:
            vals = [op_refs[i + j][...].astype(jnp.float32)
                    for j in range(len(op.kinds))]
            w = op.apply(w, *vals)
            i += len(op.kinds)
        o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    return kernel


def _clamp_blocks(blocks, M, N, K):
    """Shrink the (bm, bn, bk) tile to cover small inputs without waste.

    bm clamps to the *8-sublane-aligned* cover of M: decode GEMMs run at
    M = active slots (often 4-8), and an M=4 input under the default
    bm=128 would pad 97% of the tile; rounding M up to a multiple of 8
    keeps the tile MXU-legal (f32 min sublane tile is 8) while the pad
    stays < 8 rows. bn/bk floor at the 128-lane tile."""
    bm, bn, bk = blocks
    return (min(bm, -(-M // 8) * 8), min(bn, max(128, N)),
            min(bk, max(128, K)))


def plan_blocks(M: int, N: int, K: int, k_pack: int = 1, blocks=None
                ) -> tuple[int, int, int, int]:
    """Resolve the final (bm, bn, bk, bkw) tile `gemm` would launch for an
    (M, N, K) problem: the clamp rule above plus the packed-K word
    alignment (bk must cover whole words *and* keep both tiles MXU-legal:
    a multiple of the 128-lane x tiling with bk/k_pack a multiple of 8
    sublanes — lcm(k_pack*8, 128), a no-op 128 for bits 2/4/8 and 640 for
    the bits=3 10-codes stream). Shared by `gemm` and the static VMEM
    model (`kernels.introspect`) so the footprint the analyzer budgets is
    the tile the kernel actually dispatches."""
    bm, bn, bk = _clamp_blocks(blocks or DEFAULT_BLOCKS, M, N, K)
    if k_pack > 1:
        bk = math.lcm(k_pack * 8, max(bk, 128))
        return bm, bn, bk, bk // k_pack
    return bm, bn, bk, bk


def gemm(x: jax.Array, w: jax.Array, rhs_ops: tuple[RhsOp, ...] = (), *,
         blocks=None, backend: str | None = None,
         out_dtype=None) -> jax.Array:
    """y = x @ T(w) with T the composition of `rhs_ops`.

    x: (M, K); w: (K, N) (any dtype castable to f32, incl. int8/int16
    codes) — or, when the first op carries `k_pack > 1` (`unpack_dequant`),
    the K-packed int32 word stream of shape (ceil(K / k_pack), N). COL
    operands are (N,) vectors; SCALAR operands are scalars. Pads every dim
    to block multiples once; output sliced back to (M, N).

    `blocks=None` (default) consults the `kernels.autotune` per-shape
    table — a tuned (bm, bn, bk) for this exact (M, N, K, epilogue,
    backend) if one was recorded, `DEFAULT_BLOCKS` otherwise — so TP
    shards and pruned widths don't run tiles sized for full shapes.
    Pass explicit blocks to bypass the table (parity tests do).
    """
    backend = dispatch.resolve(backend)
    M, K = x.shape
    k_pack = rhs_ops[0].k_pack if rhs_ops else 1
    assert all(op.k_pack == 1 for op in rhs_ops[1:]), \
        "only the leading RHS op may unpack"
    Kw, N = w.shape
    if k_pack > 1:
        assert Kw == -(-K // k_pack), (x.shape, w.shape, k_pack)
    else:
        assert K == Kw, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype

    if blocks is None:
        from repro.kernels import autotune
        blocks = autotune.lookup(M, N, K, autotune.ops_key(rhs_ops),
                                 backend) or DEFAULT_BLOCKS

    plan = plan_blocks(M, N, K, k_pack, blocks)
    if introspect.recording():
        # the tile the compiled-TPU path would launch, recorded even when
        # this trace routes to xla-ref (CPU CI statically audits the TPU
        # footprint — see kernels.introspect)
        from repro.kernels import autotune
        introspect.note(introspect.GemmLaunch(
            M=M, N=N, K=K, k_pack=k_pack,
            n_col=sum(k == COL for op in rhs_ops for k in op.kinds),
            n_scalar=sum(k == SCALAR for op in rhs_ops for k in op.kinds),
            ops=autotune.ops_key(rhs_ops), backend=backend, blocks=plan,
            w_itemsize=w.dtype.itemsize))

    if backend == "xla-ref":
        w32 = w if k_pack > 1 else w.astype(jnp.float32)
        for op in rhs_ops:
            vals = [v.astype(jnp.float32).reshape(
                        (1, -1) if kind == COL else (1, 1))
                    for kind, v in zip(op.kinds, op.operands)]
            w32 = op.apply(w32, *vals)
        if k_pack > 1:
            w32 = w32[:K]   # drop the zero codes of the final partial word
        y = x.astype(jnp.float32) @ w32
        return y.astype(out_dtype)

    bm, bn, bk, _ = plan
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    Mp, Kp = xp.shape
    if k_pack > 1:
        pkw, bkw = Kp // k_pack - Kw, bk // k_pack
    else:
        pkw, bkw = pk, bk
    wp = jnp.pad(w, ((0, pkw), (0, pn))) if (pkw or pn) else w
    Np = wp.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bkw, bn), lambda i, j, k: (k, j)),
    ]
    operands = []
    for op in rhs_ops:
        for kind, v in zip(op.kinds, op.operands):
            if kind == COL:
                vp = jnp.pad(v, (0, pn)) if pn else v
                operands.append(vp.astype(jnp.float32).reshape(1, -1))
                in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
            else:
                operands.append(
                    jnp.asarray(v, jnp.float32).reshape(1, 1))
                in_specs.append(pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)))

    y = pl.pallas_call(
        _make_kernel(tuple(rhs_ops)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=(backend == "pallas-interpret"),
    )(xp, wp, *operands)
    return y[:M, :N].astype(out_dtype)


# ---------------------------------------------------------- tensor parallel
def tp_gemm(x: jax.Array, w: jax.Array, rhs_ops: tuple[RhsOp, ...] = (), *,
            mesh, axis: str = "model", blocks=None,
            backend: str | None = None, out_dtype=None) -> jax.Array:
    """Column-parallel y = x @ T(w) over one mesh axis via `shard_map`.

    The N dimension tiles across `axis`: the weight (and packed word
    stream — packing runs along K, so its columns split identically) and
    every per-column COL operand shard as P(None, axis) / P(axis), x
    replicates, and each device runs the full-K single-device `gemm` on
    its local (K, N/tp) shard. There is **no cross-device reduction** —
    each output column is produced wholly on one device with the exact
    single-device kernel arithmetic, so TP numerics are the 1-device
    numerics per column (the property the engine token-parity tests
    lean on). The returned array is the full (M, N) global result,
    laid out column-sharded over `axis`.

    Unlike a bare `gemm` inside a sharded program (an opaque custom call
    GSPMD would all-gather around — see `dispatch.platform_default`),
    the kernel here runs per device *inside* shard_map, so TPU hosts keep
    the MXU path under TP: the default backend is
    `dispatch.shard_local_default()`, not the mesh-demoted default.
    Block sizes resolve per *local* shape, so the autotune table tunes
    the (M, N/tp, K) shard, not the full width."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    tp = int(mesh.shape[axis])
    M, K = x.shape
    N = w.shape[1]
    if N % tp:
        raise ValueError(f"tp_gemm: N={N} must divide the {axis!r} axis "
                         f"size {tp}")
    backend = backend or dispatch.shard_local_default()

    in_specs = [P(), P(None, axis)]
    operands = []
    layout = []
    for op in rhs_ops:
        layout.append((op.name, op.kinds, op.apply, op.k_pack))
        for kind, v in zip(op.kinds, op.operands):
            operands.append(v)
            in_specs.append(P(axis) if kind == COL else P())

    def body(xl, wl, *vals):
        i, ops_l = 0, []
        for name, kinds, apply, k_pack in layout:
            ops_l.append(RhsOp(name, kinds, apply,
                               tuple(vals[i:i + len(kinds)]), k_pack=k_pack))
            i += len(kinds)
        return gemm(xl, wl, tuple(ops_l), blocks=blocks, backend=backend,
                    out_dtype=out_dtype)

    return shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                     out_specs=P(None, axis), check_rep=False)(
                         x, w, *operands)

"""TPU kernel package: one tiled-GEMM core, composable epilogues, and a
backend dispatch registry. See DESIGN.md §4.

Public surface:
  dispatch   — backend selection (pallas-tpu / pallas-interpret / xla-ref)
  gemm_core  — the shared (bm, bn, bk) pipeline + RhsOp epilogue configs
  ops        — jit'd differentiable entry points used by the models
"""
from repro.kernels.dispatch import (available_backends, resolve, set_backend,
                                    use_backend)
from repro.kernels.gemm_core import (RhsOp, col_mask, dequant, fake_quant_rhs,
                                     gemm)
from repro.kernels.ops import (decode_attn_op, fake_quant_op,
                               fq_masked_matmul_op, fq_matmul_op,
                               masked_matmul_op, matmul_op, quant_matmul_op)

__all__ = [
    "available_backends", "resolve", "set_backend", "use_backend",
    "RhsOp", "col_mask", "dequant", "fake_quant_rhs", "gemm",
    "decode_attn_op", "fake_quant_op", "fq_masked_matmul_op",
    "fq_matmul_op", "masked_matmul_op", "matmul_op", "quant_matmul_op",
]

"""Kernel backend registry: one place that decides how kernels execute.

Covers every routed op in `kernels/ops.py` — the GEMM family and the
flash-decode attention op (`decode_attn_op`).

Backends:
  pallas-tpu        — compiled Pallas kernels (MXU path; requires a TPU).
  pallas-interpret  — the same kernels through the Pallas interpreter
                      (bit-faithful to the kernel logic on any platform;
                      used by the parity tests and for debugging).
  xla-ref           — the pure-jnp oracle composition (`kernels/ref.py`
                      semantics). Default off-TPU: XLA's native dot is the
                      fastest correct implementation on CPU/GPU hosts.

Selection order (first match wins):
  1. explicit per-call ``backend=`` argument,
  2. legacy ``interpret=`` boolean (True -> pallas-interpret,
     False -> pallas-tpu),
  3. process-wide override (`set_backend()` / `use_backend()` /
     ``REPRO_KERNEL_BACKEND`` env var),
  4. platform default: pallas-tpu on TPU hosts, xla-ref elsewhere.

This replaces the per-module ``_interpret_default()`` platform sniffing the
three seed kernels each carried.
"""
from __future__ import annotations

import contextlib
import os

import jax

BACKENDS = ("pallas-tpu", "pallas-interpret", "xla-ref")

_state = {"override": None}


def available_backends() -> tuple[str, ...]:
    return BACKENDS


def platform_default() -> str:
    """pallas-tpu on a single-device TPU host, xla-ref everywhere else.

    Under a multi-device GSPMD mesh a pallas_call is an opaque custom call
    with no partitioning rule — GSPMD would all-gather the full weight per
    call — so sharded programs default to XLA's native (partitionable) dot
    until the kernels grow shard_map integration. Override explicitly to
    opt in."""
    if jax.default_backend() == "tpu" and jax.device_count() == 1:
        return "pallas-tpu"
    return "xla-ref"


def backend_for_mesh(mesh) -> str:
    """Mesh-aware backend pick for sharded programs.

    Any mesh spanning more than one device routes to the partitionable XLA
    path regardless of platform — a pallas_call is an opaque custom call
    with no GSPMD partitioning rule, so letting it into a sharded program
    means a full-weight all-gather per call. A 1-device mesh (the parity
    reference, or a single-TPU host) keeps the platform default so the MXU
    kernels stay on the hot path."""
    if mesh is None:
        return platform_default()
    size = getattr(mesh, "size", None)
    if size is None:  # AbstractMesh on older JAX: fall back to axis product
        size = 1
        for s in dict(mesh.shape).values():
            size *= int(s)
    return "xla-ref" if size > 1 else platform_default()


def shard_local_default() -> str:
    """Backend for kernels running *inside* `shard_map`.

    Per-device code under shard_map is no longer opaque to GSPMD — the
    partitioning already happened at the shard_map boundary — so the
    device-count guard in `platform_default` doesn't apply: TPU hosts keep
    the MXU Pallas kernels regardless of mesh size, everything else stays
    on the XLA oracle. This is what the `tp_gemm` / `tp_decode_attn`
    wrappers resolve when no explicit backend is passed."""
    return "pallas-tpu" if jax.default_backend() == "tpu" else "xla-ref"


def set_backend(name: str | None) -> None:
    """Process-wide backend override (None restores platform selection)."""
    if name is not None and name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"expected one of {BACKENDS}")
    _state["override"] = name


def get_backend_override() -> str | None:
    return _state["override"]


@contextlib.contextmanager
def use_backend(name: str):
    """Scoped backend override (tests / benchmarks)."""
    prev = _state["override"]
    set_backend(name)
    try:
        yield
    finally:
        _state["override"] = prev


def resolve(backend: str | None = None,
            interpret: bool | str | None = None) -> str:
    """Resolve the effective backend name for one kernel call."""
    if backend is None and isinstance(interpret, str):
        # legacy positional slot carrying a backend name
        backend = interpret
        interpret = None
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown kernel backend {backend!r}; "
                             f"expected one of {BACKENDS}")
        return backend
    if interpret is not None:
        return "pallas-interpret" if interpret else "pallas-tpu"
    if _state["override"] is not None:
        return _state["override"]
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in BACKENDS:
            raise ValueError(f"REPRO_KERNEL_BACKEND={env!r} is not one of "
                             f"{BACKENDS}")
        return env
    return platform_default()

"""Jit'd public wrappers around the Pallas kernels.

`fake_quant_op` exposes the fused kernel with the same custom-VJP contract as
`repro.core.quant.fake_quant`; models select the backend via
`use_pallas=True` (TPU) — on CPU CI we run interpret mode, selected here by
platform sniffing so the public API is backend-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fake_quant as _fq
from repro.kernels import masked_matmul as _mm
from repro.kernels import quant_matmul as _qm
from repro.kernels import ref as _ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------- fake quant
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def fake_quant_op(x, d, q_m, t, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _fq.fake_quant_fwd_pallas(x, d, q_m, t, interpret=interpret)


def _fq_fwd(x, d, q_m, t, interpret):
    interpret = _interpret_default() if interpret is None else interpret
    y = _fq.fake_quant_fwd_pallas(x, d, q_m, t, interpret=interpret)
    return y, (x, d, q_m, t)


def _fq_bwd(interpret, res, g):
    x, d, q_m, t = res
    interpret = _interpret_default() if interpret is None else interpret
    dx, dd, dqm, dt = _fq.fake_quant_bwd_pallas(x, d, q_m, t, g,
                                                interpret=interpret)
    return (dx, dd.reshape(jnp.shape(d)).astype(jnp.float32),
            dqm.reshape(jnp.shape(q_m)).astype(jnp.float32),
            dt.reshape(jnp.shape(t)).astype(jnp.float32))


fake_quant_op.defvjp(_fq_fwd, _fq_bwd)


# ------------------------------------------------------------- masked matmul
def masked_matmul_op(x, w, mask, *, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _mm.masked_matmul_pallas(x, w, mask, interpret=interpret)


# -------------------------------------------------------------- quant matmul
def quant_matmul_op(x, codes, scale, *, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _qm.quant_matmul_pallas(x, codes, scale, interpret=interpret)


# Re-export oracles for tests/benchmarks.
fake_quant_fwd_ref = _ref.fake_quant_fwd_ref
fake_quant_bwd_ref = _ref.fake_quant_bwd_ref
masked_matmul_ref = _ref.masked_matmul_ref
quant_matmul_ref = _ref.quant_matmul_ref

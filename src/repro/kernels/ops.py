"""Public kernel entry points, routed through the backend dispatcher.

Every op resolves its execution backend via `repro.kernels.dispatch`
(pallas-tpu / pallas-interpret / xla-ref, per-call override supported) and
executes on the shared tiled-GEMM core (`gemm_core.gemm`) — the three seed
kernels' private tiling/padding/platform-sniffing copies are gone.

Matmul ops that sit on the training path (`matmul_op`, `masked_matmul_op`,
`fq_matmul_op`, `fq_masked_matmul_op`) carry custom VJPs: Pallas calls are
not generally differentiable, and the backward GEMMs reuse the same core
(the quantizer stays fused into the dx GEMM's RHS load; the weight
cotangent routes through `core.quant.fake_quant`'s elementwise STE VJP).
Column masks are GETA decay schedules, not learned parameters — their
cotangent is defined as zero (QASSO applies forgetting in the optimizer
update, never by backprop through the mask).

`fake_quant_op` exposes the fused elementwise kernel with the same
custom-VJP contract as `repro.core.quant.fake_quant`. Its legacy 5th
positional argument accepts None (dispatch default), a bool (interpret
mode), or a backend name.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attn as _da
from repro.kernels import dispatch
from repro.kernels import fake_quant as _fq
from repro.kernels import gemm_core as _gc
from repro.kernels import introspect
from repro.kernels import ref as _ref
from repro.core.quant import fake_quant as _fake_quant_xla


def _fq_backend(interpret) -> bool:
    """Map the legacy interpret slot to the elementwise kernel's backend.

    Returns (use_xla_ref, interpret_flag)."""
    b = dispatch.resolve(None, interpret)
    return b == "xla-ref", b == "pallas-interpret"


# ----------------------------------------------------------------- fake quant
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def fake_quant_op(x, d, q_m, t, interpret=None):
    use_ref, interp = _fq_backend(interpret)
    if use_ref:
        return _ref.fake_quant_fwd_ref(x, d, q_m, t)
    return _fq.fake_quant_fwd_pallas(x, d, q_m, t, interpret=interp)


def _fq_fwd(x, d, q_m, t, interpret):
    y = fake_quant_op(x, d, q_m, t, interpret)
    return y, (x, d, q_m, t)


def _fq_bwd(interpret, res, g):
    x, d, q_m, t = res
    use_ref, interp = _fq_backend(interpret)
    if use_ref:
        dx, dd, dqm, dt = _ref.fake_quant_bwd_ref(x, d, q_m, t, g)
    else:
        dx, dd, dqm, dt = _fq.fake_quant_bwd_pallas(x, d, q_m, t, g,
                                                    interpret=interp)
    return (dx, dd.reshape(jnp.shape(d)).astype(jnp.float32),
            dqm.reshape(jnp.shape(q_m)).astype(jnp.float32),
            dt.reshape(jnp.shape(t)).astype(jnp.float32))


fake_quant_op.defvjp(_fq_fwd, _fq_bwd)


# ------------------------------------------------------------- dense matmul
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _matmul(x, w, backend):
    return _gc.gemm(x, w, (), backend=backend)


def _matmul_fwd(x, w, backend):
    return _matmul(x, w, backend), (x, w)


def _matmul_bwd(backend, res, g):
    x, w = res
    dx = _gc.gemm(g, w.T, (), backend=backend, out_dtype=x.dtype)
    dw = _gc.gemm(x.T, g, (), backend=backend, out_dtype=w.dtype)
    return dx, dw


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_op(x, w, *, interpret=None, backend=None):
    """y = x @ w on the shared GEMM core (differentiable)."""
    return _matmul(x, w, dispatch.resolve(backend, interpret))


# ------------------------------------------------------------- masked matmul
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _masked_matmul(x, w, mask, backend):
    return _gc.gemm(x, w, (_gc.col_mask(mask),), backend=backend)


def _mm_fwd(x, w, mask, backend):
    return _masked_matmul(x, w, mask, backend), (x, w, mask)


def _mm_bwd(backend, res, g):
    x, w, mask = res
    # d/dx [x @ (w*m)] = (g*m) @ w.T ; d/dw = (x.T @ g) * m = x.T @ (g*m).
    gm = (g.astype(jnp.float32) * mask.astype(jnp.float32)[None, :]
          ).astype(g.dtype)
    dx = _gc.gemm(gm, w.T, (), backend=backend, out_dtype=x.dtype)
    dw = _gc.gemm(x.T, g, (_gc.col_mask(mask),), backend=backend,
                  out_dtype=w.dtype)
    return dx, dw, jnp.zeros_like(mask)


_masked_matmul.defvjp(_mm_fwd, _mm_bwd)


def masked_matmul_op(x, w, mask, *, interpret=None, backend=None):
    """y = x @ (w * mask[None, :]) (differentiable; mask cotangent is 0)."""
    return _masked_matmul(x, w, mask, dispatch.resolve(backend, interpret))


# -------------------------------------------------------------- quant matmul
def quant_matmul_op(x, codes, scale, *, interpret=None, backend=None):
    """y = x @ (codes * scale[None, :]) — inference-only decode path."""
    backend = dispatch.resolve(backend, interpret)
    return _gc.gemm(x, codes, (_gc.dequant(scale),), backend=backend,
                    out_dtype=x.dtype)


def packed_quant_matmul_op(x, packed, bits, scale, *, interpret=None,
                           backend=None):
    """y = x @ (unpack(packed; bits) * scale[None, :]) — sub-byte serving.

    `packed` is the K-packed int32 word stream (`core.quant.pack_codes`,
    ceil(K/(32//bits)) rows for x: (M, K)); `bits` is the static storage
    width in [2, 8]. The words stream HBM->VMEM and decode inside VMEM via
    the `unpack_dequant` epilogue — inference-only, like `quant_matmul_op`."""
    backend = dispatch.resolve(backend, interpret)
    return _gc.gemm(x, packed, (_gc.unpack_dequant(bits, scale),),
                    backend=backend, out_dtype=x.dtype)


# --------------------------------------------------- flash-decode attention
def decode_attn_op(q, k, v, pos, *, window=0, chunk=None, interpret=None,
                   backend=None):
    """Single-query flash-decode attention over the slot KV arena.

    q: (B, KVh, g, dh) query heads grouped per KV head (g = H // KVh);
    k/v: (B, S, KVh, dh) arena rows with the current token written;
    pos: (B,) int32 per-slot positions. Row b attends over its
    min(pos[b] + 1, S) valid rows — full and ring (windowed) arenas
    share the rule, enforced inside the kernel. Returns (B, KVh, g, dh)
    f32 — inference-only (decode holds no gradients), like
    `quant_matmul_op`. The split-K online-softmax kernel lives in
    `kernels.decode_attn`; the xla-ref backend runs the legacy einsum
    composition (`ref.decode_attn_ref`) bit-for-bit."""
    backend = dispatch.resolve(backend, interpret)
    if introspect.recording():
        # record the compiled-TPU tile geometry regardless of which
        # backend this trace routes to (see kernels.introspect)
        B, KVh, g, dh = q.shape
        gp, dhp, ch = _da.plan_tiles(g, dh, k.shape[1], chunk)
        introspect.note(introspect.AttnLaunch(
            kind="decode_attn", B=B, KVh=KVh, g=g, dh=dh, gp=gp, dhp=dhp,
            chunk=ch, kv_itemsize=k.dtype.itemsize))
    if backend == "xla-ref":
        return _ref.decode_attn_ref(q, k, v, pos, window=window)
    return _da.decode_attn_pallas(q, k, v, pos, window=window, chunk=chunk,
                                  interpret=(backend == "pallas-interpret"))


def paged_decode_attn_op(q, kpool, vpool, pos, page_table, *, page_size,
                         seq_len, kv_bits=None, k_scale=None, v_scale=None,
                         window=0, interpret=None, backend=None):
    """Single-query flash-decode attention over a *paged* KV pool.

    kpool/vpool: (n_pages, page_size, KVh, dh) pool pages shared by every
    slot — or int8 codes (byte width dh for kv_bits=8, dh//2 nibble pairs
    for kv_bits=4) with per-row f32 scales k_scale/v_scale of shape
    (n_pages, page_size, KVh), decoded in VMEM by the kernel.
    page_table: (B, Lp) int32 logical->physical map; unallocated logical
    pages alias the reserved zero page. `seq_len` is the logical arena
    length the contiguous engine would use — the valid mask is the same
    min(pos+1, seq_len) rule, and the xla-ref backend's gathered view is
    sliced to exactly `seq_len` rows so an unquantized paged engine is
    bit-identical to the contiguous one (see `ref.paged_decode_attn_ref`).
    """
    backend = dispatch.resolve(backend, interpret)
    if introspect.recording():
        B, KVh, g, dh = q.shape
        gp, dhp, _ = _da.plan_paged_tiles(g, dh, kpool.shape[-1], kv_bits)
        introspect.note(introspect.AttnLaunch(
            kind="paged_decode_attn", B=B, KVh=KVh, g=g, dh=dh, gp=gp,
            dhp=dhp, chunk=int(page_size), kv_itemsize=kpool.dtype.itemsize,
            scaled=kv_bits is not None))
    if backend == "xla-ref":
        return _ref.paged_decode_attn_ref(
            q, kpool, vpool, pos, page_table, page_size=page_size,
            seq_len=seq_len, kv_bits=kv_bits, k_scale=k_scale,
            v_scale=v_scale, window=window)
    return _da.paged_decode_attn_pallas(
        q, kpool, vpool, pos, page_table, page_size=page_size,
        seq_len=seq_len, kv_bits=kv_bits, k_scale=k_scale, v_scale=v_scale,
        window=window, interpret=(backend == "pallas-interpret"))


# ------------------------------------------- fused fake-quant (+mask) matmul
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _fq_matmul(x, w, d, q_m, t, backend):
    return _gc.gemm(x, w, (_gc.fake_quant_rhs(d, q_m, t),), backend=backend)


def _fqm_fwd(x, w, d, q_m, t, backend):
    return _fq_matmul(x, w, d, q_m, t, backend), (x, w, d, q_m, t)


def _fq_weight_grads(w, d, q_m, t, dwq):
    """Route the weight cotangent through the quantizer's STE VJP
    (Eqs 4-6 for the scalars, clip-gated identity for w)."""
    _, vjp = jax.vjp(_fake_quant_xla, w, d, q_m, t)
    return vjp(dwq.astype(w.dtype))


def _fqm_bwd(backend, res, g):
    x, w, d, q_m, t = res
    # dx = g @ fake_quant(w).T; fake_quant is elementwise, so the transpose
    # commutes and the quantizer stays fused into the RHS tile load.
    fq = _gc.fake_quant_rhs(d, q_m, t)
    dx = _gc.gemm(g, w.T, (fq,), backend=backend, out_dtype=x.dtype)
    dwq = _gc.gemm(x.T, g, (), backend=backend, out_dtype=jnp.float32)
    dw, dd, dqm, dt = _fq_weight_grads(w, d, q_m, t, dwq)
    return dx, dw, dd, dqm, dt


_fq_matmul.defvjp(_fqm_fwd, _fqm_bwd)


def fq_matmul_op(x, w, d, q_m, t, *, interpret=None, backend=None):
    """y = x @ fake_quant(w; d, q_m, t) in one HBM pass of W.

    Backward: STE through the quantizer (via `core.quant.fake_quant`'s VJP,
    Eqs 4-6 for the scalars)."""
    return _fq_matmul(x, w, d, q_m, t, dispatch.resolve(backend, interpret))


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _fq_masked_matmul(x, w, mask, d, q_m, t, backend):
    return _gc.gemm(x, w, _gc.fq_mask_ops(d, q_m, t, mask), backend=backend)


def _fqmm_fwd(x, w, mask, d, q_m, t, backend):
    y = _fq_masked_matmul(x, w, mask, d, q_m, t, backend)
    return y, (x, w, mask, d, q_m, t)


def _fqmm_bwd(backend, res, g):
    x, w, mask, d, q_m, t = res
    # dx = g @ (fq(w)∘mask).T = (g∘mask) @ fq(w.T);
    # dwq = x.T @ g ∘ mask    = x.T @ (g∘mask).
    gm = (g.astype(jnp.float32) * mask.astype(jnp.float32)[None, :]
          ).astype(g.dtype)
    fq = _gc.fake_quant_rhs(d, q_m, t)
    dx = _gc.gemm(gm, w.T, (fq,), backend=backend, out_dtype=x.dtype)
    dwq = _gc.gemm(x.T, gm, (), backend=backend, out_dtype=jnp.float32)
    dw, dd, dqm, dt = _fq_weight_grads(w, d, q_m, t, dwq)
    return dx, dw, jnp.zeros_like(mask), dd, dqm, dt


_fq_masked_matmul.defvjp(_fqmm_fwd, _fqmm_bwd)


def fq_masked_matmul_op(x, w, mask, d, q_m, t, *, interpret=None,
                        backend=None):
    """y = x @ (fake_quant(w; d, q_m, t) * mask[None, :]).

    The GETA joint-stage forward in a single HBM pass of W (vs three for
    quantize -> mask -> matmul). Mask cotangent is 0 (decay schedule)."""
    return _fq_masked_matmul(x, w, mask, d, q_m, t,
                             dispatch.resolve(backend, interpret))


# Re-export oracles for tests/benchmarks.
decode_attn_ref = _ref.decode_attn_ref
paged_decode_attn_ref = _ref.paged_decode_attn_ref
fake_quant_fwd_ref = _ref.fake_quant_fwd_ref
fake_quant_bwd_ref = _ref.fake_quant_bwd_ref
matmul_ref = _ref.matmul_ref
masked_matmul_ref = _ref.masked_matmul_ref
quant_matmul_ref = _ref.quant_matmul_ref
packed_quant_matmul_ref = _ref.packed_quant_matmul_ref
fq_matmul_ref = _ref.fq_matmul_ref

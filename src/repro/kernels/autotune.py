"""(bm, bn, bk) block-size autotuner for the shared GEMM core.

`DEFAULT_BLOCKS = (128, 128, 128)` was tuned for full-width checkpoint
shapes. TP serving divides every projection's N by the mesh size and
pruning shrinks K/N further, so the hot GEMMs move to a corner of shape
space where a different tile wins (small-N shards want deeper bk; tall
packed streams want the word-aligned bk the core already forces). This
module closes that gap without touching call sites:

  * `gemm(..., blocks=None)` (the new default) consults `lookup()` — a
    per-(M, N, K, epilogue, backend) table — and falls back to
    `DEFAULT_BLOCKS` on a miss. Zero overhead beyond one dict probe per
    *trace* (the probe happens at trace time; compiled dispatches never
    see it).
  * `autotune_gemm(x, w, ops)` times the candidate tile set for one
    concrete GEMM, records the winner, and persists the table as JSON so
    a deployment tunes once and every later process starts warm.

The cache file lives at ``REPRO_GEMM_TUNE_CACHE`` (env var; unset means
in-memory only — tests and CI stay hermetic unless they opt in).

Keys are strings ``"MxNxK|op1+op2|backend"`` — N is the *local* width, so
a TP shard and the full-width GEMM tune independently, which is the whole
point. Only the compiled Pallas backends are worth tuning; `xla-ref`
ignores blocks and `autotune_gemm` refuses it.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional, Sequence

import jax
import numpy as np

ENV_VAR = "REPRO_GEMM_TUNE_CACHE"

# key -> (bm, bn, bk); lazily seeded from the cache file on first use.
_memory: dict[str, tuple[int, int, int]] = {}
_loaded_from: Optional[str] = None


def cache_path() -> Optional[str]:
    return os.environ.get(ENV_VAR) or None


def ops_key(rhs_ops: Sequence) -> str:
    """Epilogue identity for the cache key: op names in application order.

    Operand *values* (scales, masks) don't change the tiling economics;
    op structure (packed word streams, extra COL loads) does — and the
    names encode it (`unpack_dequant_b4` vs `dequant` vs `col_mask`)."""
    return "+".join(op.name for op in rhs_ops) or "dense"


def _key(M: int, N: int, K: int, ops: str, backend: str) -> str:
    return f"{M}x{N}x{K}|{ops}|{backend}"


def clear(*, memory_only: bool = True) -> None:
    """Drop the in-memory table (tests). The file is never deleted."""
    global _loaded_from
    _memory.clear()
    _loaded_from = None
    del memory_only


def load(path: Optional[str] = None) -> dict[str, tuple[int, int, int]]:
    """Merge the persisted table (if any) into memory and return it."""
    global _loaded_from
    path = path or cache_path()
    if path and os.path.exists(path) and _loaded_from != path:
        try:
            with open(path) as f:
                raw = json.load(f)
            for k, v in raw.get("blocks", {}).items():
                _memory.setdefault(k, tuple(int(b) for b in v))
            _loaded_from = path
        except (json.JSONDecodeError, OSError, TypeError, ValueError):
            pass    # a corrupt cache must never break serving
    return dict(_memory)


def save(path: Optional[str] = None) -> Optional[str]:
    path = path or cache_path()
    if not path:
        return None
    payload = {"format": "repro-gemm-tune-v1",
               "blocks": {k: list(v) for k, v in sorted(_memory.items())}}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def lookup(M: int, N: int, K: int, ops: str, backend: str
           ) -> Optional[tuple[int, int, int]]:
    if cache_path() and _loaded_from != cache_path():
        load()
    return _memory.get(_key(M, N, K, ops, backend))


def record(M: int, N: int, K: int, ops: str, backend: str,
           blocks: tuple[int, int, int], *, persist: bool = True
           ) -> None:
    _memory[_key(M, N, K, ops, backend)] = tuple(int(b) for b in blocks)
    if persist:
        save()


def candidate_blocks(M: int, N: int, K: int
                     ) -> list[tuple[int, int, int]]:
    """The tile grid worth timing for an (M, N, K) problem.

    Runs every candidate through `gemm_core._clamp_blocks` first and
    dedups, so a 4×128×256 decode GEMM times ~3 configs, not 36 — the
    clamp collapses everything the shape can't distinguish."""
    from repro.kernels import gemm_core
    out, seen = [], set()
    for bm in (32, 64, 128, 256):
        for bn in (128, 256, 512):
            for bk in (128, 256, 512):
                b = gemm_core._clamp_blocks((bm, bn, bk), M, N, K)
                if b not in seen:
                    seen.add(b)
                    out.append(b)
    return out


def vmem_filter(candidates, M: int, N: int, K: int, rhs_ops=(), *,
                w_itemsize: int = 4, budget: Optional[int] = None):
    """Split candidate (bm, bn, bk) tiles by the static VMEM model.

    Each candidate resolves through `gemm_core.plan_blocks` — the exact
    tile `gemm` would launch — and its footprint is estimated by
    `introspect.gemm_vmem_bytes`. Returns (fits, rejected) where
    `rejected` maps the candidate to its estimated bytes; `budget`
    defaults to `introspect.VMEM_BUDGET_BYTES` (~16 MiB/core)."""
    from repro.kernels import gemm_core, introspect
    k_pack = rhs_ops[0].k_pack if rhs_ops else 1
    n_col = sum(kk == gemm_core.COL for op in rhs_ops for kk in op.kinds)
    n_scalar = sum(kk == gemm_core.SCALAR
                   for op in rhs_ops for kk in op.kinds)
    budget = budget or introspect.VMEM_BUDGET_BYTES
    fits, rejected = [], {}
    for blocks in candidates:
        plan = gemm_core.plan_blocks(M, N, K, k_pack, tuple(blocks))
        nbytes = introspect.gemm_vmem_bytes(introspect.GemmLaunch(
            M=M, N=N, K=K, k_pack=k_pack, n_col=n_col, n_scalar=n_scalar,
            ops=ops_key(rhs_ops), backend="static", blocks=plan,
            w_itemsize=w_itemsize))
        if nbytes > budget:
            rejected[tuple(blocks)] = nbytes
        else:
            fits.append(tuple(blocks))
    return fits, rejected


def autotune_gemm(x, w, rhs_ops=(), *, backend: Optional[str] = None,
                  candidates=None, repeats: int = 3, out_dtype=None,
                  persist: bool = True, vmem_budget: Optional[int] = None):
    """Time `gemm` over the candidate tiles, record + return the winner.

    Returns (best_blocks, {blocks: seconds}). Each candidate is compiled
    once (untimed) then timed best-of-`repeats` with blocked dispatches.
    The winner lands in the in-memory table immediately — the very next
    `gemm(..., blocks=None)` trace of this shape picks it up — and in the
    cache file when ``REPRO_GEMM_TUNE_CACHE`` is set and `persist`.

    Candidates whose static VMEM footprint exceeds `vmem_budget`
    (default: the ~16 MiB/core TPU budget) are dropped *before* timing —
    a tile that would OOM real VMEM must not win a CPU-interpret race."""
    from repro.kernels import dispatch, gemm_core
    backend = dispatch.resolve(backend)
    if backend == "xla-ref":
        raise ValueError("autotune_gemm tunes the Pallas tiling; xla-ref "
                         "ignores blocks — nothing to tune")
    M, K = x.shape
    k_pack = rhs_ops[0].k_pack if rhs_ops else 1
    N = w.shape[1]
    K_logical = K if k_pack == 1 else K    # x carries logical K already
    cands = list(candidates or candidate_blocks(M, N, K_logical))
    cands, rejected = vmem_filter(cands, M, N, K_logical, rhs_ops,
                                  w_itemsize=w.dtype.itemsize,
                                  budget=vmem_budget)
    if not cands:
        raise ValueError(
            f"every candidate tile exceeds the VMEM budget "
            f"({ {k: v for k, v in sorted(rejected.items())} })")
    timings: dict[tuple[int, int, int], float] = {}
    for blocks in cands:
        fn = jax.jit(lambda a, b: gemm_core.gemm(
            a, b, tuple(rhs_ops), blocks=blocks, backend=backend,
            out_dtype=out_dtype))
        jax.block_until_ready(fn(x, w))           # compile, untimed
        best = np.inf
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, w))
            best = min(best, time.perf_counter() - t0)
        timings[blocks] = best
    winner = min(timings, key=timings.get)
    record(M, N, K_logical, ops_key(rhs_ops), backend, winner,
           persist=persist)
    return winner, timings

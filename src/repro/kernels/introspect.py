"""Static launch introspection + VMEM footprint models for the kernels.

The static verifier (`repro.analysis`) needs to know, for a traced engine
entry point, which Pallas launches the trace would dispatch on a TPU and
at what tile geometry — *without* running anything and *without* a TPU:
on CPU the dispatch layer routes every op to the xla-ref oracle, so the
Pallas wrappers themselves never execute. The hooks therefore live at the
dispatch layer (`ops.decode_attn_op`, `gemm_core.gemm`), *after* block
resolution but *before* the backend branch: every backend records the
tile the compiled-TPU path would use.

Recording is off by default and costs one `is None` check per traced op.
`record_launches()` turns it on for the duration of a trace:

    with introspect.record_launches() as launches:
        jax.make_jaxpr(engine._decode)(params, qparams, caches, tok, pos)
    # launches: [GemmLaunch(...), AttnLaunch(...), ...]

The VMEM byte models below are deliberately simple upper-estimate
arithmetic over the block specs (2x double-buffering on grid-streamed
blocks, accumulator + scratch resident, decoded packed tile materialized
in-VMEM) against the ~16 MiB/core budget from the Pallas TPU guide. They
are used by the analysis `vmem` pass *and* by `autotune.autotune_gemm` to
refuse timing candidate tiles that could not fit on real hardware.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

# Per-core VMEM on current TPU generations (the Pallas guide's planning
# number). The budget below leaves headroom for compiler-managed
# temporaries; tiles past it are rejected statically.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_BUDGET_BYTES = VMEM_BYTES

_F32 = 4
_LANES = 128        # mirror of decode_attn._LANES (scratch minor dim)


@dataclasses.dataclass(frozen=True)
class GemmLaunch:
    """One `gemm_core.gemm` dispatch: logical shape + resolved tile."""
    M: int
    N: int
    K: int
    k_pack: int                       # codes per int32 word (1 = unpacked)
    n_col: int                        # COL (1, bn) operand count
    n_scalar: int                     # SCALAR (1, 1) operand count
    ops: str                          # autotune.ops_key epilogue identity
    backend: str
    blocks: tuple[int, int, int, int]  # (bm, bn, bk, bkw) final tile
    w_itemsize: int = 4

    kind = "gemm"

    def describe(self) -> str:
        bm, bn, bk, bkw = self.blocks
        return (f"gemm {self.M}x{self.N}x{self.K}|{self.ops} "
                f"tile bm={bm} bn={bn} bk={bk} bkw={bkw}")


@dataclasses.dataclass(frozen=True)
class AttnLaunch:
    """One flash-decode attention dispatch (contiguous or paged)."""
    kind: str                         # "decode_attn" | "paged_decode_attn"
    B: int
    KVh: int
    g: int                            # query heads per KV head
    dh: int
    gp: int                           # padded block dims (compiled align)
    dhp: int
    chunk: int                        # K rows per grid step (page_size
    #                                   for the paged kernel)
    kv_itemsize: int = 4              # pool element bytes (1 for codes)
    scaled: bool = False              # per-row scale blocks ride along

    def describe(self) -> str:
        return (f"{self.kind} B={self.B} KVh={self.KVh} g={self.g} "
                f"dh={self.dh} tile gp={self.gp} dhp={self.dhp} "
                f"chunk={self.chunk}")


_records: Optional[list] = None


@contextlib.contextmanager
def record_launches():
    """Collect every kernel-dispatch note issued while tracing inside the
    block. Reentrant use shares the innermost list (the analysis registry
    traces one entry at a time)."""
    global _records
    prev = _records
    _records = [] if prev is None else prev
    try:
        yield _records
    finally:
        _records = prev


def recording() -> bool:
    return _records is not None


def note(launch) -> None:
    if _records is not None:
        _records.append(launch)


# ------------------------------------------------------- VMEM byte models
def gemm_vmem_bytes(launch: GemmLaunch) -> int:
    """Estimated VMEM bytes for one gemm tile-program.

    2x double-buffering on the streamed input blocks (x, w, COL/SCALAR
    operands), the f32 output accumulator (kept 2x: the (i, j) revisit
    pattern still overlaps the next block's prologue), plus — when the
    RHS is a packed word stream — the decoded f32 (bk, bn) tile the
    unpack epilogue materializes before the dot."""
    bm, bn, bk, bkw = launch.blocks
    x_tile = bm * bk * _F32
    w_tile = bkw * bn * launch.w_itemsize
    operands = launch.n_col * bn * _F32 + launch.n_scalar * _F32
    out_tile = bm * bn * _F32
    decoded = bk * bn * _F32 if launch.k_pack > 1 else 0
    return 2 * (x_tile + w_tile + operands) + 2 * out_tile + decoded


def attn_vmem_bytes(launch: AttnLaunch) -> int:
    """Estimated VMEM bytes for one flash-decode tile-program: q and out
    blocks, double-buffered K/V chunk blocks (+ per-row scales when the
    pool holds codes), the running max/denom scratch, and the (gp, chunk)
    f32 probability tile the online softmax materializes per chunk."""
    q_tile = launch.gp * launch.dhp * _F32
    kv = 2 * launch.chunk * launch.dhp * launch.kv_itemsize
    scales = 2 * launch.chunk * _F32 if launch.scaled else 0
    out_tile = launch.gp * launch.dhp * _F32
    scratch = 2 * launch.gp * _LANES * _F32
    probs = launch.gp * launch.chunk * _F32
    return 2 * (q_tile + kv + scales) + 2 * out_tile + scratch + probs


def launch_vmem_bytes(launch) -> int:
    if isinstance(launch, GemmLaunch):
        return gemm_vmem_bytes(launch)
    if isinstance(launch, AttnLaunch):
        return attn_vmem_bytes(launch)
    raise TypeError(f"not a launch record: {launch!r}")


def over_budget(launch, budget: Optional[int] = None) -> bool:
    return launch_vmem_bytes(launch) > (budget or VMEM_BUDGET_BYTES)

"""Fused fake-quant Pallas TPU kernel (forward + backward).

The paper applies the (d, q_m, t)-parameterized quantizer (Eqs 1-2) to every
weight and activation tensor. In eager frameworks this is a chain of ~8
elementwise HLOs, each a full HBM round-trip; on TPU we fuse the whole chain
into one VMEM-tiled pass.

Forward:   y = d * round(clip_{q_m}^t(|x|) / d) * sgn(x)
Backward:  dx (STE, zero outside the clip) plus *tile-local partial sums*
           for the three scalar gradients (Eqs 4-6). Each grid step writes
           its partial (dd, dq_m, dt) into a (grid_m, grid_n, 3) output that
           the wrapper reduces — this keeps the kernel embarrassingly
           parallel with no cross-tile accumulation hazards.

Tiling: (block_m, 128·k) blocks — the VPU operates on (8, 128) vregs, so the
last dim stays a multiple of 128 and the second-to-last a multiple of 8.
Scalars (d, q_m, t) are passed as (1, 1) blocks mapped to every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12
DEFAULT_BLOCK = (256, 512)


def _fwd_kernel(x_ref, d_ref, qm_ref, t_ref, y_ref):
    x = x_ref[...].astype(jnp.float32)
    d = jnp.maximum(d_ref[0, 0], _EPS)
    qm = jnp.maximum(qm_ref[0, 0], _EPS)
    t = t_ref[0, 0]

    ax = jnp.abs(x)
    sign = jnp.sign(x)
    a = jnp.minimum(ax, qm)
    xt = jnp.exp(t * jnp.log(jnp.maximum(a, _EPS))) * (ax > 0)
    y = d * jnp.round(xt / d) * sign
    y_ref[...] = y.astype(y_ref.dtype)


def _bwd_kernel(x_ref, d_ref, qm_ref, t_ref, g_ref, dx_ref, partial_ref):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    d = jnp.maximum(d_ref[0, 0], _EPS)
    qm = jnp.maximum(qm_ref[0, 0], _EPS)
    t = t_ref[0, 0]

    ax = jnp.abs(x)
    sign = jnp.sign(x)
    inside = ax <= qm
    safe_ax = jnp.maximum(ax, _EPS)

    # dx: straight-through inside the clip range.
    dx_ref[...] = jnp.where(inside, g, 0.0).astype(dx_ref.dtype)

    # Shared shaped magnitude clip^t(|x|).
    a = jnp.minimum(ax, qm)
    xt = jnp.exp(t * jnp.log(jnp.maximum(a, _EPS))) * (ax > 0)

    # Eq (4): round(v) - v with v = clip^t / d.
    v = xt / d
    dd = jnp.sum(g * sign * (jnp.round(v) - v))

    # Eq (5): clip^t * log(clip_base), base = |x| inside, q_m outside.
    base = jnp.where(inside, safe_ax, qm)
    dt = jnp.sum(g * sign * jnp.exp(t * jnp.log(base)) * jnp.log(base))

    # Eq (6): 0 inside, t * q_m^{t-1} outside.
    dqm = jnp.sum(
        g * jnp.where(inside, 0.0, sign * t * jnp.exp((t - 1.0) * jnp.log(qm)))
    )

    # One 128-lane row per grid step (TPU-tileable; lanes 0..2 carry the
    # three scalar partials, the rest are zero).
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, 128), 1)
    row = jnp.where(lane == 0, dd,
                    jnp.where(lane == 1, dqm,
                              jnp.where(lane == 2, dt, 0.0)))
    partial_ref[...] = row


def _pad_to_2d(x):
    """Kernels tile a 2D view; fold leading dims, pad to block multiples."""
    shape = x.shape
    if x.ndim == 1:
        x2 = x.reshape(1, -1)
    else:
        x2 = x.reshape(-1, shape[-1])
    return x2, shape


def _block_for(shape2d, block):
    bm = min(block[0], max(8, shape2d[0]))
    bn = min(block[1], max(128, shape2d[1]))
    return bm, bn


def _pad(x2, bm, bn):
    m, n = x2.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        x2 = jnp.pad(x2, ((0, pm), (0, pn)))
    return x2


def fake_quant_fwd_pallas(x, d, q_m, t, *, block=DEFAULT_BLOCK, interpret=False):
    x2, orig_shape = _pad_to_2d(x)
    bm, bn = _block_for(x2.shape, block)
    xp = _pad(x2, bm, bn)
    m, n = xp.shape
    grid = (m // bm, n // bn)
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    sspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))

    y = pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            sspec, sspec, sspec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(xp, scal(d), scal(q_m), scal(t))
    return y[: x2.shape[0], : x2.shape[1]].reshape(orig_shape)


def fake_quant_bwd_pallas(x, d, q_m, t, g, *, block=DEFAULT_BLOCK,
                          interpret=False):
    x2, orig_shape = _pad_to_2d(x)
    g2, _ = _pad_to_2d(g)
    bm, bn = _block_for(x2.shape, block)
    xp = _pad(x2, bm, bn)
    gp = _pad(g2, bm, bn)
    m, n = xp.shape
    grid = (m // bm, n // bn)
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    sspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))

    gn = grid[1]
    dx, partials = pl.pallas_call(
        _bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((grid[0] * grid[1], 128), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            sspec, sspec, sspec,
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 128), lambda i, j: (i * gn + j, 0)),
        ),
        interpret=interpret,
    )(xp, scal(d), scal(q_m), scal(t), gp)

    dx = dx[: x2.shape[0], : x2.shape[1]].reshape(orig_shape)
    sums = jnp.sum(partials, axis=0)
    return dx, sums[0], sums[1], sums[2]

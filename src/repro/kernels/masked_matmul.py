"""Structured-pruning-aware matmul: a thin epilogue config over the core.

During GETA's joint stage, redundant parameter groups are progressively
forgotten; training computes `x @ (w * mask_cols)` where `mask_cols` zeroes
entire output columns (minimally removable structures). Materializing the
masked weight costs a full HBM write + read of W per step; the `col_mask`
RHS op fuses the mask into the RHS tile load instead, so W streams
HBM->VMEM once and the mask (a tiny (N,) vector) rides along in VMEM.

All tiling/padding lives in `gemm_core.gemm` — this module only names the
op configuration (kept as a module for the legacy import path).
"""
from __future__ import annotations

from repro.kernels import dispatch
from repro.kernels.gemm_core import DEFAULT_BLOCKS, col_mask, gemm


def masked_matmul_pallas(x, w, mask, *, blocks=DEFAULT_BLOCKS,
                         interpret=None, backend=None):
    """y[m, n] = sum_k x[m, k] * w[k, n] * mask[n].

    x: (M, K), w: (K, N), mask: (N,) in {0, 1} (or soft decay factors).
    """
    return gemm(x, w, (col_mask(mask),), blocks=blocks,
                backend=dispatch.resolve(backend, interpret))

"""Structured-pruning-aware matmul Pallas kernel.

During GETA's joint stage, redundant parameter groups are progressively
forgotten; training computes `x @ (w * mask_cols)` where `mask_cols` zeroes
entire output columns (minimally removable structures). Materializing the
masked weight costs a full HBM write + read of W per step; this kernel fuses
the column mask into the RHS tile load instead, so W streams HBM->VMEM once
and the mask (a tiny (N,) vector) rides along in VMEM.

Blocking: classic (bm, bn, bk) = (128·a, 128·b, 128·c) MXU-aligned tiling,
f32 accumulation in the output block across the K grid dimension (K is the
innermost / fastest-varying grid axis, so revisits of the same (i, j) output
block are consecutive and the accumulator pattern is valid on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCKS = (128, 128, 128)  # bm, bn, bk


def _masked_matmul_kernel(x_ref, w_ref, m_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    mask = m_ref[...].astype(jnp.float32)  # (1, bn) block of column mask
    w = w * mask  # broadcast over K rows of the tile
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def masked_matmul_pallas(x, w, mask, *, blocks=DEFAULT_BLOCKS, interpret=False):
    """y[m, n] = sum_k x[m, k] * w[k, n] * mask[n].

    x: (M, K), w: (K, N), mask: (N,) in {0, 1} (or soft decay factors).
    Pads every dim to block multiples; output sliced back.
    """
    bm, bn, bk = blocks
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm = min(bm, max(8, M))
    bn = min(bn, max(128, N))
    bk = min(bk, max(128, K))

    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    wp = jnp.pad(w, ((0, pk), (0, pn))) if (pk or pn) else w
    mp = jnp.pad(mask, (0, pn)) if pn else mask
    mp = mp.reshape(1, -1)
    Mp, Kp = xp.shape
    Np = wp.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)

    y = pl.pallas_call(
        _masked_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=interpret,
    )(xp, wp, mp)
    return y[:M, :N].astype(x.dtype)

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the source of truth the kernel tests assert against
(`assert_allclose(kernel(x), ref(x))` over shape/dtype sweeps).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.quant import _EPS, clip_qmt


def fake_quant_fwd_ref(x, d, q_m, t):
    """Eqs (1)-(2): nonlinear clip + symmetric uniform quantize-dequantize."""
    d32 = jnp.maximum(jnp.asarray(d, jnp.float32), _EPS)
    qm32 = jnp.asarray(q_m, jnp.float32)
    t32 = jnp.asarray(t, jnp.float32)
    sign = jnp.sign(x).astype(jnp.float32)
    xt = clip_qmt(jnp.abs(x).astype(jnp.float32), qm32, t32)
    return (d32 * jnp.round(xt / d32) * sign).astype(x.dtype)


def fake_quant_bwd_ref(x, d, q_m, t, g):
    """Eqs (4)-(6) + STE dx. Returns (dx, dd, dq_m, dt) with scalar reductions."""
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    d32 = jnp.maximum(jnp.asarray(d, jnp.float32), _EPS)
    qm32 = jnp.maximum(jnp.asarray(q_m, jnp.float32), _EPS)
    t32 = jnp.asarray(t, jnp.float32)

    ax = jnp.abs(x32)
    sign = jnp.sign(x32)
    inside = ax <= qm32
    safe_ax = jnp.maximum(ax, _EPS)

    dx = jnp.where(inside, g32, 0.0).astype(x.dtype)

    v = clip_qmt(ax, qm32, t32) / d32
    dd = jnp.sum(g32 * sign * (jnp.round(v) - v))

    base = jnp.where(inside, safe_ax, qm32)
    dt = jnp.sum(g32 * sign * jnp.power(base, t32) * jnp.log(base))

    dqm = jnp.sum(
        g32 * jnp.where(inside, 0.0, sign * t32 * jnp.power(qm32, t32 - 1.0))
    )
    return dx, dd, dqm, dt


def matmul_ref(x, w):
    """Plain dense y = x @ w at f32 accumulation."""
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def masked_matmul_ref(x, w, mask):
    """y = x @ (w * mask[None, :]) — structured column (group) masking."""
    w32 = w.astype(jnp.float32) * mask.astype(jnp.float32)[None, :]
    return (x.astype(jnp.float32) @ w32).astype(x.dtype)


def fq_matmul_ref(x, w, d, q_m, t, mask=None):
    """y = x @ (fake_quant(w) * mask) — the fused GETA joint-stage forward."""
    wq = fake_quant_fwd_ref(w, d, q_m, t).astype(jnp.float32)
    if mask is not None:
        wq = wq * mask.astype(jnp.float32)[None, :]
    return (x.astype(jnp.float32) @ wq).astype(x.dtype)


def quant_matmul_ref(x, codes, scale):
    """y = x @ (codes * scale[None, :]) — int8 weights, per-column scales."""
    w = codes.astype(jnp.float32) * scale.astype(jnp.float32)[None, :]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def decode_attn_ref(q, k, v, pos, *, window=0):
    """Single-query attention over the slot KV arena — the oracle for
    `kernels.decode_attn`.

    q: (B, KVh, g, dh) query heads grouped per KV head; k/v: (B, S, KVh,
    dh) arena rows (current token already written); pos: (B,) int32. Row
    b attends over its min(pos[b] + 1, S) written arena rows — rows
    [0, pos] of a full arena, or the whole ring once a windowed arena
    wraps (attention is permutation-invariant over KV rows, so ring
    storage order is irrelevant). `window` is accepted for interface
    symmetry; the min(pos+1, S) rule already covers both arena kinds.

    Deliberately the exact einsum/softmax composition of the legacy
    `attn_apply` decode branch (same ops, same order), so the xla-ref
    backend is bit-identical to the pre-kernel path and the engine's
    kernel-on-vs-off token-identity smoke is exact, not approximate.
    """
    del window
    B, KVh, g, dh = q.shape
    S = k.shape[1]
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    qh = q.reshape(B, 1, KVh, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    valid = (jnp.arange(S)[None, :]
             < jnp.minimum(pos + 1, S)[:, None])
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, KVh, g, dh)


def paged_decode_attn_ref(q, kpool, vpool, pos, page_table, *, page_size,
                          seq_len, kv_bits=None, k_scale=None, v_scale=None,
                          window=0):
    """Single-query attention over a paged KV pool — the oracle for the
    page-indirect flash-decode kernel.

    kpool/vpool: (n_pages, page_size, KVh, dh) pool pages (or int8 codes
    of width dh / dh//2 for kv_bits 8 / 4, with per-row scales
    k_scale/v_scale of shape (n_pages, page_size, KVh)); page_table:
    (B, Lp) int32 logical->physical page map per slot. Gathers each
    slot's pages, dequantizes if the pool is quantized, and slices the
    flattened rows to `seq_len` — the contiguous arena length — before
    delegating to `decode_attn_ref`.

    The slice is load-bearing for the paged-vs-contiguous token-identity
    contract: XLA's reduction grouping varies with the reduced length,
    so attention over Lp*page_size rows (trailing zeros included) is not
    bitwise the same as over seq_len rows even though the extra columns
    carry zero probability. With the slice, an unquantized pool's
    gathered view is bitwise the contiguous arena (unallocated logical
    pages alias the zero page, matching the arena's zero-init tail) and
    this function reduces to the exact legacy composition.
    """
    del window
    pt = jnp.asarray(page_table, jnp.int32)
    B, Lp = pt.shape
    P = int(page_size)
    if Lp * P < seq_len:
        raise ValueError(f"page table covers {Lp * P} rows < seq_len {seq_len}")

    def gather(pool, scale):
        pages = jnp.take(pool, pt, axis=0)        # (B, Lp, P, KVh, dh*)
        if kv_bits is not None:
            from repro.core.quant import kv_quant_decode
            pages = kv_quant_decode(pages, jnp.take(scale, pt, axis=0),
                                    kv_bits)
        rows = pages.reshape(B, Lp * P, *pages.shape[3:])
        return rows[:, :seq_len]

    return decode_attn_ref(q, gather(kpool, k_scale), gather(vpool, v_scale),
                           pos)


def packed_quant_matmul_ref(x, packed, bits, scale):
    """y = x @ (unpack(packed) * scale[None, :]) — sub-byte packed weights.

    packed: (ceil(K / (32//bits)), N) int32 word stream from
    `core.quant.pack_codes` (K-packed); unpacks to the (K, N) codes and
    dequantizes — the oracle for the `unpack_dequant` GEMM epilogue."""
    from repro.core.quant import unpack_codes
    codes = unpack_codes(packed, bits, x.shape[-1], axis=0)
    return quant_matmul_ref(x, codes, scale)

"""Fused flash-decode attention over the slot KV arena (single query).

Decode-time attention is the one hot op the kernel backend didn't own:
`attn_apply`'s decode branch materializes a full-length f32 score tensor
(B, KVh, g, 1, S_max) over the *whole* arena row, masks the unwritten
slots with `jnp.where`, and softmaxes — three HBM round-trips of an array
that grows with max_seq. This kernel streams each cache row once:

  grid = (batch-slot b, KV head h, split-K chunk c)   — c innermost

Chunk programs for one (b, h) run consecutively (the same accumulator
pattern `gemm_core` uses for its K axis), carrying the online-softmax
state across chunks in VMEM scratch:

  m  (g, LANES) f32   running row max of the scores
  l  (g, LANES) f32   running softmax denominator
  o  = the f32 output block itself, holding the *unnormalized*
       rescaled accumulator until the last chunk divides by l.

Per chunk: s = q @ k_chunk^T / sqrt(dh), masked to the slot's valid
length; m_new = max(m, max(s)); both l and o are rescaled by
exp(m - m_new) before accumulating exp(s - m_new) — the standard
flash-attention cross-chunk combine, so any chunking of the cache length
produces the same softmax (the chunk-count invariance test pins this).

GQA: all g = H // KVh query groups of one KV head are computed by a
single program as the (g, dh) LHS of both GEMMs, so the kv tile is read
once per head, not once per query head.

Valid-length / ring-window masking lives *inside* the kernel: a slot at
position `pos` has written exactly n_valid = min(pos + 1, S) arena rows —
rows [0, pos] of a full arena, or the whole ring once `pos` wraps a
windowed (ring_len = S) arena. Attention is permutation-invariant over
KV rows, so the ring's scrambled storage order needs no unscrambling;
columns >= n_valid are masked to -1e30 and chunks that start at or past
n_valid are skipped entirely (`@pl.when`), so scores for unwritten rows
are never computed.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Cache-length rows processed per grid step. 128 keeps the (g, chunk)
# probability tile lane-aligned on the MXU; the wrapper shrinks it for
# short arenas (interpret mode may go as low as 8).
DEFAULT_CHUNK = 128

_NEG_INF = -1e30
_LANES = 128     # scratch minor dim: m/l are logically (g, 1), stored
                 # lane-replicated so the VMEM tile stays MXU-shaped


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _kernel(nv_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            chunk: int, nchunks: int, scale: float):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    n_valid = nv_ref[0, 0]

    @pl.when(c * chunk < n_valid)
    def _chunk():
        q = q_ref[0, 0].astype(jnp.float32)              # (g, dh)
        kt = k_ref[0, :, 0, :].astype(jnp.float32)       # (chunk, dh)
        s = jax.lax.dot_general(
            q, kt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (g, chunk)
        col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + c * chunk
        s = jnp.where(col < n_valid, s, _NEG_INF)
        m_prev = m_ref[:, :1]                            # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                           # (g, chunk)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        vt = v_ref[0, :, 0, :].astype(jnp.float32)       # (chunk, dh)
        pv = jax.lax.dot_general(
            p, vt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (g, dh)
        o_ref[0, 0] = o_ref[0, 0] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(c == nchunks - 1)
    def _final():
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(l_ref[:, :1], 1e-30)


def plan_tiles(g: int, dh: int, S: int, chunk: int | None = None, *,
               align: int = 128) -> tuple[int, int, int]:
    """Resolve the (gp, dhp, chunk) tile geometry the flash-decode kernel
    launches for a (g, dh, S) problem at the given lane alignment (128
    compiled, 8 interpret). Shared by `decode_attn_pallas` and the static
    VMEM model (`kernels.introspect`) so they cannot drift."""
    chunk = int(chunk or DEFAULT_CHUNK)
    chunk = max(align, min(_round_up(chunk, align), _round_up(S, align)))
    return _round_up(g, 8), _round_up(dh, align), chunk


def plan_paged_tiles(g: int, dh: int, dhs: int, kv_bits: int | None, *,
                     align: int = 128) -> tuple[int, int, int]:
    """(gp, dhp, dhsp) for the paged kernel: the stored byte width `dhs`
    pads to the lane tile and nibble unpack doubles it back to >= dh.
    Shared with the static VMEM model like `plan_tiles`."""
    dhsp = _round_up(dhs, align)
    dhp = dhsp * 2 if kv_bits == 4 else dhsp
    return _round_up(g, 8), dhp, dhsp


def decode_attn_pallas(q, k, v, pos, *, window: int = 0,
                       chunk: int | None = None,
                       interpret: bool = False) -> jax.Array:
    """Single-query attention of q over the (k, v) slot arena.

    q:   (B, KVh, g, dh) — the token's query heads, grouped per KV head
         (g = H // KVh; GQA ratio 1 for MHA).
    k,v: (B, S, KVh, dh) — arena rows, post update of the current token.
    pos: (B,) int32 per-slot absolute positions of the token being
         decoded; row b has min(pos[b] + 1, S) valid arena rows (ring
         arenas wrap, full arenas write row `pos` directly — same rule).
    window: the layer's sliding window (static); kept for interface
         symmetry with `attn_apply` — a windowed layer's arena *is* the
         ring (S = ring_len), so the masking rule above already covers it.
    chunk: split-K chunk length along S (default 128, shrunk to cover
         short arenas). Returns (B, KVh, g, dh) f32.
    """
    del window   # the min(pos+1, S) rule covers ring and full arenas
    B, KVh, g, dh = q.shape
    assert k.shape == v.shape == (B, k.shape[1], KVh, dh), (
        q.shape, k.shape, v.shape)
    S = k.shape[1]
    scale = 1.0 / math.sqrt(dh)

    # Compiled TPU tiles want 128-lane alignment; the interpreter (CPU
    # parity tier) runs any shape, so it may tile at the 8-sublane floor.
    align = 8 if interpret else 128
    gp, dhp, chunk = plan_tiles(g, dh, S, chunk, align=align)
    Sp = _round_up(S, chunk)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, gp - g), (0, dhp - dh)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, dhp - dh)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, dhp - dh)))
    nv = jnp.minimum(jnp.asarray(pos, jnp.int32).reshape(B, 1) + 1, S)

    nchunks = Sp // chunk
    grid = (B, KVh, nchunks)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, nchunks=nchunks,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, c: (b, 0)),
            pl.BlockSpec((1, 1, gp, dhp), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, chunk, 1, dhp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, dhp), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, dhp), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVh, gp, dhp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((gp, _LANES), jnp.float32),   # running max m
            pltpu.VMEM((gp, _LANES), jnp.float32),   # running denom l
        ],
        interpret=interpret,
    )(nv, qp, kp, vp)
    return out[:, :, :g, :dh]


def tp_decode_attn(q, k, v, pos, *, mesh, axis: str = "model",
                   window: int = 0, chunk: int | None = None,
                   backend: str | None = None) -> jax.Array:
    """KV-head-parallel flash decode over one mesh axis via `shard_map`.

    Decode attention is embarrassingly parallel over KV heads — softmax
    normalizes within a head and GQA groups ride their KV head — so the
    TP layout splits q on its head axis (1) and the k/v arenas on theirs
    (2), each device runs the single-device kernel over KVh/tp local
    heads, and the (B, KVh, g, dh) output concatenates over heads with
    **no cross-device reduction**: per-head numerics are exactly the
    1-device kernel's. `pos` replicates (it is per-slot, not per-head).

    Like `tp_gemm`, the kernel call sits *inside* shard_map where it is
    partitioned already, so the default backend is
    `dispatch.shard_local_default()` — TPU hosts keep the fused kernel
    under TP instead of the mesh-demoted einsum path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels import ops as _ops
    from repro.kernels import dispatch

    tp = int(mesh.shape[axis])
    KVh = q.shape[1]
    if KVh % tp:
        raise ValueError(f"tp_decode_attn: KVh={KVh} must divide the "
                         f"{axis!r} axis size {tp}")
    backend = backend or dispatch.shard_local_default()

    def body(ql, kl, vl, pl_):
        return _ops.decode_attn_op(ql, kl, vl, pl_, window=window,
                                   chunk=chunk, backend=backend)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(None, None, axis), P(None, None, axis),
                  P()),
        out_specs=P(None, axis), check_rep=False)(q, k, v, pos)


def _page_dequant(w, scale, bits):
    """Decode one int8 code tile (P, dhs) to f32 rows in VMEM.

    Mirrors `core.quant.kv_quant_decode` on a 2D tile: arithmetic-shift
    nibble unpack for bits=4 (low nibble in byte order first), then the
    per-row scale. Zero codes with zero scale stay exact zeros, so pool
    padding and zero-page rows contribute nothing to the dot products.
    """
    x = w.astype(jnp.int32)
    if bits == 4:
        lo = (x << 28) >> 28
        hi = (x << 24) >> 28
        x = jnp.stack([lo, hi], axis=-1).reshape(x.shape[0], x.shape[1] * 2)
    return x.astype(jnp.float32) * scale[:, None]


def _paged_kernel(nv_ref, pt_ref, q_ref, k_ref, v_ref, *rest,
                  chunk: int, nchunks: int, scale: float, kv_bits):
    if kv_bits is not None:
        ks_ref, vs_ref, o_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    n_valid = nv_ref[b]

    @pl.when(c * chunk < n_valid)
    def _chunk():
        q = q_ref[0, 0].astype(jnp.float32)              # (g, dh)
        kt = k_ref[0, :, 0, :]                           # (chunk, dh*)
        vt = v_ref[0, :, 0, :]
        if kv_bits is not None:
            kt = _page_dequant(kt, ks_ref[0, :, 0], kv_bits)
            vt = _page_dequant(vt, vs_ref[0, :, 0], kv_bits)
        else:
            kt = kt.astype(jnp.float32)
            vt = vt.astype(jnp.float32)
        s = jax.lax.dot_general(
            q, kt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (g, chunk)
        col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + c * chunk
        s = jnp.where(col < n_valid, s, _NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, vt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (g, dh)
        o_ref[0, 0] = o_ref[0, 0] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(c == nchunks - 1)
    def _final():
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(l_ref[:, :1], 1e-30)


def paged_decode_attn_pallas(q, kpool, vpool, pos, page_table, *, page_size,
                             seq_len, kv_bits=None, k_scale=None,
                             v_scale=None, window: int = 0,
                             interpret: bool = False) -> jax.Array:
    """Page-indirect flash decode: the split-K grid of `decode_attn_pallas`
    with the K-chunk axis walking *logical pages* and the physical page id
    scalar-prefetched from the slot's page table.

    kpool/vpool: (n_pages, page_size, KVh, dh) pool (dtype rows), or int8
    codes of byte width dh / dh//2 for kv_bits 8 / 4 plus per-row scales
    k_scale/v_scale (n_pages, page_size, KVh) f32, decoded in VMEM right
    after the tile load — the KV analogue of the weight `unpack_dequant`
    epilogue. page_table: (B, Lp) int32; both it and the per-slot valid
    length ride in scalar-prefetch SMEM (`PrefetchScalarGridSpec`), so
    the k/v BlockSpec index map can address tile (pt[b, c], h) directly
    and only a slot's own pages ever stream into VMEM. Chunk = page_size
    (must be a multiple of 8). `seq_len` is the logical arena length;
    masking is the same min(pos+1, seq_len) rule as the contiguous
    kernel. Returns (B, KVh, g, dh) f32.
    """
    del window
    B, KVh, g, dh = q.shape
    P = int(page_size)
    if P % 8:
        raise ValueError(f"page_size must be a multiple of 8, got {P}")
    Lp = page_table.shape[1]
    if Lp * P < seq_len:
        raise ValueError(f"page table covers {Lp * P} rows < seq_len {seq_len}")
    dhs = kpool.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    align = 8 if interpret else 128

    # Pad the code byte stream; nibble unpack doubles it back to >= dh.
    gp, dhp, dhsp = plan_paged_tiles(g, dh, dhs, kv_bits, align=align)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, gp - g), (0, dhp - dh)))
    kp = jnp.pad(kpool, ((0, 0), (0, 0), (0, 0), (0, dhsp - dhs)))
    vp = jnp.pad(vpool, ((0, 0), (0, 0), (0, 0), (0, dhsp - dhs)))
    nv = jnp.minimum(jnp.asarray(pos, jnp.int32).reshape(B) + 1, seq_len)
    pt = jnp.asarray(page_table, jnp.int32)

    def qmap(b, h, c, nv_ref, pt_ref):
        return (b, h, 0, 0)

    def kvmap(b, h, c, nv_ref, pt_ref):
        return (pt_ref[b, c], 0, h, 0)

    def smap(b, h, c, nv_ref, pt_ref):
        return (pt_ref[b, c], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, gp, dhp), qmap),
        pl.BlockSpec((1, P, 1, dhsp), kvmap),
        pl.BlockSpec((1, P, 1, dhsp), kvmap),
    ]
    operands = [qp, kp, vp]
    if kv_bits is not None:
        in_specs += [pl.BlockSpec((1, P, 1), smap),
                     pl.BlockSpec((1, P, 1), smap)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVh, Lp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, gp, dhp), qmap),
        scratch_shapes=[
            pltpu.VMEM((gp, _LANES), jnp.float32),
            pltpu.VMEM((gp, _LANES), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, chunk=P, nchunks=Lp, scale=scale,
                          kv_bits=kv_bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVh, gp, dhp), jnp.float32),
        interpret=interpret,
    )(nv, pt, *operands)
    return out[:, :, :g, :dh]

"""Quantized-weight matmul (serving path): a thin epilogue config.

`construct_subnet()` exports integer weight codes + per-column scales. At
serving time the memory-bound cost of a decode-step matmul is dominated by
streaming W from HBM; storing W as int8 cuts that traffic 2x vs bf16 / 4x
vs f32. The `dequant` RHS op streams int codes HBM->VMEM, dequantizes
*inside* VMEM (codes * scale), and feeds the MXU at f32 accumulation.

This is the TPU-native adaptation of the paper's deployment claim (BOPs
reduction -> real speedups): on GPU one would use INT8 tensor cores; on TPU
v5e the MXU natively multiplies bf16, so the win is realized as HBM
bandwidth reduction — exactly the term that dominates decode rooflines.

All tiling/padding lives in `gemm_core.gemm` — this module only names the
op configuration (kept as a module for the legacy import path).
"""
from __future__ import annotations

from repro.kernels import dispatch
from repro.kernels.gemm_core import DEFAULT_BLOCKS, dequant, gemm


def quant_matmul_pallas(x, codes, scale, *, blocks=DEFAULT_BLOCKS,
                        interpret=None, backend=None):
    """y = x @ (codes * scale[None, :]).

    x: (M, K) float; codes: (K, N) int8/int16/int32; scale: (N,) f32.
    """
    return gemm(x, codes, (dequant(scale),), blocks=blocks,
                backend=dispatch.resolve(backend, interpret),
                out_dtype=x.dtype)

"""Quantized-weight matmul Pallas kernel (serving path).

`construct_subnet()` exports integer weight codes + per-column scales. At
serving time the memory-bound cost of a decode-step matmul is dominated by
streaming W from HBM; storing W as int8 cuts that traffic 2x vs bf16 / 4x vs
f32. This kernel streams int8 codes HBM->VMEM, dequantizes *inside* VMEM
(codes * scale), and feeds the MXU at f32 accumulation.

This is the TPU-native adaptation of the paper's deployment claim (BOPs
reduction -> real speedups): on GPU one would use INT8 tensor cores; on TPU
v5e the MXU natively multiplies bf16, so the win is realized as HBM
bandwidth reduction — exactly the term that dominates decode rooflines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCKS = (128, 128, 128)


def _quant_matmul_kernel(x_ref, c_ref, s_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    codes = c_ref[...].astype(jnp.float32)   # int8 -> f32 in VMEM
    scale = s_ref[...].astype(jnp.float32)   # (1, bn)
    w = codes * scale
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def quant_matmul_pallas(x, codes, scale, *, blocks=DEFAULT_BLOCKS,
                        interpret=False):
    """y = x @ (codes * scale[None, :]).

    x: (M, K) float; codes: (K, N) int8/int32; scale: (N,) f32.
    """
    bm, bn, bk = blocks
    M, K = x.shape
    K2, N = codes.shape
    assert K == K2
    bm = min(bm, max(8, M))
    bn = min(bn, max(128, N))
    bk = min(bk, max(128, K))

    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    cp = jnp.pad(codes, ((0, pk), (0, pn))) if (pk or pn) else codes
    sp = jnp.pad(scale, (0, pn)) if pn else scale
    sp = sp.reshape(1, -1)
    Mp, Kp = xp.shape
    Np = cp.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)

    y = pl.pallas_call(
        _quant_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=interpret,
    )(xp, cp, sp)
    return y[:M, :N].astype(x.dtype)

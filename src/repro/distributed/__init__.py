from repro.distributed.sharding import (DEFAULT_RULES, ShardingPlan,
                                        batch_spec, constrain, make_plan)

"""Distributed-optimization tricks: gradient compression, collective
scheduling helpers.

Gradient compression (int8 + per-block scales, error feedback):
  A bf16 ring all-reduce moves 2*(k-1)/k * N * 2 bytes per link. Replacing
  it with quantize -> all-gather(int8 codes + f32 block scales) -> local
  reduce moves (k-1)/k * N * 1 bytes: a ~4x wire reduction. The error-
  feedback residual (kept in optimizer state) restores convergence. This is
  expressed with shard_map so the collective is explicit in the HLO and the
  roofline's collective term sees the reduction.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # JAX >= 0.6 exports shard_map at the top level
    from jax import shard_map as _shard_map
except ImportError:  # older JAX: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compatible shard_map: the replication-check kwarg was renamed
    (check_rep -> check_vma) across JAX releases; forward whichever the
    installed version accepts."""
    params = inspect.signature(_shard_map).parameters
    if "check_vma" in params:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def replicate_stats(mesh: Mesh | None):
    """Explicit cross-replica reduction point for optimizer statistics.

    Under GSPMD a reduction over a sharded tensor yields partial sums whose
    combine point (and summation order) the partitioner is free to place
    anywhere downstream. QASSO's control decisions — the saliency partition,
    the Eq 16/17 projection stats, cooldown hard-zeroing — must be computed
    from IDENTICAL values on every replica, or replicas silently train
    different subnets. Constraining the statistic to the fully-replicated
    layout on `mesh` pins the all-reduce *here*, before any decision
    consumes it. Identity when mesh is None (single-process training).
    """
    if mesh is None:
        return lambda x: x
    rep = NamedSharding(mesh, P())

    def reduce_fn(x):
        return jax.lax.with_sharding_constraint(x, rep)

    return reduce_fn


BLOCK = 256


def _quantize_blockwise(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (flat, padded to BLOCK) -> (int8 codes, f32 per-block scales)."""
    xb = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _dequantize_blockwise(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return (codes.astype(jnp.float32) * scale).reshape(-1)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-gather + local reduce, semantically a psum over axis_name.

    Call inside shard_map. Wire bytes: N int8 vs 2N bf16 for ring AR."""
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad))
    codes, scale = _quantize_blockwise(flat)
    all_codes = jax.lax.all_gather(codes, axis_name)      # (k, n/B, B) int8
    all_scale = jax.lax.all_gather(scale, axis_name)
    summed = jnp.sum(all_codes.astype(jnp.float32) * all_scale, axis=0)
    return summed.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def compressed_grad_allreduce(grads: Any, mesh: Mesh,
                              axis_names: tuple[str, ...] = ("pod", "data"),
                              error_feedback: Any = None) -> tuple[Any, Any]:
    """All-reduce a gradient pytree with int8 compression + error feedback.

    grads are assumed replicated over `axis_names` *within* the shard_map
    (i.e. per-device microbatch grads). Returns (mean grads, new residuals).
    """
    names = tuple(a for a in axis_names if a in mesh.shape)
    k = 1
    for a in names:
        k *= mesh.shape[a]
    if error_feedback is None:
        error_feedback = jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, ef):
        target = g.astype(jnp.float32) + ef
        n = target.size
        pad = (-n) % BLOCK
        flat = jnp.pad(target.reshape(-1), (0, pad))
        codes, scale = _quantize_blockwise(flat)
        sent = _dequantize_blockwise(codes, scale)[:n].reshape(g.shape)
        new_ef = target - sent
        return sent, new_ef

    pairs = jax.tree_util.tree_map(one, grads, error_feedback)
    sent = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                    is_leaf=lambda x: isinstance(x, tuple))

    def reduce_fn(gs):
        def red(g):
            for a in names:
                g = compressed_psum(g, a)
            return g

        return jax.tree_util.tree_map(red, gs)

    specs = jax.tree_util.tree_map(lambda _: P(), sent)
    reduced = shard_map(reduce_fn, mesh=mesh, in_specs=(specs,),
                        out_specs=specs, check_vma=False)(sent)
    mean = jax.tree_util.tree_map(lambda g: g / k, reduced)
    return mean, new_ef


def moe_ep_constraints(mesh: Mesh):
    """Sharding constraints for the MoE all-to-all path: annotating the
    dispatched activations (E, C, D) with E -> 'model' makes GSPMD lower the
    dispatch/combine einsums to all-to-all over the model axis instead of
    all-gathering the full token buffer (the §Perf MoE hillclimb lever)."""
    from repro.distributed.sharding import constrain

    def fn(xe):
        return constrain(xe, mesh, P("model", None, None))

    return fn

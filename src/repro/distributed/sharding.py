"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Every parameter carries a tuple of logical axis names (emitted by the model
init alongside the pytree). This module maps logical axes -> mesh axes,
checking divisibility and falling back to replication (recorded, never
silent) when a dim does not divide.

Default layout on the production mesh (pod, data, model):
  batch          -> (pod, data)        DP across pods and the data axis
  vocab*, heads, mlp, experts, ...     TP/EP on `model`
  embed          -> (pod, data) iff fsdp=True   (FSDP: params + opt state
                    sharded over the data axes; mandatory for >=100B archs)
  seq            -> (pod, data) for long-context decode (SP)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "vocab": "model",
    "vocab_out": "model",
    "embed": None,               # -> ("pod", "data") when fsdp
    "q_heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "experts_router": "model",
    "expert_mlp": None,
    "mamba_inner": "model",
    "mamba_inner2": "model",
    "mamba_state": None,
    "mamba_lowrank": None,
    "mamba_lowrank_dt": None,
    "rwkv_heads": "model",
    "rwkv_ffn": "model",
    "lora": None,
    "layers": None,
    "conv_k": None,
    "codebooks": None,
    "mix5": None,
    "mix2": None,
}


@dataclasses.dataclass
class ShardingPlan:
    mesh: Mesh
    rules: dict[str, Any]
    fallbacks: list[tuple[str, str, int]]  # (param, axis, dim) replicated

    def spec_for(self, name: str, logical: tuple[str, ...],
                 shape: tuple[int, ...]) -> P:
        parts = []
        used = set()
        for ax_name, dim in zip(logical, shape):
            mesh_ax = self.rules.get(ax_name)
            if mesh_ax is None:
                parts.append(None)
                continue
            axes = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
            axes = tuple(a for a in axes if a in self.mesh.shape)
            size = int(np.prod([self.mesh.shape[a] for a in axes])) if axes \
                else 1
            if size <= 1 or dim % size != 0 or any(a in used for a in axes):
                if size > 1:
                    self.fallbacks.append((name, ax_name, dim))
                parts.append(None)
                continue
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else axes)
        return P(*parts)

    def shardings(self, params_axes: dict[str, tuple],
                  shapes: dict[str, tuple]) -> dict[str, NamedSharding]:
        return {
            name: NamedSharding(self.mesh,
                                self.spec_for(name, ax, shapes[name]))
            for name, ax in params_axes.items()
        }


def make_plan(mesh: Mesh, *, fsdp: bool = False,
              overrides: Optional[dict] = None,
              mode: str = "tp") -> ShardingPlan:
    """mode:
      'tp'   — the baseline layout: DP over (pod, data), TP/EP on `model`
               (+ FSDP over the DP axes when fsdp=True).
      'zero' — pure data parallelism with ZeRO param sharding: batch over
               EVERY mesh axis, params/grads/opt-state sharded over
               (data, model) on their embed/vocab axis, no tensor
               parallelism. The right regime for <=15B dense models where
               TP-16 activation all-reduces dominate the roofline (§Perf).
    """
    rules = dict(DEFAULT_RULES)
    overrides = dict(overrides or {})
    # per-arch knobs that are not axis rules
    overrides.pop("base_optimizer", None)
    if overrides.pop("fsdp", False):
        fsdp = True
    mode = overrides.pop("mode", mode)
    if "experts_axis" in overrides:
        rules["experts"] = overrides.pop("experts_axis")
    if "expert_mlp_axis" in overrides:
        rules["expert_mlp"] = overrides.pop("expert_mlp_axis")
    rules.update(overrides)
    if mode == "zero":
        all_axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.shape)
        zero_axes = tuple(a for a in ("data", "model") if a in mesh.shape)
        for k in rules:
            rules[k] = None
        rules["batch"] = all_axes
        rules["embed"] = zero_axes
        rules["vocab"] = zero_axes
        rules["vocab_out"] = None
    elif fsdp:
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        rules["embed"] = dp_axes
    rules.setdefault("batch", ("pod", "data"))
    return ShardingPlan(mesh=mesh, rules=rules, fallbacks=[])


def serving_axes_for(name: str, params_axes: dict[str, tuple]
                     ) -> Optional[tuple]:
    """Logical axes for a *served* param key.

    `core.subnet.servable_params` rewrites each compressed weight `<w>`
    into derived keys the model init never named:

      <w>.codes      int8/int16 codes, same rank/layout as <w>
      <w>.packed{b}  int32 K-packed words — K shrinks to ceil(K/cpw) but
                     the axis ORDER is unchanged, so <w>'s logical axes
                     still label it (spec_for's divisibility check then
                     decides per packed shape whether the word count still
                     divides the mesh)
      <w>.scale      per-tensor scale, scalar or (layers,) when stacked

    Dense keys pass through; unknown keys return None (replicate)."""
    if name in params_axes:
        return params_axes[name]
    base, _, suffix = name.rpartition(".")
    ax = params_axes.get(base)
    if ax is None:
        return None
    if suffix == "codes" or (suffix.startswith("packed")
                             and suffix[len("packed"):].isdigit()):
        return ax
    if suffix == "scale":
        return ("layers",)      # (layers,) when stacked; rank-0 replicates
    return None


def serving_param_specs(plan: ShardingPlan, params_axes: dict[str, tuple],
                        params: dict) -> dict[str, P]:
    """PartitionSpecs for an engine's served param dict (dense weights,
    int codes, packed word streams, scales — DESIGN.md §4.12).

    Anything whose logical axes can't be recovered (or whose rank no
    longer matches, e.g. a per-tensor scalar scale) replicates; every
    genuinely TP-shardable axis (q/kv heads, mlp hidden, vocab_out) goes
    through the same `spec_for` divisibility-checked rules training uses,
    so a pruned width that stops dividing the mesh falls back to
    replication instead of crashing — recorded in `plan.fallbacks`."""
    specs = {}
    for name, leaf in params.items():
        ax = serving_axes_for(name, params_axes)
        if ax is None or len(ax) != np.ndim(leaf):
            specs[name] = P()
        else:
            specs[name] = plan.spec_for(name, tuple(ax), np.shape(leaf))
    return specs


def kv_cache_specs(mesh: Mesh, cache_shapes: dict[str, tuple]
                   ) -> dict[str, P]:
    """PartitionSpecs for an engine KV arena, contiguous or paged.

    Attention K/V leaves shard their KV-head axis over `model` — axis 3
    in both the contiguous (nb, B, S, KVh, dh) arena and the paged
    (nb, n_pages, P, KVh, dh) pools, and likewise the paged per-row
    scale planes (nb, n_pages, P, KVh). The page/slot/row axes are never
    split: page tables stay host-side and every logical page maps to one
    local tile per device. A KVh that doesn't divide the mesh replicates
    (GQA smoke configs with 2 kv heads on 4 devices); recurrent-state
    leaves (mamba h/conv, rwkv shift/wkv) are O(1)-per-slot and
    replicate."""
    size = int(mesh.shape.get("model", 1))
    specs: dict[str, P] = {}
    for name, shape in cache_shapes.items():
        kv = name.endswith(".k") or name.endswith(".v")
        sc = name.endswith("_scale")
        if size > 1 and ((kv and len(shape) == 5) or (sc and len(shape) == 4)) \
                and shape[3] % size == 0:
            specs[name] = P(None, None, None, "model")
        else:
            specs[name] = P()
    return specs


def batch_spec(mesh: Mesh, *, shard_seq: bool = False,
               mode: str = "tp") -> P:
    axes = ("pod", "data") if mode != "zero" else ("pod", "data", "model")
    dp = tuple(a for a in axes if a in mesh.shape)
    dp = dp[0] if len(dp) == 1 else dp
    if shard_seq:
        return P(None, dp)      # (batch, seq): SP for long-context
    return P(dp)


def constrain(x: jax.Array, mesh: Mesh, spec: P) -> jax.Array:
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

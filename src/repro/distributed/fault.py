"""Fault tolerance & straggler mitigation (host-side control plane).

On a real 1000+ node fleet this runs per-host next to the JAX client:
- heartbeat registry: every host posts a monotonic (step, wall-time) beat;
  the elected monitor flags hosts silent for > `heartbeat_timeout`.
- restart policy: on failure, all hosts restore the latest complete
  checkpoint (manifest is atomically renamed only after every shard is
  durable) and resume; the data pipeline is stateless-seeded by step, so
  replay is exact.
- straggler mitigation: per-step deadline = median(step_time) *
  `straggler_factor`; a host breaching it `patience` times is flagged for
  hot-spare replacement (here: logged + counted).
- elastic scaling: checkpoints carry the mesh shape; restore re-shards to
  the new mesh (see repro.checkpoint), so scale-down/up is a restart.

This container is single-process, so the fleet behaviour is exercised by
fault-injection tests (tests/test_fault_tolerance.py) driving this exact
code path.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional


@dataclasses.dataclass
class FaultConfig:
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 2.0
    straggler_patience: int = 3
    checkpoint_every: int = 50
    max_restarts: int = 10


class DeviceLoss(RuntimeError):
    """A device dropped out of the mesh mid-step (ICI/host failure)."""


# XLA surfaces device/fabric failures as generic RuntimeErrors; these
# substrings are the stable markers across backends (TPU DATA_LOSS,
# GPU NCCL aborts, PJRT device removal).
_DEVICE_LOSS_MARKERS = ("data_loss", "device lost", "device failure",
                        "nccl", "interconnect", "socket closed")


def is_device_loss(exc: BaseException) -> bool:
    """Classify an exception as a device loss (restorable: the surviving
    hosts restart from the latest checkpoint) vs a program bug (which
    should also restore, but is worth distinguishing in telemetry)."""
    if isinstance(exc, DeviceLoss):
        return True
    msg = str(exc).lower()
    return any(m in msg for m in _DEVICE_LOSS_MARKERS)


class HeartbeatRegistry:
    def __init__(self, hosts: list[str], timeout: float):
        self.timeout = timeout
        self.last: dict[str, float] = {h: time.monotonic() for h in hosts}

    def beat(self, host: str, now: Optional[float] = None):
        self.last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last.items() if now - t > self.timeout]


class StragglerMonitor:
    def __init__(self, factor: float, patience: int, window: int = 32):
        self.factor = factor
        self.patience = patience
        self.times: deque[float] = deque(maxlen=window)
        self.strikes: dict[str, int] = {}
        self.flagged: list[str] = []

    def deadline(self) -> float:
        if not self.times:
            return float("inf")
        s = sorted(self.times)
        return s[len(s) // 2] * self.factor

    def record(self, host: str, step_time: float):
        dl = self.deadline()
        self.times.append(step_time)
        if step_time > dl:
            self.strikes[host] = self.strikes.get(host, 0) + 1
            if self.strikes[host] >= self.patience and host not in self.flagged:
                self.flagged.append(host)
        else:
            self.strikes[host] = 0


@dataclasses.dataclass
class RunResult:
    final_step: int
    restarts: int
    stragglers_flagged: list[str]
    device_losses: int = 0


class FaultTolerantLoop:
    """Checkpoint/restart driver around a step function.

    step_fn(state, step) -> state ; may raise (injected or real failure).
    save_fn(state, step) / restore_fn() -> (state, step) handle durability.
    """

    def __init__(self, cfg: FaultConfig, step_fn: Callable,
                 save_fn: Callable, restore_fn: Callable,
                 host: str = "host0"):
        self.cfg = cfg
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.host = host
        self.monitor = StragglerMonitor(cfg.straggler_factor,
                                        cfg.straggler_patience)
        self.heartbeats = HeartbeatRegistry([host], cfg.heartbeat_timeout)

    def run(self, state, total_steps: int) -> tuple[object, RunResult]:
        initial_state = state
        step = 0
        restarts = 0
        device_losses = 0
        while step < total_steps:
            try:
                t0 = time.monotonic()
                state = self.step_fn(state, step)
                self.monitor.record(self.host, time.monotonic() - t0)
                self.heartbeats.beat(self.host)
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.save_fn(state, step)
            except Exception as e:
                # a device loss is the expected fleet event: restore from
                # the latest complete checkpoint instead of crashing
                if is_device_loss(e):
                    device_losses += 1
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                restored = self.restore_fn()
                if restored is None:
                    # no checkpoint yet: restart TRULY fresh — from the
                    # initial state, not the half-trained one (a stale
                    # state at step 0 desyncs everything keyed on the
                    # step counter: the QASSO stage schedule, the data
                    # stream, the checkpointed RNG key)
                    state = initial_state
                    step = 0
                    continue
                state, step = restored
        return state, RunResult(step, restarts, self.monitor.flagged,
                                device_losses)

"""Host-side page bookkeeping for the paged KV arena (DESIGN.md §4.11).

The device side is a pool: each attention layer's K/V leaves are
`(n_blocks, n_pages, page_size, KVh, dh)` tensors shared by every slot,
addressed through per-slot page tables (logical page -> physical page).
Everything that *decides* which physical page backs which logical row
lives here, on the host, where admission/eviction already run:

- `PageAllocator` — free-list allocation with refcounts and an explicit
  dirty -> zeroed -> free lifecycle. A released page (refcount hit 0) is
  quarantined as *dirty* until the engine has zeroed it on device
  (`take_dirty` / `mark_zeroed`); `alloc` only ever hands out zeroed
  pages. That moves the PR 7 zero-init invariant ("rows beyond the
  written prefix are bitwise zero") into the allocator: a fresh slot's
  pages are zero by construction, so speculative rollback and the decode
  valid-mask keep working unchanged on recycled pages.

- `PrefixCache` — refcounted whole-prompt sharing keyed on the prompt
  token hash. A hit retains the entry's prompt pages (fan-out by
  refcount: N slots with the hot prompt pin ONE copy of its K/V), reuses
  the memoized first token, and skips the prefill dispatch entirely; the
  partial tail page (prompt rows the owner will decode-write into) is
  copy-on-write: the entry keeps a pristine template and every sharer
  copies it into a freshly allocated page.

  Sharing is *whole-prompt* on purpose. Page-aligned partial-prefix
  sharing sounds strictly better, but K/V rows for a shared prefix are
  NOT bitwise stable across prefills of different total lengths (XLA
  regroup reductions with sequence length — measured on this backend:
  rows [0, 20) of a 20-token and a 33-token prefill differ in last-ulp),
  so partial sharing would break the paged-vs-contiguous token-identity
  contract. Whole-prompt reuse is exact: the contiguous engine computes
  the second request's prefill through the same compiled call on the
  same inputs, hence the same bits the cached pages already hold.

Two physical pages are reserved: page 0 is the permanent ZERO page
(backs every unallocated logical page, so gathered views of a slot's
unwritten tail are bitwise zero) and page 1 is the TRASH page (idle
slots' decode writes land there — the engine decodes all slots every
step, and an idle slot must not be able to corrupt page 0).

Under tensor-parallel serving (DESIGN.md §4.12) this split is what
makes the paged arena shard cleanly: page *payloads* are device-local
(the pools shard on their KV-head axis when `KVh % tp == 0`), while
everything in this module — page tables, free lists, refcounts, the
prefix cache — is control plane, host-side and identical regardless of
mesh size, so the allocator never needs to know a mesh exists.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional

import numpy as np

ZERO_PAGE = 0
TRASH_PAGE = 1
N_RESERVED = 2


def pages_for_rows(n_rows: int, page_size: int) -> int:
    """Logical pages covering `n_rows` arena rows."""
    return -(-int(n_rows) // int(page_size))


class PageAllocator:
    """Free-list page allocator with refcounts and zero-before-reuse.

    Page lifecycle: free -> live (refcount >= 1, via `alloc`/`retain`)
    -> dirty (refcount hit 0 in `release`) -> free again only after the
    caller zeroed it on device and called `mark_zeroed`. `alloc` draws
    exclusively from the free list, so a page can never be handed out
    while another owner holds it (no double allocation) nor before its
    stale contents were zeroed — the two invariants the property tests
    drive with random admit/evict/rollback interleavings.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages <= N_RESERVED:
            raise ValueError(f"need > {N_RESERVED} pages (zero + trash are "
                             f"reserved), got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.refcount = np.zeros(self.n_pages, np.int64)
        self.refcount[:N_RESERVED] = 1          # permanently held
        # pop() from the tail -> lowest ids first (stable, test-friendly)
        self._free = list(range(self.n_pages - 1, N_RESERVED - 1, -1))
        self._dirty: list[int] = []

    # ------------------------------------------------------------ queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Pages some owner (slot or prefix-cache entry) currently pins."""
        return (self.n_pages - N_RESERVED - len(self._free)
                - len(self._dirty))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # ---------------------------------------------------------- lifecycle
    def alloc(self, n: int) -> list[int]:
        """Take n zeroed pages (refcount 1 each). Raises if the free list
        cannot cover the request — callers relieve pressure first
        (`PrefixCache.drop_lru`) and re-check with `can_alloc`."""
        if n > len(self._free):
            raise MemoryError(
                f"paged KV arena exhausted: need {n} pages, "
                f"{len(self._free)} free of {self.n_pages} "
                f"({len(self._dirty)} dirty, {self.n_live} live)")
        pages = [self._free.pop() for _ in range(n)]
        self.refcount[pages] += 1
        return pages

    def retain(self, pages) -> None:
        """Add one owner to already-live pages (prefix-sharing fan-out)."""
        pages = [int(p) for p in pages]
        if any(p < N_RESERVED for p in pages) or np.any(
                self.refcount[pages] < 1):
            raise ValueError(f"retain of reserved/non-live page(s) {pages}")
        self.refcount[pages] += 1

    def release(self, pages) -> list[int]:
        """Drop one owner per page; pages whose refcount hits 0 move to
        the dirty quarantine and are returned (the caller must zero them
        on device and `mark_zeroed` before they become allocatable)."""
        freed = []
        for p in pages:
            p = int(p)
            if p < N_RESERVED or self.refcount[p] < 1:
                raise ValueError(f"release of non-live page {p}")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._dirty.append(p)
                freed.append(p)
        return freed

    def take_dirty(self) -> list[int]:
        """Hand the dirty quarantine to the caller for device zeroing."""
        dirty, self._dirty = self._dirty, []
        return dirty

    def mark_zeroed(self, pages) -> None:
        """Return zeroed pages to the free list."""
        for p in pages:
            p = int(p)
            if self.refcount[p] != 0 or p in self._free or p in self._dirty:
                raise ValueError(f"mark_zeroed of non-quarantined page {p}")
            self._free.append(p)

    def check(self) -> None:
        """Assert the partition invariant: every page is in exactly one
        of {reserved, free, dirty, live}."""
        free, dirty = set(self._free), set(self._dirty)
        assert not free & dirty, free & dirty
        for p in range(self.n_pages):
            states = ((p < N_RESERVED) + (p in free) + (p in dirty)
                      + (p >= N_RESERVED and self.refcount[p] > 0))
            assert states == 1, (p, self.refcount[p], p in free, p in dirty)


def prompt_key(prompt: np.ndarray) -> bytes:
    """Content hash of a prompt token stream (whole-prompt sharing key)."""
    a = np.ascontiguousarray(np.asarray(prompt, np.int32))
    return hashlib.sha1(a.tobytes()).digest() + len(a).to_bytes(4, "little")


@dataclasses.dataclass
class PrefixEntry:
    key: bytes
    prompt_len: int
    full_pages: tuple[int, ...]     # pages fully covered by prompt rows
    tail_page: Optional[int]        # pristine CoW template (partial page)
    first_token: int                # memoized prefill argmax

    @property
    def pages(self) -> list[int]:
        return list(self.full_pages) + (
            [self.tail_page] if self.tail_page is not None else [])


class PrefixCache:
    """LRU cache of whole-prompt KV page sets (see module docstring).

    Each entry holds one allocator reference on its pages, so a hot
    prompt's K/V survives every individual owner's eviction — exactly
    the "refcounted shared-prefix pages survive one owner's eviction"
    property — until capacity or allocator pressure drops the entry.
    """

    def __init__(self, alloc: PageAllocator, capacity: int = 8):
        self.alloc = alloc
        self.capacity = int(capacity)
        self._entries: OrderedDict[bytes, PrefixEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, prompt: np.ndarray) -> Optional[PrefixEntry]:
        ent = self._entries.get(prompt_key(prompt))
        if ent is None:
            self.misses += 1
            return None
        self._entries.move_to_end(prompt_key(prompt))
        self.hits += 1
        return ent

    def insert(self, ent: PrefixEntry) -> None:
        """Register an entry; its pages must already carry this cache's
        +1 refcount (the engine retains/allocates before registering)."""
        if ent.key in self._entries:
            raise ValueError("duplicate prefix entry")
        self._entries[ent.key] = ent
        while len(self._entries) > self.capacity:
            self.drop_lru()

    def drop_lru(self) -> list[int]:
        """Release the least-recently-used entry's hold. Returns the
        pages freed to dirty (possibly none, if slots still share them)."""
        if not self._entries:
            return []
        _, ent = self._entries.popitem(last=False)
        return self.alloc.release(ent.pages)

    def drop_all(self) -> list[int]:
        freed = []
        while self._entries:
            freed += self.drop_lru()
        return freed

# Entry points: mesh construction, input specs, train/serve step builders,
# and the 512-device dry-run (python -m repro.launch.dryrun).
from repro.launch.mesh import make_host_mesh, make_production_mesh

"""Self-speculative decoding from nested GETA subnets.

GETA's joint pruning+quantization training hands serving a *family* of
compression points of one model with shared quantizer scales:
`core.subnet.prepare_serving` resolves quantizers *before* slicing, so an
aggressive subnet (pruned s50 + packed b2/b4) and the b8 target are
mutually consistent by construction. This module turns that artifact
family into a decode-latency multiplier: the subnet drafts k tokens
through the packed GEMM + flash-decode kernels, the target scores all
k+1 positions in one chunked pass (`LM.verify_chunk` — the same
GEMM-shaping win one-shot prefill gets at admission), and a
leading-match rule commits the *target's* argmaxes. Greedy speculative
decode is therefore token-identical to the target-only engine no matter
how bad the draft is — a weak draft costs speed, never tokens — which is
the hard oracle `tests/test_speculative.py` and the CI smoke pin.

Dual-arena bookkeeping: draft and target each own a KV arena shaped by
their own SlimPlan (the draft's holds surviving heads only), sharing slot
indices and per-slot positions. A speculative step writes rows
[pos, pos+k] in both; rejection zeroes every row beyond the accepted
prefix in both (`rollback_rows`). The zero-rollback is exact because full
(window == 0) arenas keep all rows beyond the written prefix at their
zero init — an invariant admission preserves (a prefill row is built in a
fresh zeroed cache and inserted whole) and the rollback property tests
assert bitwise. Ring (windowed) arenas are gated out: a wrap overwrites
pre-wrap history that a rejection could never restore. See DESIGN.md
§4.10.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.subnet import prepare_serving, resolve_keep_masks
from repro.models.transformer import LM


@dataclasses.dataclass
class DraftModel:
    """A servable draft subnet: its own (sliced) LM plus the resolved
    (params, qparams) pair. The engine keeps a second KV arena shaped by
    `lm`'s SlimPlan for it."""
    lm: LM
    params: dict
    qparams: Optional[dict]
    meta: dict


def build_draft(arch: str, smoke: bool = True, checkpoint: Optional[dict]
                = None, *, sparsity: float = 0.5, bits: float = 2.0,
                packed: bool = True, seed: int = 0) -> DraftModel:
    """Construct the draft subnet from the target's checkpoint params.

    `checkpoint` is the *same* param dict the target serves from (pre
    `prepare_serving`) — sharing it is what makes the draft
    well-calibrated: quantizers init on the identical tensors, and on a
    GETA-trained checkpoint (pruned groups hard-zeroed by QASSO cooldown)
    the sliced subnet is numerically the target itself at its surviving
    widths. `sparsity=0` keeps all units (a packed-only draft)."""
    cfg = get_arch(arch, smoke=smoke)
    lm = LM(cfg)
    if checkpoint is None:
        checkpoint, _ = lm.init(jax.random.PRNGKey(seed))
    params, qparams, meta = prepare_serving(
        lm, checkpoint, compressed=True, packed=packed, bits_init=bits,
        prune_sparsity=(sparsity if sparsity > 0 else None))
    meta.setdefault("sparsity", 0.0)
    meta["draft_bits"] = bits
    return DraftModel(lm=lm, params=params, qparams=qparams, meta=meta)


def pow2_floor(k: int) -> int:
    """Largest power of two <= k (0 for k < 1) — the draft-window
    quantizer that keeps the engine's compiled spec-step set bounded."""
    k = int(k)
    return 0 if k < 1 else 1 << (k.bit_length() - 1)


def reachable_spec_ks(draft_k: int, max_seq: int) -> set[int]:
    """Every draft-window length `Engine._spec_round` can dispatch:
    k_eff = pow2_floor(min(draft_k, remaining - 1)) enumerated over every
    possible remaining-budget value in [1, max_seq]. Brute force on
    purpose — the static compile-set audit (repro.analysis) diffs this
    against the warmup contract (`Engine._spec_ks`), so it must be an
    independent derivation."""
    return {pow2_floor(min(int(draft_k), rem - 1))
            for rem in range(1, int(max_seq) + 1)}


def rollback_rows(caches: dict, lo, hi) -> dict:
    """Zero arena rows s in [lo[b], hi[b]] for every slot b.

    Cache leaves are (n_blocks, slots, S, ...): axis 1 is the slot, axis
    2 the sequence row. Zeroing (not just abandoning) rejected rows
    restores the full-arena invariant that everything beyond the written
    prefix equals the zero init — the next write at those positions lands
    on the same bits a never-drafted engine would see, and the decode
    mask (`valid = s <= pos`) never reads them in between."""
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)

    def zap(c):
        s = jnp.arange(c.shape[2])
        stale = (s[None, :] >= lo[:, None]) & (s[None, :] <= hi[:, None])
        m = stale.reshape((1,) + stale.shape + (1,) * (c.ndim - 3))
        return jnp.where(m, jnp.zeros((), c.dtype), c)

    return jax.tree_util.tree_map(zap, caches)


def make_spec_step(target_lm: LM, draft_lm: LM):
    """Build the fused speculative step (jit it with k static).

    One call runs: a k+1-step draft scan (the extra step writes the k-th
    proposal's own K/V row, needed when every proposal is accepted; its
    emitted token is discarded) -> one chunked target verify over
    (last_committed, d_1..d_k) -> leading-match acceptance -> zero
    rollback of rows beyond the accepted prefix in *both* arenas.

    Returns (target argmaxes (B, k+1), n_commit (B,), target caches,
    draft caches). Committed tokens are always the target's argmaxes —
    token identity with a target-only engine is structural; the draft
    only sets how many commit per step (n_commit = 1 + accepted run; the
    +1 is the target's free token). k = 0 degenerates to a plain
    one-token verify whose draft scan still runs once, keeping the draft
    arena in sync through the same code path."""

    def spec_step(tparams, tqparams, dparams, dqparams,
                  tcaches, dcaches, tok, pos, k):
        def draft_body(carry, _):
            dc, t, p = carry
            logits, dc = draft_lm.decode_step(dparams, dqparams, dc, t, p)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return (dc, nxt[:, None], p + 1), nxt

        (dcaches, _, _), drafted = jax.lax.scan(
            draft_body, (dcaches, tok, pos), None, length=k + 1)
        proposals = jnp.moveaxis(drafted, 0, 1)[:, :k]       # (B, k)
        chunk = jnp.concatenate([tok, proposals], axis=1)    # (B, k+1)
        logits, tcaches = target_lm.verify_chunk(
            tparams, tqparams, tcaches, chunk, pos)
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, k+1)
        acc = jnp.cumprod((proposals == tgt[:, :k]).astype(jnp.int32),
                          axis=1)
        n_commit = 1 + jnp.sum(acc, axis=1)                  # in [1, k+1]
        tcaches = rollback_rows(tcaches, pos + n_commit, pos + k)
        dcaches = rollback_rows(dcaches, pos + n_commit, pos + k)
        return tgt, n_commit, tcaches, dcaches

    return spec_step


def build_checkpoint_engines(arch: str, smoke: bool = True, *,
                             sparsity: float = 0.5, draft_bits: float = 8.0,
                             draft_k: int = 4, max_slots: int = 4,
                             max_seq: int = 64, seed: int = 0):
    """Target + draft pair as a trained GETA checkpoint would serve them.

    QASSO's cooldown leaves a checkpoint whose pruned groups are *exactly*
    zero; this surrogate applies the magnitude keep-masks to the dense
    init the same way. The target serves that checkpoint dense+b8; the
    draft is its s-sliced packed subnet — numerically the same function
    at `draft_bits=8` (the PR 4/5 slicing/packing parity contracts), so
    acceptance approaches 1 while each draft step runs at the subnet's
    ~2x-cheaper sliced shapes. This is the deployment configuration the
    speculative benchmark measures; with lower `draft_bits` the draft gets
    cheaper and acceptance becomes the measured tradeoff.

    Returns (speculative engine, baseline engine, lm) — both engines
    serve the identical target arrays, so their token streams must match
    bitwise (the benchmark asserts it)."""
    from repro.launch.engine import Engine
    cfg = get_arch(arch, smoke=smoke)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(seed))
    qadg, masks = resolve_keep_masks(lm, params, sparsity)
    ckpt = qadg.space.apply_masks(params, masks)
    tqparams = lm.init_qparams(ckpt)
    draft = build_draft(arch, smoke, ckpt, sparsity=sparsity,
                        bits=draft_bits, seed=seed)
    spec = Engine(lm, ckpt, tqparams, max_slots=max_slots, max_seq=max_seq,
                  draft=draft, draft_k=draft_k)
    base = Engine(lm, ckpt, tqparams, max_slots=max_slots, max_seq=max_seq)
    return spec, base, lm

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# ^^ MUST precede every other import (jax locks the device count on first
# init). Only the dry-run sees 512 placeholder devices; tests/benches that
# import other modules keep the real 1-CPU view.

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_cells, get_arch, get_overrides
from repro.configs.base import CompressionConfig, ModelConfig
from repro.distributed.sharding import batch_spec, make_plan
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs, param_specs
from repro.launch.train import build_geta, make_geta_train_step
from repro.models.transformer import LM, layer_plan
from repro.optim.base import AdamState, get_optimizer
from repro.roofline import analysis as RA

_DRYRUN_COMP = CompressionConfig(
    target_sparsity=0.3, bit_lower=4, bit_upper=16, act_quant=False,
    warmup_steps=100, projection_periods=3, projection_steps=100,
    pruning_periods=5, pruning_steps=100, cooldown_steps=500)


def _attach(sds, sharding):
    return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sharding)


def _rep_tree(tree, mesh):
    rep = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda s: _attach(s, rep), tree)


def _qstate_sds(qasso, state_shapes, param_sh, mesh):
    """Attach shardings to the QASSO state stand-ins: base-optimizer
    moments follow their parameters; everything else is replicated."""
    rep = NamedSharding(mesh, P())

    def like_params(tree):
        return {k: _attach(v, param_sh[k]) for k, v in tree.items()}

    base = state_shapes.base
    if isinstance(base, AdamState):
        base_s = AdamState(_attach(base.count, rep), like_params(base.m),
                           like_params(base.v))
    elif isinstance(base, dict):
        base_s = like_params(base)
    else:
        base_s = base
    return state_shapes._replace(
        step=_attach(state_shapes.step, rep),
        base=base_s,
        redundant={k: _attach(v, rep)
                   for k, v in state_shapes.redundant.items()},
        keep_mask={k: _attach(v, rep)
                   for k, v in state_shapes.keep_mask.items()},
        gamma=_attach(state_shapes.gamma, rep))


def build_cell(arch: str, shape_name: str, mesh, step: str = "geta",
               depth: Optional[int] = None, microbatches: int = 4,
               mode: str = "tp", serve_quant: str = "qat",
               serve_attn: str = "auto"):
    """Lower one (arch x shape x mesh) cell. Returns (lowered, cfg, meta).

    depth: override n_blocks (roofline depth-1/2 differencing).
    mode: sharding layout ('tp' baseline | 'zero' pure-DP ZeRO).
    serve_quant: decode path — 'qat' re-runs the fake-quant chain on every
    weight per step (the training-parity baseline); 'prequant' serves the
    frozen x_Q weights directly (construct_subnet output; x_Q is constant
    post-training, so the per-step pow/round chain is pure waste)."""
    cfg = get_arch(arch)
    if depth is not None:
        plan, _ = layer_plan(cfg)
        cfg = dataclasses.replace(cfg, n_layers=len(plan) * depth)
    overrides = get_overrides(arch)
    base_opt = overrides.get("base_optimizer", "adamw")
    plan = make_plan(mesh, overrides=dict(overrides), mode=mode)
    lm = LM(cfg)
    shape = SHAPES[shape_name]
    p_sds, p_sh, _ = param_specs(lm, mesh, plan)
    # pin the residual-stream sharding (batch over the DP axes); for
    # batch=1 long-context cells shard the sequence instead (SP);
    # pin fake-quantized weights to their param shardings (see LM docs)
    if shape.global_batch == 1:
        lm.act_sharding = NamedSharding(
            mesh, P(None, batch_spec(mesh, mode=mode)[0]))
    else:
        lm.act_sharding = NamedSharding(mesh, batch_spec(mesh, mode=mode))
    lm.param_shardings = p_sh
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        b_sds = batch_specs(cfg, shape, mesh, mode=mode)
        if step == "geta":
            qadg, qasso = build_geta(lm, _DRYRUN_COMP, lr=3e-4,
                                     base_optimizer=base_opt)
            q_shapes = jax.eval_shape(
                lambda p: lm.init_qparams(p, bits_init=8.0), p_sds)
            q_sds = _rep_tree(q_shapes, mesh)
            s_shapes = jax.eval_shape(qasso.init, p_sds, q_sds)
            s_sds = _qstate_sds(qasso, s_shapes, p_sh, mesh)
            mb_sh = NamedSharding(mesh, batch_spec(mesh, mode=mode))
            g_sh = ({k: p_sh[k] for k in p_sh},
                    jax.tree_util.tree_map(lambda _: rep, q_shapes))
            fn = make_geta_train_step(lm, qasso, microbatches=microbatches,
                                      mb_sharding=mb_sh, grad_shardings=g_sh)
            lowered = jax.jit(fn).lower(p_sds, q_sds, s_sds, b_sds)
        else:
            opt = get_optimizer(base_opt)
            o_shapes = jax.eval_shape(opt.init, p_sds)
            if isinstance(o_shapes, AdamState):
                o_sds = AdamState(
                    _attach(o_shapes.count, rep),
                    {k: _attach(v, p_sh[k]) for k, v in o_shapes.m.items()},
                    {k: _attach(v, p_sh[k]) for k, v in o_shapes.v.items()})
            elif isinstance(o_shapes, dict):
                o_sds = {k: _attach(v, p_sh[k])
                         for k, v in o_shapes.items()}
            else:
                o_sds = o_shapes

            from repro.launch.train import _accumulate_grads

            def fn(params, opt_state, batch):
                def lg(b):
                    return jax.value_and_grad(
                        lambda p: lm.loss(p, None, b))(params)

                if microbatches <= 1:
                    loss, gx = lg(batch)
                else:
                    loss, gx = _accumulate_grads(
                        lg, batch, microbatches, params,
                        mb_sharding=NamedSharding(
                            mesh, batch_spec(mesh, mode=mode)),
                        grad_shardings={k: p_sh[k] for k in p_sh})
                delta, opt_state = opt.update(gx, opt_state, params,
                                              jnp.float32(3e-4))
                new_p = jax.tree_util.tree_map(jnp.add, params, delta)
                return new_p, opt_state, loss

            lowered = jax.jit(fn).lower(p_sds, o_sds, b_sds)
    elif shape.kind == "prefill":
        b_sds = batch_specs(cfg, shape, mesh)
        q_shapes = jax.eval_shape(
            lambda p: lm.init_qparams(p, bits_init=8.0), p_sds)
        q_sds = _rep_tree(q_shapes, mesh)

        def fwd(params, qparams, batch):
            return lm.forward(params, qparams, batch["tokens"],
                              batch.get("vision_embeds"))

        lowered = jax.jit(fwd).lower(p_sds, q_sds, b_sds)
    else:  # decode
        from repro.models import layers as Lyr
        if serve_attn == "psum":  # (seqshard handled via decode_specs)
            # pin score sharding: contract d_head locally, psum partials
            dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
            Lyr.DECODE_SCORE_SHARDING = NamedSharding(
                mesh, P(dp_axes if shape.global_batch > 1 else None))
        else:
            Lyr.DECODE_SCORE_SHARDING = None
        d = decode_specs(cfg, shape, mesh,
                         cache_layout=("seq" if serve_attn == "seqshard"
                                       else "heads"))
        if serve_quant == "prequant":
            def serve(params, caches, token, pos):
                return lm.decode_step(params, None, caches, token, pos)

            lowered = jax.jit(serve).lower(p_sds, d["caches"],
                                           d["token"], d["pos"])
        else:
            q_shapes = jax.eval_shape(
                lambda p: lm.init_qparams(p, bits_init=8.0), p_sds)
            q_sds = _rep_tree(q_shapes, mesh)

            def serve(params, qparams, caches, token, pos):
                return lm.decode_step(params, qparams, caches, token, pos)

            lowered = jax.jit(serve).lower(p_sds, q_sds, d["caches"],
                                           d["token"], d["pos"])
    return lowered, cfg, {"plan_fallbacks": plan.fallbacks, "step": step}


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             step: str = "geta", microbatches: int = 4,
             verbose: bool = True) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "step": step, "microbatches": microbatches}
    try:
        lowered, cfg, meta = build_cell(arch, shape_name, mesh, step,
                                        microbatches=microbatches)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        cost = RA.cost_from_compiled(compiled)
        rec.update(
            ok=True, lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            arg_gb=ma.argument_size_in_bytes / 1e9,
            temp_gb=ma.temp_size_in_bytes / 1e9,
            out_gb=ma.output_size_in_bytes / 1e9,
            flops_per_dev=cost.flops,
            bytes_per_dev=cost.bytes_accessed,
            wire_bytes_per_dev=cost.wire_bytes,
            collectives=cost.coll_counts,
            fallbacks=[f"{p}:{a}" for p, a, _ in meta["plan_fallbacks"]],
        )
        if verbose:
            print(f"[ok]   {arch:26s} {shape_name:12s} {mesh_name:6s} "
                  f"{step:5s} compile={t_compile:6.1f}s "
                  f"dev_mem={(ma.argument_size_in_bytes + ma.temp_size_in_bytes)/1e9:7.2f}GB "
                  f"flops/dev={cost.flops:.3e} wire/dev={cost.wire_bytes:.3e}")
            print(f"       memory_analysis: args={ma.argument_size_in_bytes} "
                  f"temp={ma.temp_size_in_bytes} out={ma.output_size_in_bytes}")
            print(f"       cost_analysis: flops={cost.flops} "
                  f"bytes={cost.bytes_accessed} colls={cost.coll_counts}")
    except Exception as e:  # a failing cell is a bug in the system
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch:26s} {shape_name:12s} {mesh_name:6s}: {e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--step", default="geta", choices=["geta", "base"])
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch != "all":
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape != "all":
        cells = [c for c in cells if c[1] == args.shape]

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("1pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod", make_production_mesh(multi_pod=True)))

    records = []
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            records.append(run_cell(arch, shape, mesh, mesh_name, args.step,
                                    microbatches=args.microbatches))

    n_ok = sum(r["ok"] for r in records)
    print(f"\n{n_ok}/{len(records)} cells compiled")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1, default=str)
    print(f"wrote {args.out}")
    if n_ok < len(records):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

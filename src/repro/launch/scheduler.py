"""Swappable step-scheduling policies for the serving engine.

`Engine.step()` used to hard-code one iteration shape: admit (one-shot
full-prompt prefills into free slots), then one batched decode. That
coupling is what made long prompts head-of-line-block decode — a 2048-row
prefill is one dispatch the whole engine waits on while every active slot
sits idle. This module extracts the per-step decision into a policy
object the engine consults each `step()`:

  * `OneShotScheduler` — the original behavior, verbatim: plan is always
    ("admit", "decode"). The default; every pre-existing engine test pins
    its semantics.
  * `ChunkedPrefillScheduler(chunk)` — disaggregated prefill/decode: the
    prompt is prefilled `chunk` rows at a time into a *staging* row cache
    (a `PrefillJob`), interleaved with decode steps over the active
    slots, and finished jobs hand their KV off to a free slot through the
    engine's handoff queue. Decode latency stays bounded by one chunk,
    not one prompt.

A policy is just `plan_step(engine) -> tuple[str, ...]` over the action
vocabulary the engine executes in order: "admit", "handoff",
"prefill_chunk", "decode". Policies read engine state but never mutate
it; actions with nothing to do are cheap no-ops, so a policy may
over-plan. Policies carrying a `chunk` attribute switch the engine into
chunked mode at construction (staging machinery, chunk-bucket warmup,
`run()` driving `step()` instead of the fused window).

The chunk plan keeps the compiled-shape set bounded the same way the
speculative path bounds its k set: a length-S prompt splits into S//C
full chunks plus a *descending power-of-two decomposition* of the
remainder — never padded (arena rows beyond the written prefix must stay
bitwise zero; speculative rollback and the paged pools both lean on
that) — so every possible dispatch shape is in `chunk_buckets(C)` =
{C} ∪ {2^i : 2^i < C}, which `warmup()` precompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def chunk_plan(s: int, chunk: int) -> list[int]:
    """Chunk lengths for a length-`s` prompt at chunk size `chunk`:
    full chunks first, then the remainder as descending powers of two
    (21 @ 16 -> [16, 4, 1]). Sums to exactly `s`."""
    if s < 1:
        raise ValueError(f"prompt length must be >= 1, got {s}")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    out = [chunk] * (s // chunk)
    r = s % chunk
    while r:
        b = 1 << (r.bit_length() - 1)
        out.append(b)
        r -= b
    return out


def reachable_chunk_shapes(max_prompt: int, chunk: int) -> set[int]:
    """Every chunk length `chunk_plan` can emit for any prompt length in
    [1, max_prompt] — brute-force enumeration, *intentionally* independent
    of `chunk_buckets`: the static compile-set audit (repro.analysis)
    diffs this set against the warmup contract, so the two must not share
    an implementation that could be wrong in the same way."""
    out: set[int] = set()
    for s in range(1, max_prompt + 1):
        out.update(chunk_plan(s, chunk))
    return out


def chunk_buckets(chunk: int) -> list[int]:
    """Every chunk length `chunk_plan` can emit: {chunk} ∪ {2^i < chunk}.
    The warmup contract — one prefill-chunk compile per bucket, and no
    prompt length can dispatch any other shape."""
    out = {int(chunk)}
    b = 1
    while b < chunk:
        out.add(b)
        b *= 2
    return sorted(out)


@dataclasses.dataclass
class PrefillJob:
    """A prompt mid-prefill: the staging row cache being filled chunk by
    chunk, the chunk lengths still to run, and — once the last chunk
    lands — the memoized first output token. Exactly one job is in
    flight at a time (prefill is serialized; decode is what must not
    starve)."""
    req: object                        # engine.Request
    caches: object                     # fresh (1, max_seq) row cache
    chunks: list[int]                  # remaining chunk lengths
    done_rows: int = 0                 # prompt rows already written
    first: Optional[int] = None        # set when the last chunk lands


@dataclasses.dataclass(frozen=True)
class OneShotScheduler:
    """The classic engine iteration: admit with one-shot full-prompt
    prefills, then one batched decode (or speculative round)."""
    chunk = None    # not a chunked policy

    def plan_step(self, eng) -> tuple[str, ...]:
        return ("admit", "decode")


@dataclasses.dataclass(frozen=True)
class ChunkedPrefillScheduler:
    """Disaggregated prefill/decode: every step advances the in-flight
    prefill by at most one chunk AND runs one decode batch, so decode
    tail latency is bounded by a chunk, not a prompt. Finished prefills
    queue on the engine's handoff deque until a slot frees (capped at
    max_slots staged jobs so staging can't grow unboundedly under slot
    pressure)."""
    chunk: int = 16

    def __post_init__(self):
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")

    def plan_step(self, eng) -> tuple[str, ...]:
        acts = []
        if eng._handoff:
            acts.append("handoff")
        if eng._prefill_job is not None or (
                eng.queue and len(eng._handoff) < eng.max_slots):
            acts.append("prefill_chunk")
        # plan decode when a handoff is pending too: the handoff action
        # runs first, so a freshly-admitted slot decodes this same step
        # instead of idling one iteration (the decode action no-ops if
        # admission couldn't place anything)
        if eng.n_active or eng._handoff:
            acts.append("decode")
        return tuple(acts)

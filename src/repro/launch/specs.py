"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

`input_specs` is the shannon/kernels pattern: weak-type-correct, shardable,
no device allocation — the dry-run lowers against these.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import batch_spec
from repro.models.transformer import LM


def _sds(shape, dtype, mesh: Optional[Mesh], spec: Optional[P]):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                mesh: Optional[Mesh] = None, mode: str = "tp") -> dict:
    """Training/prefill batch stand-ins (the stub modality frontends provide
    token frames / patch embeddings here)."""
    B, S = shape.global_batch, shape.seq_len
    dp = batch_spec(mesh, shard_seq=False, mode=mode) \
        if mesh is not None else None
    out = {}
    if cfg.family == "audio":
        out["tokens"] = _sds((B, S, cfg.num_codebooks), jnp.int32, mesh, dp)
    elif cfg.family == "vlm":
        out["tokens"] = _sds((B, S - cfg.vision_patches), jnp.int32, mesh, dp)
        out["vision_embeds"] = _sds(
            (B, cfg.vision_patches, cfg.d_model),
            jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32,
            mesh, dp)
    else:
        out["tokens"] = _sds((B, S), jnp.int32, mesh, dp)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 mesh: Optional[Mesh] = None,
                 cache_layout: str = "heads") -> dict:
    """serve_step stand-ins: one new token against a seq_len KV cache."""
    B, S = shape.global_batch, shape.seq_len
    lm = LM(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    cache_shapes = jax.eval_shape(lambda: lm.init_cache(B, S, dtype=dt))
    shard_seq = B == 1          # long-context: SP over the cache sequence
    dp_axes = tuple(a for a in ("pod", "data")
                    if mesh is not None and a in mesh.shape)
    dp = dp_axes[0] if len(dp_axes) == 1 else (dp_axes or None)

    model_size = mesh.shape.get("model", 1) if mesh is not None else 1

    def cache_spec(name, s):
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        # (n_blocks, B, S, KV, dh) attn kv / (n_blocks, B, ...) states
        if ".k" in name or ".v" in name:
            if cache_layout == "seq":
                # shard the cache SEQUENCE on the model axis: attention
                # reduces over seq, so only the tiny softmax statistics
                # and the (B,1,H,dh) output cross devices (§Perf It.5)
                parts = [None, dp, "model", None, None]
                if shard_seq:
                    parts = [None, None, ("data", "model"), None, None]
                return _sds(s.shape, s.dtype, mesh, P(*parts))
            # TP the cache: KV-head axis when it divides, else d_head
            # (always 128 = 8x16) — a replicated 32k cache costs 13-26
            # GB/device on the large archs.
            kv_part = "model" if s.shape[3] % model_size == 0 else None
            dh_part = "model" if kv_part is None else None
            parts = [None, dp, None, kv_part, dh_part]
            if shard_seq:
                parts = [None, None, dp, kv_part, dh_part]
            return _sds(s.shape, s.dtype, mesh, P(*parts))
        parts = [None] + [dp] + [None] * (len(s.shape) - 2)
        if shard_seq:
            parts = [None] * len(s.shape)
        return _sds(s.shape, s.dtype, mesh, P(*parts))

    caches = {k: cache_spec(k, v) for k, v in cache_shapes.items()}
    tok_shape = (B, 1, cfg.num_codebooks) if cfg.num_codebooks else (B, 1)
    token = _sds(tok_shape, jnp.int32, mesh,
                 P(dp) if (mesh is not None and not shard_seq) else P())
    pos = _sds((), jnp.int32, mesh, P())
    return {"caches": caches, "token": token, "pos": pos}


def param_specs(lm: LM, mesh: Optional[Mesh], plan=None,
                seed: int = 0) -> tuple[dict, dict, dict]:
    """(param ShapeDtypeStructs, their shardings, logical axes) without
    allocating anything. The logical-axes dict is static Python data built
    during the abstract trace, captured via closure."""
    captured: dict = {}

    def only_params(k):
        p, a = lm.init(k)
        captured.update(a)
        return p

    params_shapes = jax.eval_shape(only_params, jax.random.PRNGKey(seed))
    axes = captured
    if mesh is None or plan is None:
        return params_shapes, {k: None for k in params_shapes}, axes
    shardings = plan.shardings(
        axes, {k: v.shape for k, v in params_shapes.items()})
    with_sh = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=shardings[k])
        for k, v in params_shapes.items()
    }
    return with_sh, shardings, axes

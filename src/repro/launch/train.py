"""Training drivers: step builders (shared with the dry-run) + a real
CPU-scale end-to-end loop with QASSO, checkpointing and fault tolerance.

Usage (reduced scale, runs on this container):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES, CompressionConfig, get_arch,
                           get_overrides)
from repro.core.qadg import build_qadg
from repro.core.qasso import QASSO, QASSOConfig, QASSOState
from repro.data.synthetic import batch_for
from repro.distributed.fault import FaultConfig, FaultTolerantLoop
from repro.distributed.sharding import batch_spec, make_plan
from repro.models.transformer import LM
from repro.optim.base import AdamState, get_optimizer, tree_add
from repro.optim.schedules import constant, cosine


def qasso_config_from(comp: CompressionConfig,
                      base_optimizer: str = "adamw") -> QASSOConfig:
    return QASSOConfig(
        target_sparsity=comp.target_sparsity,
        bit_lower=comp.bit_lower, bit_upper=comp.bit_upper,
        warmup_steps=comp.warmup_steps,
        projection_periods=comp.projection_periods,
        projection_steps=comp.projection_steps,
        bit_reduction=comp.bit_reduction,
        pruning_periods=comp.pruning_periods,
        pruning_steps=comp.pruning_steps,
        cooldown_steps=comp.cooldown_steps,
        base_optimizer=base_optimizer)


def build_geta(lm: LM, comp: CompressionConfig, lr: float,
               base_optimizer: str = "adamw"):
    """(qadg, qasso) for a model — the paper's `geta = GETA(model)`."""
    qadg = build_qadg(lm.build_graph(act_quant=comp.act_quant).graph)
    qcfg = qasso_config_from(comp, base_optimizer)
    qasso = QASSO(qadg.space, qadg.sites, qcfg,
                  cosine(lr, qcfg.total_steps, warmup=qcfg.warmup_steps))
    return qadg, qasso


def _constrain_tree(tree, shardings):
    if shardings is None:
        return tree
    return jax.tree_util.tree_map(
        lambda x, s: x if s is None else jax.lax.with_sharding_constraint(x, s),
        tree, shardings)


def _accumulate_grads(loss_grad_fn, batch, microbatches: int,
                      grad_example, mb_sharding=None, grad_shardings=None):
    """Scan-accumulated gradients over `microbatches` splits of the global
    batch (f32 accumulators — per-device activation memory scales with
    1/microbatches at fixed global batch).

    loss_grad_fn(microbatch) -> (loss, grads_pytree).
    mb_sharding: optional NamedSharding for the reshaped (k, B/k, ...)
    batch — without the explicit constraint GSPMD can drop the batch
    sharding across the reshape (measured 3.5x temp regression).
    grad_shardings: optional tree of NamedShardings matching grad_example;
    pins the f32 accumulators (scan carries) to the parameter shardings —
    GSPMD's carry fixed-point otherwise all-gathers FSDP-sharded expert
    grads (measured ~35 full f32 copies on jamba-398b).
    Returns (mean loss, mean grads)."""
    def split(x):
        y = x.reshape(microbatches, x.shape[0] // microbatches,
                      *x.shape[1:])
        if mb_sharding is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = P(None, *mb_sharding.spec)
            y = jax.lax.with_sharding_constraint(
                y, NamedSharding(mb_sharding.mesh, spec))
        return y

    mbatch = jax.tree_util.tree_map(split, batch)
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.float32), grad_example)
    zeros = _constrain_tree(zeros, grad_shardings)

    def body(acc, mb):
        loss_acc, g_acc = acc
        loss, grads = loss_grad_fn(mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
        g_acc = _constrain_tree(g_acc, grad_shardings)
        return (loss_acc + loss, g_acc), None

    (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros),
                                        mbatch)
    scale = 1.0 / microbatches
    return loss_sum * scale, jax.tree_util.tree_map(
        lambda g: g * scale, g_sum)


def make_geta_train_step(lm: LM, qasso: QASSO, microbatches: int = 1,
                         mb_sharding=None, grad_shardings=None):
    """The production train step: loss -> grads -> QASSO joint update."""

    def step(params, qparams, qstate, batch):
        def lg(b):
            loss, grads = jax.value_and_grad(lm.loss, argnums=(0, 1))(
                params, qparams, b)
            return loss, grads

        if microbatches <= 1:
            loss, (gx, gq) = lg(batch)
        else:
            loss, (gx, gq) = _accumulate_grads(lg, batch, microbatches,
                                               (params, qparams),
                                               mb_sharding=mb_sharding,
                                               grad_shardings=grad_shardings)
        params, qparams, qstate, metrics = qasso.update(
            params, qparams, gx, gq, qstate)
        metrics["loss"] = loss
        return params, qparams, qstate, metrics

    return step


# ------------------------------------------------------- sharded training
def geta_state_shardings(qasso: QASSO, params, qparams, mesh,
                         param_shardings=None):
    """Plan-derived shardings for the full GETA state tree.

    params follow the ShardingPlan (FSDP shards the embed axis over the DP
    axes when the plan says so); the base-optimizer moments follow their
    parameters (they are elementwise companions, so FSDP sharding of the
    params shards the optimizer state for free); everything control-plane —
    quantizer scalars, redundancy/keep masks, step counter, gamma — is
    replicated (they are the values QASSO must agree on across replicas).
    Returns (param_sh, qparam_sh, qstate_sh) pytrees of NamedShardings.
    """
    rep = NamedSharding(mesh, P())
    p_sh = {k: (param_shardings or {}).get(k) or rep for k in params}
    q_sh = jax.tree_util.tree_map(lambda _: rep, qparams)
    state_shape = jax.eval_shape(qasso.init, params, qparams)
    base = state_shape.base
    if isinstance(base, AdamState):
        base_sh = AdamState(rep, {k: p_sh[k] for k in base.m},
                            {k: p_sh[k] for k in base.v})
    elif isinstance(base, dict):                 # momentum: one moment tree
        base_sh = {k: p_sh[k] for k in base}
    else:                                        # sgd: stateless
        base_sh = jax.tree_util.tree_map(lambda _: rep, base)
    s_sh = QASSOState(
        step=rep, base=base_sh,
        redundant={k: rep for k in state_shape.redundant},
        keep_mask={k: rep for k in state_shape.keep_mask},
        gamma=rep)
    return p_sh, q_sh, s_sh


def _gather_full(x, spec, axis_name_filter=None):
    """Reassemble a shard_map-local param shard to the full tensor.

    `spec` is the param's PartitionSpec; every sharded dim is all-gathered
    (tiled) in minor-to-major axis order, which reconstructs the original
    array bitwise (pure data movement, no arithmetic)."""
    for dim, part in enumerate(spec):
        if part is None:
            continue
        names = (part,) if isinstance(part, str) else tuple(part)
        for name in reversed(names):
            x = jax.lax.all_gather(x, name, axis=dim, tiled=True)
    return x


def make_ordered_loss_grads(lm, mesh, param_specs_tree=None,
                            grad_slices: Optional[int] = None,
                            axis: str = "data"):
    """(loss, (gx, gq)) with a DETERMINISTIC reduction tree over the batch.

    The global batch is split into `grad_slices` equal slices (default: the
    mesh's `axis` size); each slice's gradients are computed independently
    and combined by f32 summation in FIXED slice order. Two properties fall
    out:

    - k-device data parallelism is **bitwise-reproducible across mesh
      sizes**: the k-device run (one slice per device via shard_map,
      all-gather + ordered sum) produces bit-identical loss and gradients
      to a 1-device run of the same step with `grad_slices=k` (sequential
      unrolled accumulation — same tree, same order). This is what lets
      the sharded-parity tier assert exact equality instead of chasing
      reduction-order ulps through QASSO's discrete decisions (saliency
      ranking, fake-quant rounding, the Alg 4 rescale loop), every one of
      which is a knife edge that amplifies a 1-ulp gradient difference
      into a diverged subnet.
    - the combine is an all-gather + local ordered sum rather than a psum
      (the `compressed_grad_allreduce` wire pattern): k× gradient bytes on
      the wire vs 2(k-1)/k for a ring — the documented cost of determinism
      (DESIGN.md §5). The scalar loss is pinned with an optimization
      barrier on the sequential path: XLA otherwise duplicates the cheap
      loss reduction into differently-fused consumers and reassociates the
      metric by a few ulps (state is unaffected — only the metric).

    FSDP params are handled inside the shard_map body: sharded params are
    all-gathered (tiled, bitwise) to full before the slice computation, so
    gradients are identical whether params live replicated or sharded.
    """
    dp = dict(mesh.shape).get(axis, 1)
    k = grad_slices or max(dp, 1)
    if dp > 1 and k != dp:
        raise ValueError(
            f"deterministic grads need one slice per device: "
            f"grad_slices={k} but mesh has {dp} '{axis}' devices")

    def lg_fn(p, q, bb):
        # trace WITHOUT the model's internal sharding constraints: the
        # k-device body runs under shard_map (constraints are illegal on
        # manual axes) and the 1-device reference must lower the exact
        # same computation (a constraint-induced fusion difference breaks
        # the bitwise contract). Restored right after the trace so the
        # caller's lm is untouched.
        if hasattr(lm, "act_sharding"):
            saved = (lm.act_sharding, lm.param_shardings)
            lm.act_sharding = None
            lm.param_shardings = None
            try:
                return jax.value_and_grad(lm.loss, argnums=(0, 1))(p, q, bb)
            finally:
                lm.act_sharding, lm.param_shardings = saved
        return jax.value_and_grad(lm.loss, argnums=(0, 1))(p, q, bb)

    scale = jnp.float32(1.0 / k)

    if dp == 1:
        # sequential reference: unrolled slice loop, ordered f32 accumulate
        def lg(params, qparams, batch):
            slices = jax.tree_util.tree_map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]),
                batch)
            ls, g_acc = [], None
            for i in range(k):
                mb = jax.tree_util.tree_map(lambda x: x[i], slices)
                l, g = lg_fn(params, qparams, mb)
                ls.append(l.astype(jnp.float32))
                gf = jax.tree_util.tree_map(
                    lambda t: t.astype(jnp.float32), g)
                g_acc = gf if g_acc is None else jax.tree_util.tree_map(
                    jnp.add, g_acc, gf)
            lsa = jax.lax.optimization_barrier(jnp.stack(ls))
            loss = lsa[0]
            for i in range(1, k):
                loss = loss + lsa[i]
            return loss * scale, jax.tree_util.tree_map(
                lambda t: t * scale, g_acc)

        return lg

    from jax.sharding import PartitionSpec
    from repro.distributed.collectives import shard_map

    def body(params, qparams, batch):
        if param_specs_tree is not None:
            params = {name: _gather_full(w, param_specs_tree[name])
                      for name, w in params.items()}
        loss, (gx, gq) = lg_fn(params, qparams, batch)

        def combine(x):
            xs = jax.lax.all_gather(x.astype(jnp.float32), axis)  # (k, ...)
            acc = xs[0]
            for i in range(1, k):
                acc = acc + xs[i]
            return acc * scale

        return combine(loss), (jax.tree_util.tree_map(combine, gx),
                               jax.tree_util.tree_map(combine, gq))

    p_specs = (dict(param_specs_tree) if param_specs_tree is not None
               else PartitionSpec())
    lg = shard_map(body, mesh=mesh,
                   in_specs=(p_specs, PartitionSpec(),
                             PartitionSpec(axis)),
                   out_specs=(PartitionSpec(),
                              (PartitionSpec(), PartitionSpec())),
                   check_vma=False)
    return lg


def make_sharded_geta_train_step(lm, qasso: QASSO, mesh, params, qparams, *,
                                 param_shardings=None,
                                 grad_slices: Optional[int] = None,
                                 deterministic: bool = True,
                                 microbatches: int = 1):
    """The GETA step jitted against a real device mesh.

    - in/out shardings are derived from the ShardingPlan via
      `geta_state_shardings` (data-parallel batch over the mesh's DP axes,
      params/opt-state per plan — replicated for pure DP, sharded for FSDP);
    - gradients come from `make_ordered_loss_grads` when deterministic
      (the default): bitwise-reproducible across mesh sizes, so a k-device
      run exactly matches the 1-device reference with `grad_slices=k`.
      `deterministic=False` falls back to plain GSPMD value_and_grad
      (ring psum, cheaper wire, ulp-level reduction-order noise);
    - QASSO runs replica-consistent (`qasso.replica_consistent(mesh)`):
      the saliency and Eq 15-17 statistics are computed from explicitly
      replicated inputs, so partition ranking, bit-width projections and
      cooldown hard-zeroing are identical on every device — and identical
      to the 1-device run, since full-tensor reductions then happen
      locally in a mesh-size-invariant order;
    - the kernel backend resolves mesh-aware (`dispatch.backend_for_mesh`):
      >1 device routes GEMMs to the partitionable XLA path.

    Returns (jitted_step, (param_sh, qparam_sh, qstate_sh, batch_sh)).
    Callers `jax.device_put` the initial state and each batch with the
    returned shardings; `batch_sh` is a pytree-prefix sharding valid for
    any batch dict.
    """
    import copy

    from repro.kernels.dispatch import backend_for_mesh, use_backend

    # the step closes over a COPY so the caller's qasso keeps working in
    # non-mesh contexts (replica_consistent pins stat layouts to `mesh`,
    # which would poison a later plain-jit trace of the same object)
    qasso = copy.copy(qasso).replica_consistent(mesh)
    p_sh, q_sh, s_sh = geta_state_shardings(qasso, params, qparams, mesh,
                                            param_shardings)
    batch_sh = NamedSharding(mesh, batch_spec(mesh))
    rep = NamedSharding(mesh, P())
    backend = backend_for_mesh(mesh)

    if deterministic:
        if microbatches > 1:
            raise ValueError(
                "microbatches>1 is only supported with deterministic="
                "False (the deterministic path computes one gradient per "
                "batch slice; use grad_slices to control the split)")
        specs_tree = ({k: v.spec for k, v in param_shardings.items()}
                      if param_shardings else None)
        lg = make_ordered_loss_grads(lm, mesh, specs_tree,
                                     grad_slices=grad_slices)

        def step(params, qparams, qstate, batch):
            with use_backend(backend):
                loss, (gx, gq) = lg(params, qparams, batch)
                params, qparams, qstate, metrics = qasso.update(
                    params, qparams, gx, gq, qstate)
            metrics["loss"] = loss
            return params, qparams, qstate, metrics
    else:
        base_step = make_geta_train_step(
            lm, qasso, microbatches=microbatches,
            mb_sharding=batch_sh if microbatches > 1 else None,
            grad_shardings=(p_sh, q_sh) if microbatches > 1 else None)

        def step(params, qparams, qstate, batch):
            with use_backend(backend):
                return base_step(params, qparams, qstate, batch)

    jstep = jax.jit(step,
                    in_shardings=(p_sh, q_sh, s_sh, batch_sh),
                    out_shardings=(p_sh, q_sh, s_sh, rep))
    return jstep, (p_sh, q_sh, s_sh, batch_sh)


def make_base_train_step(lm: LM, optimizer_name: str = "adamw",
                         lr: float = 3e-4):
    """Vanilla (no-GETA) train step — the roofline comparison baseline."""
    opt = get_optimizer(optimizer_name)
    sched = constant(lr)

    def step(params, opt_state, step_idx, batch):
        loss, gx = jax.value_and_grad(
            lambda p: lm.loss(p, None, batch))(params)
        delta, opt_state = opt.update(gx, opt_state, params,
                                      sched(step_idx))
        params = tree_add(params, delta)
        return params, opt_state, step_idx + 1, loss

    return step, opt


# ---------------------------------------------------------------- driver
def train_loop(arch: str, smoke: bool, steps: int, batch: int, seq: int,
               ckpt_dir: Optional[str] = None, seed: int = 0,
               comp: Optional[CompressionConfig] = None,
               inject_failure_at: Optional[int] = None,
               log_every: int = 10, verbose: bool = True,
               mesh=None, fsdp: bool = False,
               checkpoint_every: Optional[int] = None):
    """GETA training driver. `mesh=None` is the single-device path; passing
    a mesh jits the step with ShardingPlan-derived in/out shardings
    (data-parallel batch, FSDP params when fsdp=True) and checkpoints place
    restored leaves with the CURRENT mesh's shardings (elastic resume).

    The checkpoint carries the FULL state tree — params, qparams, the whole
    QASSOState (base-optimizer moments, step counter, partition masks) and
    the data-RNG key — so a killed run resumes on a bitwise-identical
    trajectory (tests/test_checkpoint_resume.py)."""
    cfg = get_arch(arch, smoke=smoke)
    comp = comp or CompressionConfig(
        warmup_steps=max(steps // 10, 2),
        projection_periods=2, projection_steps=max(steps // 10, 2),
        pruning_periods=3, pruning_steps=max(steps // 10, 2),
        cooldown_steps=max(steps // 4, 2))
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(seed))
    qparams = lm.init_qparams(params, bits_init=16.0,
                              act_quant=comp.act_quant)
    base_opt = get_overrides(arch).get("base_optimizer", "adamw")
    qadg, qasso = build_geta(lm, comp, lr=3e-4, base_optimizer=base_opt)
    qadg.space.validate(params)

    batch_sh = None
    state_sh = None
    if mesh is not None:
        from repro.launch.specs import param_specs
        plan = make_plan(mesh, fsdp=fsdp,
                         overrides=dict(get_overrides(arch)))
        _, p_sh, _ = param_specs(lm, mesh, plan)
        jstep, (p_sh, q_sh, s_sh, batch_sh) = make_sharded_geta_train_step(
            lm, qasso, mesh, params, qparams, param_shardings=p_sh)
        params = jax.device_put(params, p_sh)
        qparams = jax.device_put(qparams, q_sh)
        qstate = jax.device_put(qasso.init(params, qparams), s_sh)
        rep = NamedSharding(mesh, P())
        state_sh = {"params": p_sh, "qparams": q_sh, "qstate": s_sh,
                    "rng": rep}
    else:
        qstate = qasso.init(params, qparams)
        jstep = jax.jit(make_geta_train_step(lm, qasso))

    from repro.checkpoint import restore_checkpoint, save_checkpoint

    # state["rng"] holds the data key for the NEXT step (equal to
    # fold_in(PRNGKey(seed), step), so the stream is identical to the
    # stateless form); checkpointing it means a restored run consumes the
    # exact saved key rather than re-deriving it — the RNG stream is part
    # of the bitwise-replay contract.
    rng0 = jax.random.PRNGKey(seed)
    state = {"params": params, "qparams": qparams, "qstate": qstate,
             "rng": jax.random.fold_in(rng0, 0)}
    losses = []
    pending_failure = [inject_failure_at]   # one-shot injection

    def step_fn(state, i):
        if pending_failure[0] is not None and i == pending_failure[0]:
            pending_failure[0] = None
            raise RuntimeError("injected node failure")
        b = batch_for(cfg, seed, i, batch, seq, key=state["rng"])
        if batch_sh is not None:
            b = jax.device_put(b, batch_sh)
        p, q, s, metrics = jstep(state["params"], state["qparams"],
                                 state["qstate"], b)
        losses.append(float(metrics["loss"]))
        if verbose and i % log_every == 0:
            print(f"step {i:4d} stage={int(metrics['stage'])} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"bits=[{float(metrics['bits_min']):.1f},"
                  f"{float(metrics['bits_max']):.1f}] "
                  f"sparsity={float(metrics['sparsity_hard']):.3f}")
        return {"params": p, "qparams": q, "qstate": s,
                "rng": jax.random.fold_in(rng0, i + 1)}

    if ckpt_dir:
        def save_fn(state, i):
            save_checkpoint(ckpt_dir, i, state)

        def restore_fn():
            return restore_checkpoint(ckpt_dir, state, shardings=state_sh)

        loop = FaultTolerantLoop(
            FaultConfig(checkpoint_every=checkpoint_every
                        or max(steps // 4, 1)),
            step_fn, save_fn, restore_fn)
        state, result = loop.run(state, steps)
        if verbose:
            print(f"done: {result}")
    else:
        for i in range(steps):
            state = step_fn(state, i)
    return state, qadg, qasso, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=None,
                    help="data-parallel mesh over the first N local devices "
                         "(CPU hosts: also set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params/opt-state over the data axis")
    args = ap.parse_args()
    mesh = None
    if args.devices:
        from repro.launch.mesh import make_subset_mesh
        mesh = make_subset_mesh(args.devices)
    t0 = time.time()
    state, qadg, qasso, losses = train_loop(
        args.arch, args.smoke, args.steps, args.batch, args.seq,
        ckpt_dir=args.ckpt_dir, seed=args.seed, mesh=mesh, fsdp=args.fsdp)
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    sp = float(qasso.space.sparsity(state["qstate"].keep_mask))
    print(f"final hard sparsity: {sp:.3f}")


if __name__ == "__main__":
    main()

"""Training drivers: step builders (shared with the dry-run) + a real
CPU-scale end-to-end loop with QASSO, checkpointing and fault tolerance.

Usage (reduced scale, runs on this container):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import (SHAPES, CompressionConfig, get_arch,
                           get_overrides)
from repro.core.qadg import build_qadg
from repro.core.qasso import QASSO, QASSOConfig
from repro.data.synthetic import batch_for
from repro.distributed.fault import FaultConfig, FaultTolerantLoop
from repro.models.transformer import LM
from repro.optim.base import get_optimizer, tree_add
from repro.optim.schedules import constant, cosine


def qasso_config_from(comp: CompressionConfig,
                      base_optimizer: str = "adamw") -> QASSOConfig:
    return QASSOConfig(
        target_sparsity=comp.target_sparsity,
        bit_lower=comp.bit_lower, bit_upper=comp.bit_upper,
        warmup_steps=comp.warmup_steps,
        projection_periods=comp.projection_periods,
        projection_steps=comp.projection_steps,
        bit_reduction=comp.bit_reduction,
        pruning_periods=comp.pruning_periods,
        pruning_steps=comp.pruning_steps,
        cooldown_steps=comp.cooldown_steps,
        base_optimizer=base_optimizer)


def build_geta(lm: LM, comp: CompressionConfig, lr: float,
               base_optimizer: str = "adamw"):
    """(qadg, qasso) for a model — the paper's `geta = GETA(model)`."""
    qadg = build_qadg(lm.build_graph(act_quant=comp.act_quant).graph)
    qcfg = qasso_config_from(comp, base_optimizer)
    qasso = QASSO(qadg.space, qadg.sites, qcfg,
                  cosine(lr, qcfg.total_steps, warmup=qcfg.warmup_steps))
    return qadg, qasso


def _constrain_tree(tree, shardings):
    if shardings is None:
        return tree
    return jax.tree_util.tree_map(
        lambda x, s: x if s is None else jax.lax.with_sharding_constraint(x, s),
        tree, shardings)


def _accumulate_grads(loss_grad_fn, batch, microbatches: int,
                      grad_example, mb_sharding=None, grad_shardings=None):
    """Scan-accumulated gradients over `microbatches` splits of the global
    batch (f32 accumulators — per-device activation memory scales with
    1/microbatches at fixed global batch).

    loss_grad_fn(microbatch) -> (loss, grads_pytree).
    mb_sharding: optional NamedSharding for the reshaped (k, B/k, ...)
    batch — without the explicit constraint GSPMD can drop the batch
    sharding across the reshape (measured 3.5x temp regression).
    grad_shardings: optional tree of NamedShardings matching grad_example;
    pins the f32 accumulators (scan carries) to the parameter shardings —
    GSPMD's carry fixed-point otherwise all-gathers FSDP-sharded expert
    grads (measured ~35 full f32 copies on jamba-398b).
    Returns (mean loss, mean grads)."""
    def split(x):
        y = x.reshape(microbatches, x.shape[0] // microbatches,
                      *x.shape[1:])
        if mb_sharding is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = P(None, *mb_sharding.spec)
            y = jax.lax.with_sharding_constraint(
                y, NamedSharding(mb_sharding.mesh, spec))
        return y

    mbatch = jax.tree_util.tree_map(split, batch)
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.float32), grad_example)
    zeros = _constrain_tree(zeros, grad_shardings)

    def body(acc, mb):
        loss_acc, g_acc = acc
        loss, grads = loss_grad_fn(mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
        g_acc = _constrain_tree(g_acc, grad_shardings)
        return (loss_acc + loss, g_acc), None

    (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros),
                                        mbatch)
    scale = 1.0 / microbatches
    return loss_sum * scale, jax.tree_util.tree_map(
        lambda g: g * scale, g_sum)


def make_geta_train_step(lm: LM, qasso: QASSO, microbatches: int = 1,
                         mb_sharding=None, grad_shardings=None):
    """The production train step: loss -> grads -> QASSO joint update."""

    def step(params, qparams, qstate, batch):
        def lg(b):
            loss, grads = jax.value_and_grad(lm.loss, argnums=(0, 1))(
                params, qparams, b)
            return loss, grads

        if microbatches <= 1:
            loss, (gx, gq) = lg(batch)
        else:
            loss, (gx, gq) = _accumulate_grads(lg, batch, microbatches,
                                               (params, qparams),
                                               mb_sharding=mb_sharding,
                                               grad_shardings=grad_shardings)
        params, qparams, qstate, metrics = qasso.update(
            params, qparams, gx, gq, qstate)
        metrics["loss"] = loss
        return params, qparams, qstate, metrics

    return step


def make_base_train_step(lm: LM, optimizer_name: str = "adamw",
                         lr: float = 3e-4):
    """Vanilla (no-GETA) train step — the roofline comparison baseline."""
    opt = get_optimizer(optimizer_name)
    sched = constant(lr)

    def step(params, opt_state, step_idx, batch):
        loss, gx = jax.value_and_grad(
            lambda p: lm.loss(p, None, batch))(params)
        delta, opt_state = opt.update(gx, opt_state, params,
                                      sched(step_idx))
        params = tree_add(params, delta)
        return params, opt_state, step_idx + 1, loss

    return step, opt


# ---------------------------------------------------------------- driver
def train_loop(arch: str, smoke: bool, steps: int, batch: int, seq: int,
               ckpt_dir: Optional[str] = None, seed: int = 0,
               comp: Optional[CompressionConfig] = None,
               inject_failure_at: Optional[int] = None,
               log_every: int = 10, verbose: bool = True):
    cfg = get_arch(arch, smoke=smoke)
    comp = comp or CompressionConfig(
        warmup_steps=max(steps // 10, 2),
        projection_periods=2, projection_steps=max(steps // 10, 2),
        pruning_periods=3, pruning_steps=max(steps // 10, 2),
        cooldown_steps=max(steps // 4, 2))
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(seed))
    qparams = lm.init_qparams(params, bits_init=16.0,
                              act_quant=comp.act_quant)
    base_opt = get_overrides(arch).get("base_optimizer", "adamw")
    qadg, qasso = build_geta(lm, comp, lr=3e-4, base_optimizer=base_opt)
    qadg.space.validate(params)
    qstate = qasso.init(params, qparams)

    jstep = jax.jit(make_geta_train_step(lm, qasso))

    from repro.checkpoint import restore_checkpoint, save_checkpoint

    state = {"params": params, "qparams": qparams, "qstate": qstate}
    losses = []
    pending_failure = [inject_failure_at]   # one-shot injection

    def step_fn(state, i):
        if pending_failure[0] is not None and i == pending_failure[0]:
            pending_failure[0] = None
            raise RuntimeError("injected node failure")
        b = batch_for(cfg, seed, i, batch, seq)
        p, q, s, metrics = jstep(state["params"], state["qparams"],
                                 state["qstate"], b)
        losses.append(float(metrics["loss"]))
        if verbose and i % log_every == 0:
            print(f"step {i:4d} stage={int(metrics['stage'])} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"bits=[{float(metrics['bits_min']):.1f},"
                  f"{float(metrics['bits_max']):.1f}] "
                  f"sparsity={float(metrics['sparsity_hard']):.3f}")
        return {"params": p, "qparams": q, "qstate": s}

    if ckpt_dir:
        def save_fn(state, i):
            save_checkpoint(ckpt_dir, i, state)

        def restore_fn():
            out = restore_checkpoint(ckpt_dir, state)
            return out

        loop = FaultTolerantLoop(
            FaultConfig(checkpoint_every=max(steps // 4, 1)),
            step_fn, save_fn, restore_fn)
        state, result = loop.run(state, steps)
        if verbose:
            print(f"done: {result}")
    else:
        for i in range(steps):
            state = step_fn(state, i)
    return state, qadg, qasso, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    t0 = time.time()
    state, qadg, qasso, losses = train_loop(
        args.arch, args.smoke, args.steps, args.batch, args.seq,
        ckpt_dir=args.ckpt_dir, seed=args.seed)
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    sp = float(qasso.space.sparsity(state["qstate"].keep_mask))
    print(f"final hard sparsity: {sp:.3f}")


if __name__ == "__main__":
    main()

"""Continuous-batching serving engine over the compressed GEMM path.

The static `serve_loop` (launch/serve.py) decodes one fixed batch in
lockstep: every sequence shares a scalar position, prefill is a sequential
per-token loop, and a finished sequence keeps burning decode slots until
the longest one ends. This engine replaces all three:

- **request queue + admission/eviction** — requests arrive with their own
  prompt and token budget; a finished request frees its slot immediately
  and the next queued request is admitted into it.
- **slot-based (paged-lite) KV management** — the caches are one
  `LM.init_cache(max_slots, max_seq)` arena; each slot is a cache row
  owned by at most one request. Admission overwrites the *whole* row (the
  prefill builds it in a fresh zeroed cache, insertion is a single
  `dynamic_update_slice` per leaf), so no stale state survives eviction.
- **per-slot positions** — `LM.decode_step` takes a (B,) position vector,
  so slots at different progress share one batched decode dispatch (each
  row ropes at its own absolute position and masks its own cache length).
- **one-shot prefill** — `LM.prefill` fills a cache row with a single
  full-sequence forward (GEMM-shaped (1, S) matmuls) instead of S
  sequential decode steps.

Three jitted functions run everything: `_prefill` (one per distinct
prompt length), `_insert` (slot index is a traced scalar — one compile
serves every slot), and `_decode` (one compile, period). Works unchanged
on dense fake-quant params and on `--compressed` Subnet int codes —
`core.subnet.prepare_serving` resolves the pair once and every jit closes
over the same arrays.

Two orthogonal scaling axes ride on top (PR 9, DESIGN.md §4.12):

- **tensor parallelism** — `Engine(..., mesh=make_tp_mesh(n))` shards the
  served params (attention heads / MLP hidden / vocab through the
  training `ShardingPlan` rules, int codes and packed word streams by
  name mapping) and the KV arena — contiguous *and* paged pools — by KV
  head over the mesh's `model` axis. Every jit pins its output shardings
  so the arena stays device-resident and sharded across the whole decode
  loop; page tables and slot bookkeeping stay host-side, unchanged. An
  N-device engine is token-identical to the 1-device engine (the
  `serve --tp --smoke` parity matrix pins dense/pruned/packed/paged).
- **disaggregated chunked prefill** — `scheduler=
  ChunkedPrefillScheduler(chunk)` (launch/scheduler.py) splits each
  prompt's prefill into bounded chunks staged into a private row cache
  (`LM.verify_chunk` at absolute positions), interleaving one decode
  batch per chunk so a long prompt can no longer head-of-line-block the
  active slots; the finished row hands off to a free slot through the
  engine's handoff queue exactly like a one-shot prefill row would.

Smoke:
  PYTHONPATH=src python -m repro.launch.serve --smoke --compressed \
      --prompt-lens 12,5 --gen 8
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.quant import kv_quant_decode, kv_quant_encode
from repro.core.subnet import (compression_report, prepare_serving,
                               tree_bytes)
from repro.data.synthetic import batch_for
from repro.launch import paging
from repro.models import layers as model_layers
from repro.models.transformer import LM


def _kv_split(caches: dict) -> tuple[list[str], list[str]]:
    """Partition cache keys into attention K/V leaves (pool pages under
    the paged arena) and recurrent-state leaves (always per-slot)."""
    kv = sorted(k for k in caches
                if k.endswith(".k") or k.endswith(".v"))
    state = sorted(k for k in caches
                   if k not in kv and not k.endswith("_scale"))
    return kv, state


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    tokens: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    submit_t: float = 0.0
    admit_t: float = 0.0
    finish_t: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new_tokens


class Engine:
    """Continuous-batching decode over a slot arena.

    Drive it either one `step()` at a time (admission + one batched decode
    dispatch) or with `run()` until every submitted request finished.
    """

    def __init__(self, lm: LM, params: dict, qparams: Optional[dict], *,
                 max_slots: int = 4, max_seq: int = 64,
                 draft=None, draft_k: int = 4, paged: bool = False,
                 page_size: int = 16, kv_bits: Optional[int] = None,
                 n_pages: Optional[int] = None, prefix_sharing: bool = True,
                 mesh=None, param_axes: Optional[dict] = None,
                 scheduler=None):
        cfg = lm.cfg
        if cfg.num_codebooks or cfg.vision_patches:
            raise ValueError("the engine serves plain token LMs; codebook "
                             "and VLM prompts need a modality frontend — "
                             "use the static loop (serve.py --static / "
                             "serve_loop) for these archs")
        self.lm = lm
        self.max_slots = max_slots
        self.max_seq = max_seq
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self._cache_dtype = dt

        # --- tensor parallelism (DESIGN.md §4.12) ----------------------
        # params shard through the training ShardingPlan's TP rules (the
        # served dict's derived keys — .codes / .packed{b} / .scale — map
        # back to their base weight's axes by name); the KV arena shards
        # by KV head. Shapes the mesh can't divide replicate, recorded in
        # `tp_fallbacks` so the smoke can report them.
        self.mesh = mesh
        self._rep = None            # NamedSharding(mesh, P()) when TP
        self._arena_sh = None       # per-leaf shardings: slot/page arena
        self._row_sh = None         # ... a (1, max_seq) staging row
        self._darena_sh = None      # ... draft arena / draft row
        self._drow_sh = None
        self.tp_fallbacks: list = []
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as PSpec
            from repro.distributed import sharding as shlib
            if param_axes is None:
                # recover the logical axes without materializing a second
                # init: abstract-eval lm.init and capture the axes dict it
                # returns (names are stable across pruning — apply_slim_plan
                # reshapes, it never renames)
                captured: dict = {}

                def _cap(key):
                    p, a = lm.init(key)
                    captured.update(a)
                    return p

                jax.eval_shape(_cap, jax.random.PRNGKey(0))
                param_axes = captured
            plan = shlib.make_plan(mesh, mode="tp")
            pspecs = shlib.serving_param_specs(plan, param_axes, params)
            params = jax.device_put(
                params, {k: NamedSharding(mesh, s)
                         for k, s in pspecs.items()})
            if qparams is not None:
                qparams = jax.device_put(qparams,
                                         NamedSharding(mesh, PSpec()))
            self._rep = NamedSharding(mesh, PSpec())
            self.tp_fallbacks = list(plan.fallbacks)
        self.param_axes = dict(param_axes or {})
        self.params = params
        self.qparams = qparams
        mesh_ = mesh

        def _jit(fn, static_argnums=(), out_shardings=None):
            # every engine jit pins its output shardings under TP so the
            # arena never silently de-shards between dispatches; without a
            # mesh this is exactly jax.jit
            if mesh_ is None or out_shardings is None:
                return jax.jit(fn, static_argnums=static_argnums)
            return jax.jit(fn, static_argnums=static_argnums,
                           out_shardings=out_shardings)
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.kv_bits = kv_bits
        if kv_bits is not None and not self.paged:
            raise ValueError("kv_bits quantizes the *paged* page store; "
                             "pass paged=True")
        if self.paged:
            # paged block arena: attention K/V live in shared page pools
            # addressed through per-slot page tables; admission/eviction
            # become host-side allocator ops (launch/paging.py)
            self.Lp = paging.pages_for_rows(max_seq, self.page_size)
            if n_pages is None:
                # every slot can hold a full-length request, plus one
                # table's worth of headroom for prefix-cache entries
                n_pages = paging.N_RESERVED + (max_slots + 1) * self.Lp
            self.n_pages = int(n_pages)
            self.alloc = paging.PageAllocator(self.n_pages, self.page_size)
            self.prefix_cache = (paging.PrefixCache(self.alloc)
                                 if prefix_sharing else None)
            self.page_table = np.full((max_slots, self.Lp),
                                      paging.TRASH_PAGE, np.int32)
            self.slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
            self.caches = lm.init_paged_cache(max_slots, self.n_pages,
                                              self.page_size, dtype=dt,
                                              kv_bits=kv_bits)
        else:
            self.caches = lm.init_cache(max_slots, max_seq, dtype=dt)
        if mesh is not None:
            from jax.sharding import NamedSharding
            from repro.distributed import sharding as shlib
            arena_specs = shlib.kv_cache_specs(
                mesh, {k: v.shape for k, v in self.caches.items()})
            self._arena_sh = {k: NamedSharding(mesh, s)
                              for k, s in arena_specs.items()}
            self.caches = jax.device_put(self.caches, self._arena_sh)
            # prefill staging rows are contiguous (1, max_seq) caches even
            # under the paged arena — they get their own spec set
            row_tmpl = jax.eval_shape(
                lambda: lm.init_cache(1, max_seq, dtype=dt))
            row_specs = shlib.kv_cache_specs(
                mesh, {k: v.shape for k, v in row_tmpl.items()})
            self._row_sh = {k: NamedSharding(mesh, s)
                            for k, s in row_specs.items()}
        # host-side slot table: position, last emitted token, owner
        self.pos = np.zeros((max_slots,), np.int32)
        self.last_tok = np.zeros((max_slots,), np.int32)
        self.active: list[Optional[Request]] = [None] * max_slots
        self.queue: deque[Request] = deque()
        self.done: dict[int, Request] = {}
        self._next_rid = 0
        self.stats = {"decode_steps": 0, "decode_tokens": 0, "decode_s": 0.0,
                      "prefills": 0, "prefill_tokens": 0, "prefill_s": 0.0,
                      "draft_prefills": 0, "draft_prefill_tokens": 0,
                      "draft_prefill_s": 0.0, "prefix_hits": 0,
                      "admitted": 0, "evicted": 0,
                      "spec_steps": 0, "spec_drafted": 0, "spec_accepted": 0,
                      "prefill_chunks": 0, "chunked_prefills": 0,
                      "decode_steps_mid_prefill": 0}
        self.serving_meta: dict = {}   # prepare_serving meta (build_engine)

        # speculative decoding: a DraftModel (launch/speculative.py) adds
        # a second KV arena sharing this engine's slot/position tables
        self.draft = draft
        self.draft_k = int(draft_k)
        self.dcaches = None
        if draft is not None:
            from repro.launch.speculative import make_spec_step
            if cfg.window > 0:
                raise ValueError(
                    "speculative decoding needs full (window == 0) KV "
                    "arenas: a ring wrap overwrites pre-wrap rows that a "
                    "rejection could never roll back")
            bad = sorted({s.mixer for s in lm.plan if s.mixer != "attn"})
            if bad:
                raise ValueError(
                    f"speculative decoding needs attention mixers "
                    f"everywhere (rollback zeroes KV rows); plan has "
                    f"{bad} layers whose recurrent state cannot be "
                    f"rolled back")
            if not 1 <= self.draft_k < max_seq:
                raise ValueError(
                    f"draft_k={self.draft_k} must be in [1, "
                    f"max_seq={max_seq})")
            if self.paged:
                # the draft arena pages in lockstep: its own pools (at the
                # draft's sliced KV shapes) indexed by the *same* page
                # table and allocator — one allocation covers both arenas
                self.dcaches = draft.lm.init_paged_cache(
                    max_slots, self.n_pages, self.page_size, dtype=dt,
                    kv_bits=kv_bits)
            else:
                self.dcaches = draft.lm.init_cache(max_slots, max_seq,
                                                   dtype=dt)
            if mesh is not None:
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as PSpec
                from repro.distributed import sharding as shlib
                # the draft arena shards by *its* (sliced) KV heads; the
                # draft's served params shard through the same TP rules
                dspecs = shlib.kv_cache_specs(
                    mesh, {k: v.shape for k, v in self.dcaches.items()})
                self._darena_sh = {k: NamedSharding(mesh, s)
                                   for k, s in dspecs.items()}
                self.dcaches = jax.device_put(self.dcaches, self._darena_sh)
                drow_tmpl = jax.eval_shape(
                    lambda: draft.lm.init_cache(1, max_seq, dtype=dt))
                drow_specs = shlib.kv_cache_specs(
                    mesh, {k: v.shape for k, v in drow_tmpl.items()})
                self._drow_sh = {k: NamedSharding(mesh, s)
                                 for k, s in drow_specs.items()}
                dcap: dict = {}

                def _dcap(key):
                    p, a = draft.lm.init(key)
                    dcap.update(a)
                    return p

                jax.eval_shape(_dcap, jax.random.PRNGKey(0))
                dplan = shlib.make_plan(mesh, mode="tp")
                dpspecs = shlib.serving_param_specs(dplan, dcap,
                                                    draft.params)
                draft.params = jax.device_put(
                    draft.params, {k: NamedSharding(mesh, s)
                                   for k, s in dpspecs.items()})
                if draft.qparams is not None:
                    draft.qparams = jax.device_put(
                        draft.qparams, NamedSharding(mesh, PSpec()))
                self.tp_fallbacks += [("draft:" + n, a, d)
                                      for n, a, d in dplan.fallbacks]
            spec_fn = make_spec_step(lm, draft.lm)
            self._spec = _jit(
                spec_fn, static_argnums=(8,),
                out_shardings=(self._rep, self._rep, self._arena_sh,
                               self._darena_sh))

            def _prefill_draft(dparams, dqparams, tokens):
                c = draft.lm.init_cache(1, max_seq, dtype=dt)
                _, c = draft.lm.prefill(dparams, dqparams, c, tokens,
                                        last_logit_only=True)
                return c

            self._prefill_draft = _jit(_prefill_draft,
                                       out_shardings=self._drow_sh)

        def _prefill(params, qparams, tokens):
            caches = lm.init_cache(1, max_seq, dtype=dt)
            # only the last position feeds decode: skip the (S-1) x vocab
            # head GEMM the full-logits prefill would burn per admission
            logits, caches = lm.prefill(params, qparams, caches, tokens,
                                        last_logit_only=True)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, caches

        def _insert(caches, row, slot):
            def ins(c, r):
                idx = (0, slot) + (0,) * (c.ndim - 2)
                return jax.lax.dynamic_update_slice(c, r.astype(c.dtype), idx)
            return jax.tree_util.tree_map(ins, caches, row)

        def _decode(params, qparams, caches, tok, pos):
            logits, caches = lm.decode_step(params, qparams, caches, tok, pos)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, caches

        def _decode_window(params, qparams, caches, tok, pos, k):
            # k event-free steps fused into one dispatch: between two
            # admission/eviction events (whose timing is count-based and
            # known in advance) nothing on the host needs the tokens, so
            # the loop runs on-device and syncs once per window.
            def body(carry, _):
                caches, tok, pos = carry
                logits, caches = lm.decode_step(params, qparams, caches,
                                                tok, pos)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return (caches, nxt[:, None], pos + 1), nxt

            (caches, _, _), toks = jax.lax.scan(
                body, (caches, tok, pos), None, length=k)
            return toks, caches     # toks: (k, B)

        self._prefill = _jit(_prefill,
                             out_shardings=((self._rep, self._row_sh)
                                            if mesh is not None else None))
        # _insert used to serve both arenas (target AND draft rows through
        # one unpinned jit, keyed by avals) relying on dynamic_update_slice
        # propagating the operand's sharding — the exact operand-propagation
        # hole the sharding-pin audit (repro.analysis, DESIGN.md §4.13)
        # exists to flag. Each arena now gets its own pinned clone:
        # _insert writes target rows under the target arena's sharding
        # tree, _insert_d (created only with a draft attached) under the
        # draft's. Same function, same per-arena compile count as before.
        self._insert = _jit(_insert, out_shardings=self._arena_sh)
        self._insert_d = (_jit(_insert, out_shardings=self._darena_sh)
                          if draft is not None else None)
        self._decode = _jit(_decode,
                            out_shardings=((self._rep, self._arena_sh)
                                           if mesh is not None else None))
        # one compile per distinct window length (static scan trip count)
        self._decode_window = _jit(
            _decode_window, static_argnums=(5,),
            out_shardings=((self._rep, self._arena_sh)
                           if mesh is not None else None))

        if self.paged:
            P = self.page_size
            Lp = self.Lp
            kvb = self.kv_bits
            kv_keys, state_keys = _kv_split(self.caches)

            def _pages_view(pt):
                return model_layers.PagedView(table=pt, page_size=P,
                                              seq_len=max_seq, kv_bits=kvb)

            def make_insert_pages(kv, state, out_sh=None):
                # scatter a fresh (1, max_seq) prefill cache into the
                # slot's first npp physical pages (whole-page writes: the
                # prefill's zero tail keeps page remainders zero), and
                # slot-insert the recurrent-state leaves as before
                def ins(caches, row, slot, phys, npp):
                    new = dict(caches)
                    for kk in kv:
                        r = row[kk][:, 0]                 # (nb, S, KVh, dh)
                        pad = npp * P - r.shape[1]
                        if pad > 0:
                            r = jnp.pad(r, ((0, 0), (0, pad))
                                        + ((0, 0),) * (r.ndim - 2))
                        blocks = r[:, :npp * P].reshape(
                            (r.shape[0], npp, P) + r.shape[2:])
                        if kvb is not None:
                            codes, scale = kv_quant_encode(blocks, kvb)
                            new[kk] = caches[kk].at[:, phys].set(
                                codes.astype(caches[kk].dtype))
                            sk = kk + "_scale"
                            new[sk] = caches[sk].at[:, phys].set(scale)
                        else:
                            new[kk] = caches[kk].at[:, phys].set(
                                blocks.astype(caches[kk].dtype))
                    for sk in state:
                        c = caches[sk]
                        idx = (0, slot) + (0,) * (c.ndim - 2)
                        new[sk] = jax.lax.dynamic_update_slice(
                            c, row[sk].astype(c.dtype), idx)
                    return new
                return _jit(ins, static_argnums=(4,), out_shardings=out_sh)

            def make_zero_pages(kv, out_sh=None):
                def zero(caches, ids):
                    new = dict(caches)
                    for kk in kv:
                        new[kk] = caches[kk].at[:, ids].set(
                            jnp.zeros((), caches[kk].dtype))
                        if kvb is not None:
                            sk = kk + "_scale"
                            new[sk] = caches[sk].at[:, ids].set(
                                jnp.zeros((), caches[sk].dtype))
                    return new
                return _jit(zero, out_shardings=out_sh)

            def make_copy_page(kv, out_sh=None):
                def cp(caches, src, dst):
                    new = dict(caches)
                    for kk in kv:
                        new[kk] = caches[kk].at[:, dst].set(caches[kk][:, src])
                        if kvb is not None:
                            sk = kk + "_scale"
                            new[sk] = caches[sk].at[:, dst].set(
                                caches[sk][:, src])
                    return new
                return _jit(cp, out_shardings=out_sh)

            def _decode_paged(params, qparams, caches, tok, pos, pt):
                logits, caches = lm.decode_step(params, qparams, caches, tok,
                                                pos, pages=_pages_view(pt))
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, caches

            def _decode_window_paged(params, qparams, caches, tok, pos, pt,
                                     k):
                pages = _pages_view(pt)

                def body(carry, _):
                    caches, tok, pos = carry
                    logits, caches = lm.decode_step(params, qparams, caches,
                                                    tok, pos, pages=pages)
                    nxt = jnp.argmax(logits[:, -1],
                                     axis=-1).astype(jnp.int32)
                    return (caches, nxt[:, None], pos + 1), nxt

                (caches, _, _), toks = jax.lax.scan(
                    body, (caches, tok, pos), None, length=k)
                return toks, caches

            self._insert_pages = make_insert_pages(kv_keys, state_keys,
                                                   self._arena_sh)
            self._zero_pages = make_zero_pages(kv_keys, self._arena_sh)
            self._copy_page = make_copy_page(kv_keys, self._arena_sh)
            self._decode_paged = _jit(
                _decode_paged,
                out_shardings=((self._rep, self._arena_sh)
                               if mesh is not None else None))
            self._decode_window_paged = _jit(
                _decode_window_paged, static_argnums=(6,),
                out_shardings=((self._rep, self._arena_sh)
                               if mesh is not None else None))

            if draft is not None:
                dkv_keys, dstate_keys = _kv_split(self.dcaches)
                self._insert_pages_d = make_insert_pages(dkv_keys,
                                                         dstate_keys,
                                                         self._darena_sh)
                self._zero_pages_d = make_zero_pages(dkv_keys,
                                                     self._darena_sh)
                self._copy_page_d = make_copy_page(dkv_keys,
                                                   self._darena_sh)

                def make_gather(kv, state):
                    # materialize each slot's contiguous (max_seq-row)
                    # arena view from its pages: gather, dequantize if the
                    # pool holds codes, and SLICE to the logical length —
                    # the slice keeps the spec step's reductions the exact
                    # shape the contiguous engine runs, so token identity
                    # survives the round trip
                    def gather(caches, pt):
                        views = {}
                        for kk in kv:
                            pages_ = jnp.take(caches[kk], pt, axis=1)
                            if kvb is not None:
                                sc = jnp.take(caches[kk + "_scale"], pt,
                                              axis=1)
                                pages_ = kv_quant_decode(pages_, sc, kvb)
                            rows = pages_.reshape(
                                pages_.shape[:2] + (Lp * P,)
                                + pages_.shape[4:])
                            views[kk] = rows[:, :, :max_seq].astype(dt)
                        for sk in state:
                            views[sk] = caches[sk]
                        return views
                    return gather

                def make_scatter(kv):
                    # write back only the pages a spec round could have
                    # touched: rows [pos, pos+k] span at most k//P + 2
                    # logical pages per slot. Clamped duplicates write
                    # identical blocks; pages past a slot's allocation
                    # alias the zero page and receive (exactly) zeros.
                    def scatter(caches, views, pt, pos, k):
                        new = dict(caches)
                        first_lp = pos // P
                        npg = min(k // P + 2, Lp)
                        for kk in kv:
                            view = views[kk]      # (nb, B, max_seq, ...)
                            pad = Lp * P - view.shape[2]
                            vp = jnp.pad(view, ((0, 0), (0, 0), (0, pad))
                                         + ((0, 0),) * (view.ndim - 3))
                            vB = jnp.moveaxis(vp, 1, 0)   # (B, nb, rows, .)
                            for j in range(npg):
                                lp = jnp.clip(first_lp + j, 0, Lp - 1)
                                phys = jnp.take_along_axis(
                                    pt, lp[:, None], axis=1)[:, 0]
                                blk = jax.vmap(
                                    lambda vb, s: jax.lax.dynamic_slice_in_dim(
                                        vb, s, P, axis=1))(vB, lp * P)
                                blk = jnp.moveaxis(blk, 0, 1)
                                if kvb is not None:
                                    codes, scale = kv_quant_encode(blk, kvb)
                                    new[kk] = new[kk].at[:, phys].set(
                                        codes.astype(new[kk].dtype))
                                    sk = kk + "_scale"
                                    new[sk] = new[sk].at[:, phys].set(scale)
                                else:
                                    new[kk] = new[kk].at[:, phys].set(
                                        blk.astype(new[kk].dtype))
                        return new
                    return scatter

                tgather = make_gather(kv_keys, state_keys)
                dgather = make_gather(dkv_keys, dstate_keys)
                tscatter = make_scatter(kv_keys)
                dscatter = make_scatter(dkv_keys)

                def _spec_paged(tp, tq, dp, dq, tc, dc, tok, pos, pt, k):
                    tv = tgather(tc, pt)
                    dv = dgather(dc, pt)
                    tgt, ncm, tv, dv = spec_fn(tp, tq, dp, dq, tv, dv,
                                               tok, pos, k)
                    tc = tscatter(tc, tv, pt, pos, k)
                    dc = dscatter(dc, dv, pt, pos, k)
                    return tgt, ncm, tc, dc

                self._spec_paged = _jit(
                    _spec_paged, static_argnums=(9,),
                    out_shardings=(self._rep, self._rep, self._arena_sh,
                                   self._darena_sh))

        # --- step scheduling policy + chunked-prefill staging ----------
        from repro.launch.scheduler import OneShotScheduler
        self.scheduler = scheduler if scheduler is not None \
            else OneShotScheduler()
        self._handoff: deque = deque()     # (req, first_token, row) staged
        self._prefill_job = None           # scheduler.PrefillJob in flight
        chunk = getattr(self.scheduler, "chunk", None)
        self._chunk = int(chunk) if chunk else None

        def _fresh_row():
            row = lm.init_cache(1, max_seq, dtype=dt)
            if mesh_ is not None:
                row = jax.device_put(row, self._row_sh)
            return row

        self._fresh_row = _fresh_row
        if self._chunk:
            # chunked prefill stages through LM.verify_chunk (absolute
            # positions into an existing cache), which carries the same
            # preconditions as speculative rollback
            if cfg.window > 0:
                raise ValueError(
                    "chunked prefill needs full (window == 0) KV arenas: "
                    "verify_chunk writes at absolute positions and a ring "
                    "wrap would fold chunk rows onto each other")
            bad = sorted({s.mixer for s in lm.plan if s.mixer != "attn"})
            if bad:
                raise ValueError(
                    f"chunked prefill needs attention mixers everywhere "
                    f"(each chunk resumes from cache rows alone); plan "
                    f"has {bad} layers with recurrent state that one-shot "
                    f"prefill threads internally")

            def _prefill_chunk(params, qparams, caches, tokens, pos):
                # verify_chunk semantics: tokens[:, 0] is the first
                # uncommitted prompt row, K/V land at rows
                # [pos, pos+T), and logits[:, -1] predicts the token
                # after the last fed row — on the final chunk that IS the
                # request's first generated token, same as _prefill's
                logits, caches = lm.verify_chunk(params, qparams, caches,
                                                 tokens, pos,
                                                 last_logit_only=True)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, caches

            self._prefill_chunk = _jit(
                _prefill_chunk,
                out_shardings=((self._rep, self._row_sh)
                               if mesh_ is not None else None))

    # ------------------------------------------------------------- requests
    def submit(self, prompt, max_new_tokens: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        # rows actually written: the prompt occupies [0, S), the first
        # token comes out of the prefill itself, and the last of the
        # N-1 decode steps writes row S+N-2 — so a request needs S+N-1
        # arena rows (checking S+N left one row per slot unusable)
        if prompt.size + max_new_tokens - 1 > self.max_seq:
            raise ValueError(
                f"request needs {prompt.size + max_new_tokens - 1} cache "
                f"rows, arena rows hold {self.max_seq}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.paged:
            need = paging.pages_for_rows(
                prompt.size + max_new_tokens - 1, self.page_size)
            if need > self.n_pages - paging.N_RESERVED:
                raise ValueError(
                    f"request needs {need} KV pages, pool holds "
                    f"{self.n_pages - paging.N_RESERVED} allocatable pages")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid, prompt=prompt,
                                  max_new_tokens=max_new_tokens,
                                  submit_t=time.time()))
        return rid

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.active)

    @property
    def pending(self) -> bool:
        return (bool(self.queue) or self.n_active > 0
                or self._prefill_job is not None or bool(self._handoff))

    # ------------------------------------------------------------ lifecycle
    def _admit(self) -> int:
        """Prefill queued requests into free slots. Returns #admitted."""
        admitted = 0
        if self.paged:
            self._flush_dirty()
        blocked = False
        for slot in range(self.max_slots):
            if blocked:
                break
            # retry the same slot until a request actually occupies it:
            # a one-token request completes at admission and must not
            # leave the slot empty while the queue still has work
            while self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                if self.paged:
                    got = self._admit_paged(req, slot)
                    if got is None:
                        # allocator pressure even after dropping prefix
                        # entries: requeue and wait for an eviction
                        self.queue.appendleft(req)
                        blocked = True
                        break
                    admitted += int(got)
                    continue
                t0 = time.time()
                nxt, row = self._prefill(self.params, self.qparams,
                                         jnp.asarray(req.prompt)[None])
                first = int(jax.block_until_ready(nxt)[0])
                self.stats["prefill_s"] += time.time() - t0
                self.stats["prefills"] += 1
                self.stats["prefill_tokens"] += int(req.prompt.size)
                self.stats["admitted"] += 1
                req.admit_t = time.time()
                req.tokens.append(first)
                if req.done:    # one-token request: never occupies a slot
                    self._finish(req)
                    continue
                self.caches = self._insert(self.caches, row, jnp.int32(slot))
                if self.draft is not None:
                    # the draft arena admits in lockstep: its own one-shot
                    # prefill (at the draft's sliced shapes) into the same
                    # slot, so both arenas agree on position bookkeeping
                    # from the first speculative round. Its wall time and
                    # tokens are draft work — they ride their own
                    # counters, not the target prefill rate's
                    t1 = time.time()
                    drow = self._prefill_draft(self.draft.params,
                                               self.draft.qparams,
                                               jnp.asarray(req.prompt)[None])
                    self.dcaches = self._insert_d(self.dcaches, drow,
                                                  jnp.int32(slot))
                    jax.block_until_ready(
                        jax.tree_util.tree_leaves(self.dcaches)[0])
                    self.stats["draft_prefill_s"] += time.time() - t1
                    self.stats["draft_prefills"] += 1
                    self.stats["draft_prefill_tokens"] += int(req.prompt.size)
                self.pos[slot] = req.prompt.size
                self.last_tok[slot] = first
                req.slot = slot
                self.active[slot] = req
                admitted += 1
        return admitted

    # ------------------------------------------------------ paged lifecycle
    def _flush_dirty(self) -> None:
        """Zero released pages on device and return them to the free list
        (the allocator's zero-before-reuse contract). Batched into pow2
        buckets so the compiled-shape set stays bounded; the padding ids
        hit the reserved zero page, where writing zeros is a no-op."""
        dirty = self.alloc.take_dirty()
        if not dirty:
            return
        m = 1
        while m < len(dirty):
            m *= 2
        ids = np.full((m,), paging.ZERO_PAGE, np.int32)
        ids[:len(dirty)] = dirty
        ids = jnp.asarray(ids)
        self.caches = self._zero_pages(self.caches, ids)
        if self.dcaches is not None:
            self.dcaches = self._zero_pages_d(self.dcaches, ids)
        self.alloc.mark_zeroed(dirty)

    def _reserve_pages(self, n: int, keep_last: bool = False) -> bool:
        """Make n pages allocatable, dropping LRU prefix-cache entries
        under pressure. `keep_last` protects the most-recently-used entry
        (the hit being admitted against)."""
        floor = 1 if keep_last else 0
        while not self.alloc.can_alloc(n):
            if self.prefix_cache is None or len(self.prefix_cache) <= floor:
                return False
            self.prefix_cache.drop_lru()
            self._flush_dirty()
        return True

    def _admit_paged(self, req: Request, slot: int,
                     prefilled=None) -> Optional[bool]:
        """Admit one request into `slot` under the paged arena. Returns
        True (occupies the slot), False (finished at admission — retry
        the slot), or None (allocator pressure — requeue).

        `prefilled=(first_token, row_cache)` supplies an already-staged
        chunked prefill (the handoff path): the prefill dispatch and its
        stats are skipped, everything downstream — page scatter, draft
        prefill, prefix-cache registration — runs identically."""
        P = self.page_size
        S = int(req.prompt.size)
        npg_req = paging.pages_for_rows(S + req.max_new_tokens - 1, P)
        n_full = S // P              # pages fully covered by prompt rows
        partial = S % P != 0
        cache = self.prefix_cache
        ent = cache.lookup(req.prompt) if cache is not None else None

        if req.max_new_tokens == 1:
            # one-token request: the answer is the (possibly memoized)
            # prefill argmax — no pages, no slot
            if ent is not None:
                first = int(ent.first_token)
                self.stats["prefix_hits"] += 1
            elif prefilled is not None:
                first = int(prefilled[0])
            else:
                t0 = time.time()
                nxt, _ = self._prefill(self.params, self.qparams,
                                       jnp.asarray(req.prompt)[None])
                first = int(jax.block_until_ready(nxt)[0])
                self.stats["prefill_s"] += time.time() - t0
                self.stats["prefills"] += 1
                self.stats["prefill_tokens"] += S
            self.stats["admitted"] += 1
            req.admit_t = time.time()
            req.tokens.append(first)
            self._finish(req)
            return False

        if ent is not None:
            # prefix hit: share the full prompt pages in place (one more
            # refcount), CoW-copy the pristine tail template into an
            # owned page, reuse the memoized first token — and skip both
            # prefill dispatches entirely
            n_owned = npg_req - n_full
            if not self._reserve_pages(n_owned, keep_last=True):
                return None
            owned = self.alloc.alloc(n_owned)
            self.alloc.retain(ent.full_pages)
            pages = list(ent.full_pages) + owned
            if partial:
                src = jnp.int32(ent.tail_page)
                dst = jnp.int32(owned[0])
                self.caches = self._copy_page(self.caches, src, dst)
                if self.dcaches is not None:
                    self.dcaches = self._copy_page_d(self.dcaches, src, dst)
            first = int(ent.first_token)
            self.stats["prefix_hits"] += 1
        else:
            if not self._reserve_pages(npg_req):
                return None
            pages = self.alloc.alloc(npg_req)
            npp = paging.pages_for_rows(S, P)    # pages the prompt covers
            if prefilled is not None:
                first, row = prefilled
                first = int(first)
            else:
                t0 = time.time()
                nxt, row = self._prefill(self.params, self.qparams,
                                         jnp.asarray(req.prompt)[None])
                first = int(jax.block_until_ready(nxt)[0])
                self.stats["prefill_s"] += time.time() - t0
                self.stats["prefills"] += 1
                self.stats["prefill_tokens"] += S
            phys = jnp.asarray(np.asarray(pages[:npp], np.int32))
            self.caches = self._insert_pages(self.caches, row,
                                             jnp.int32(slot), phys, npp)
            if self.draft is not None:
                t1 = time.time()
                drow = self._prefill_draft(self.draft.params,
                                           self.draft.qparams,
                                           jnp.asarray(req.prompt)[None])
                self.dcaches = self._insert_pages_d(self.dcaches, drow,
                                                    jnp.int32(slot), phys,
                                                    npp)
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(self.dcaches)[0])
                self.stats["draft_prefill_s"] += time.time() - t1
                self.stats["draft_prefills"] += 1
                self.stats["draft_prefill_tokens"] += S
            if cache is not None:
                # register the prompt for sharing (best effort): the
                # cache takes its own refcount on the full pages and a
                # pristine device copy of the partial tail page — copied
                # *now*, before this owner's first decode write lands in
                # it. Sharing is whole-prompt-hash keyed: prefix K/V rows
                # are not bitwise stable across prefills of different
                # total lengths, identical prompts are (paging.py).
                tmpl = None
                if partial and self.alloc.can_alloc(1):
                    tmpl = self.alloc.alloc(1)[0]
                    src = jnp.int32(pages[n_full])
                    self.caches = self._copy_page(self.caches, src,
                                                  jnp.int32(tmpl))
                    if self.dcaches is not None:
                        self.dcaches = self._copy_page_d(self.dcaches, src,
                                                         jnp.int32(tmpl))
                if (n_full or tmpl is not None) and not (partial
                                                         and tmpl is None):
                    self.alloc.retain(pages[:n_full])
                    cache.insert(paging.PrefixEntry(
                        key=paging.prompt_key(req.prompt), prompt_len=S,
                        full_pages=tuple(pages[:n_full]), tail_page=tmpl,
                        first_token=first))

        pt_row = np.full((self.Lp,), paging.ZERO_PAGE, np.int32)
        pt_row[:len(pages)] = pages
        self.page_table[slot] = pt_row
        self.slot_pages[slot] = list(pages)
        self.pos[slot] = S
        self.last_tok[slot] = first
        self.stats["admitted"] += 1
        req.admit_t = time.time()
        req.tokens.append(first)
        req.slot = slot
        self.active[slot] = req
        return True

    def _finish(self, req: Request) -> None:
        req.finish_t = time.time()
        if req.slot >= 0:
            if self.paged:
                # eviction is a page release: refcounts drop, pages whose
                # last owner left go to the dirty quarantine (zeroed at
                # the next admission / drain), and the slot's table rows
                # point back at the trash page so its idle decode writes
                # can't touch live pages
                self.alloc.release(self.slot_pages[req.slot])
                self.slot_pages[req.slot] = []
                self.page_table[req.slot, :] = paging.TRASH_PAGE
                self.pos[req.slot] = 0
            self.active[req.slot] = None
            req.slot = -1
            self.stats["evicted"] += 1
        self.done[req.rid] = req

    def step(self) -> bool:
        """One engine iteration, shaped by the scheduler policy: the
        policy plans an ordered action tuple ("admit", "handoff",
        "prefill_chunk", "decode") and the engine executes it. The default
        OneShotScheduler plans ("admit", "decode") — the classic
        iteration, verbatim. Returns False when no action made progress
        (idle)."""
        progress = False
        for act in self.scheduler.plan_step(self):
            progress = bool(getattr(self, "_act_" + act)()) or progress
        return progress

    def _act_admit(self) -> bool:
        return self._admit() > 0

    def _act_decode(self) -> bool:
        """One batched decode over every active slot — or, with a draft
        attached, one speculative draft/verify round committing
        1..k_eff+1 tokens per slot."""
        if self.n_active == 0:
            return False
        if self.draft is not None:
            return self._spec_round()
        tok = jnp.asarray(self.last_tok)[:, None]
        pos = jnp.asarray(self.pos)
        t0 = time.time()
        if self.paged:
            nxt, self.caches = self._decode_paged(
                self.params, self.qparams, self.caches, tok, pos,
                jnp.asarray(self.page_table))
        else:
            nxt, self.caches = self._decode(self.params, self.qparams,
                                            self.caches, tok, pos)
        nxt = np.asarray(jax.block_until_ready(nxt))
        self.stats["decode_s"] += time.time() - t0
        self.stats["decode_steps"] += 1
        if self._prefill_job is not None:
            # the disaggregation liveness stat: decode batches that ran
            # while a prompt was mid-prefill. The one-shot engine's value
            # is identically zero — it cannot decode during a prefill.
            self.stats["decode_steps_mid_prefill"] += 1
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.stats["decode_tokens"] += 1
            req.tokens.append(int(nxt[slot]))
            self.last_tok[slot] = nxt[slot]
            self.pos[slot] += 1
            if req.done:
                self._finish(req)
        return True

    # ------------------------------------------------- chunked prefill path
    def _act_prefill_chunk(self) -> bool:
        """Advance the in-flight prefill by one chunk (starting a new job
        from the queue when none is in flight). A finished job moves to
        the handoff queue with its staged row cache and memoized first
        token; a paged prefix-cache hit skips staging entirely and hands
        off immediately."""
        if self._prefill_job is None:
            if not self.queue or len(self._handoff) >= self.max_slots:
                return False
            req = self.queue.popleft()
            if (self.paged and self.prefix_cache is not None
                    and self.prefix_cache.lookup(req.prompt) is not None):
                # hot prompt: pages and first token are already pinned —
                # no prefill work at all, _admit_paged redoes the lookup
                self._handoff.append((req, None, None))
                return True
            from repro.launch.scheduler import PrefillJob, chunk_plan
            self._prefill_job = PrefillJob(
                req=req, caches=self._fresh_row(),
                chunks=chunk_plan(int(req.prompt.size), self._chunk))
        job = self._prefill_job
        c = job.chunks.pop(0)
        toks = jnp.asarray(
            job.req.prompt[job.done_rows:job.done_rows + c])[None]
        t0 = time.time()
        nxt, job.caches = self._prefill_chunk(
            self.params, self.qparams, job.caches, toks,
            jnp.full((1,), job.done_rows, jnp.int32))
        first = int(jax.block_until_ready(nxt)[0])
        self.stats["prefill_s"] += time.time() - t0
        self.stats["prefill_chunks"] += 1
        job.done_rows += c
        if not job.chunks:
            job.first = first       # the request's first generated token
            self.stats["prefills"] += 1
            self.stats["chunked_prefills"] += 1
            self.stats["prefill_tokens"] += int(job.req.prompt.size)
            self._handoff.append((job.req, job.first, job.caches))
            self._prefill_job = None
        return True

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _act_handoff(self) -> bool:
        """Admit finished prefill jobs from the handoff queue into free
        slots — the KV handoff. A staged row inserts exactly like the
        one-shot path's fresh prefill row, so decode state is identical
        from the first step. Stops at the first entry that cannot place
        (no free slot / allocator pressure), preserving FIFO order."""
        progress = False
        if self.paged:
            self._flush_dirty()
        while self._handoff:
            req, first, row = self._handoff[0]
            if self.paged:
                slot = self._free_slot()
                if req.max_new_tokens > 1 and slot is None:
                    break
                got = self._admit_paged(
                    req, -1 if slot is None else slot,
                    prefilled=None if first is None else (first, row))
                if got is None:
                    break
            elif req.max_new_tokens == 1:
                # one-token request: the staged first token IS the answer
                self.stats["admitted"] += 1
                req.admit_t = time.time()
                req.tokens.append(int(first))
                self._finish(req)
            else:
                slot = self._free_slot()
                if slot is None:
                    break
                self._insert_staged(req, int(first), row, slot)
            self._handoff.popleft()
            progress = True
        return progress

    def _insert_staged(self, req: Request, first: int, row, slot: int
                       ) -> None:
        """Contiguous-arena tail of admission from a staged row cache:
        the one-shot path's post-prefill bookkeeping, reused verbatim by
        the handoff queue."""
        self.caches = self._insert(self.caches, row, jnp.int32(slot))
        if self.draft is not None:
            # the draft arena still prefills one-shot at handoff (its
            # sliced shapes make this the cheap half); chunking the draft
            # too would need a second staging row per job
            t1 = time.time()
            drow = self._prefill_draft(self.draft.params,
                                       self.draft.qparams,
                                       jnp.asarray(req.prompt)[None])
            self.dcaches = self._insert_d(self.dcaches, drow,
                                          jnp.int32(slot))
            jax.block_until_ready(
                jax.tree_util.tree_leaves(self.dcaches)[0])
            self.stats["draft_prefill_s"] += time.time() - t1
            self.stats["draft_prefills"] += 1
            self.stats["draft_prefill_tokens"] += int(req.prompt.size)
        self.stats["admitted"] += 1
        req.admit_t = time.time()
        req.tokens.append(first)
        self.pos[slot] = req.prompt.size
        self.last_tok[slot] = first
        req.slot = slot
        self.active[slot] = req

    def _spec_ks(self) -> list[int]:
        """Draft-window lengths the speculative path can dispatch at:
        {0} + powers of two <= draft_k — `_spec_round` quantizes to this
        set, so it is exactly the compiled-shape set `warmup` covers."""
        ks = [0]
        k = 1
        while k <= self.draft_k:
            ks.append(k)
            k *= 2
        return ks

    def _spec_round(self) -> bool:
        """One speculative draft/verify/commit round over active slots.

        k_eff = pow2_floor(min(draft_k, min remaining - 1)): the pow2
        floor keeps the compiled spec-step set bounded (`_spec_ks`, the
        warmup contract), and capping at min-remaining-1 guarantees every
        slot's k_eff+1 writes stay inside its [0, prompt+budget) arena
        prefix and its commits inside the token budget — the target's
        free token rides on top of at most k_eff accepted proposals, so a
        round commits at most `remaining` tokens and never truncates.
        k_eff = 0 (a slot is one token from done) degenerates to a plain
        one-token verify that still runs the draft scan once, keeping the
        draft arena in sync through the same code path."""
        from repro.launch.speculative import pow2_floor
        rem = min(req.max_new_tokens - len(req.tokens)
                  for req in self.active if req is not None)
        k = pow2_floor(min(self.draft_k, rem - 1))
        tok = jnp.asarray(self.last_tok)[:, None]
        pos = jnp.asarray(self.pos)
        t0 = time.time()
        if self.paged:
            tgt, ncm, self.caches, self.dcaches = self._spec_paged(
                self.params, self.qparams, self.draft.params,
                self.draft.qparams, self.caches, self.dcaches, tok, pos,
                jnp.asarray(self.page_table), k)
        else:
            tgt, ncm, self.caches, self.dcaches = self._spec(
                self.params, self.qparams, self.draft.params,
                self.draft.qparams, self.caches, self.dcaches, tok, pos, k)
        tgt = np.asarray(jax.block_until_ready(tgt))
        ncm = np.asarray(ncm)
        self.stats["decode_s"] += time.time() - t0
        # one round advances k+1 positions' worth of scoring in one
        # dispatch: decode_steps counts positions scored (slot_occupancy
        # keeps its meaning), decode_tokens counts only *committed*
        # tokens — drafted-but-rejected work shows up as the gap between
        # spec_drafted and spec_accepted, never as throughput
        self.stats["decode_steps"] += k + 1
        self.stats["spec_steps"] += 1
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            n = int(ncm[slot])
            self.stats["decode_tokens"] += n
            self.stats["spec_drafted"] += k
            self.stats["spec_accepted"] += n - 1
            req.tokens.extend(int(t) for t in tgt[slot, :n])
            self.last_tok[slot] = tgt[slot, n - 1]
            self.pos[slot] += n
            if req.done:
                self._finish(req)
        return True

    MAX_WINDOW = 32

    def warmed_window_ks(self) -> list[int]:
        """Window lengths `warmup()` precompiles: powers of two up to
        MAX_WINDOW. `_window` quantizes every dispatch to
        min(pow2_floor(remaining), MAX_WINDOW), so this set must cover
        everything reachable — the compile-set audit (repro.analysis)
        recomputes the reachable set independently and diffs it against
        this one."""
        ks, k = [], 1
        while k <= self.MAX_WINDOW:
            ks.append(k)
            k *= 2
        return ks

    def warmup(self) -> None:
        """Compile the decode dispatches on dummy inputs (slot state and
        caches untouched) so the first timed window measures decode, not
        XLA: every power-of-two window length (the `run()` path decodes
        exclusively through windows; the single-step `step()` path warms
        lazily on first use) plus the queued prompt lengths' prefills.
        With a draft attached, the speculative step compiles instead —
        one spec-step per k in `_spec_ks()` (the k_eff quantization
        guarantees no other shape can be dispatched) plus the draft's own
        prefills — so the compiled-shape set stays bounded either way.

        A chunked-prefill engine warms a different set: the single-step
        decode (its `run()` drives `step()`, never the window family) and
        one `_prefill_chunk` compile per bucket in
        `chunk_buckets(chunk)` — `chunk_plan`'s pow2 remainder
        decomposition guarantees no prompt length can dispatch any other
        chunk shape, so the compile set is bounded by the chunk size, not
        the workload's prompt lengths."""
        tok = jnp.zeros((self.max_slots, 1), jnp.int32)
        pos = jnp.zeros((self.max_slots,), jnp.int32)
        pt = jnp.asarray(self.page_table) if self.paged else None
        if self.draft is not None:
            for k in self._spec_ks():
                if self.paged:
                    tgt, _, _, _ = self._spec_paged(
                        self.params, self.qparams, self.draft.params,
                        self.draft.qparams, self.caches, self.dcaches,
                        tok, pos, pt, k)
                else:
                    tgt, _, _, _ = self._spec(
                        self.params, self.qparams, self.draft.params,
                        self.draft.qparams, self.caches, self.dcaches,
                        tok, pos, k)
                jax.block_until_ready(tgt)
        elif self._chunk:
            if self.paged:
                nxt, _ = self._decode_paged(self.params, self.qparams,
                                            self.caches, tok, pos, pt)
            else:
                nxt, _ = self._decode(self.params, self.qparams,
                                      self.caches, tok, pos)
            jax.block_until_ready(nxt)
        else:
            for k in self.warmed_window_ks():
                if self.paged:
                    toks, _ = self._decode_window_paged(
                        self.params, self.qparams, self.caches, tok, pos,
                        pt, k)
                else:
                    toks, _ = self._decode_window(self.params, self.qparams,
                                                  self.caches, tok, pos, k)
                jax.block_until_ready(toks)
        if self._chunk:
            from repro.launch.scheduler import chunk_buckets
            row = self._fresh_row()
            for c in chunk_buckets(self._chunk):
                nxt, row = self._prefill_chunk(
                    self.params, self.qparams, row,
                    jnp.zeros((1, c), jnp.int32), jnp.zeros((1,), jnp.int32))
                jax.block_until_ready(nxt)
            if self.draft is not None:
                for n in sorted({req.prompt.size for req in self.queue}):
                    drow = self._prefill_draft(
                        self.draft.params, self.draft.qparams,
                        jnp.zeros((1, int(n)), jnp.int32))
                    jax.block_until_ready(
                        jax.tree_util.tree_leaves(drow)[0])
            return
        # prefill compiles per distinct prompt length; the queued lengths
        # are known, so warm them here instead of inside _admit's timing
        for n in sorted({req.prompt.size for req in self.queue}):
            nxt, _ = self._prefill(self.params, self.qparams,
                                   jnp.zeros((1, int(n)), jnp.int32))
            jax.block_until_ready(nxt)
            if self.draft is not None:
                drow = self._prefill_draft(self.draft.params,
                                           self.draft.qparams,
                                           jnp.zeros((1, int(n)), jnp.int32))
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(drow)[0])

    def compile_cache_sizes(self) -> dict[str, int]:
        """Compiled-entry counts for every engine jit — the warmup
        contract's regression pin: after `warmup()` + `run()`, a chunked
        engine's `_prefill_chunk` count must equal
        `len(chunk_buckets(chunk))` and `_decode`/`_decode_paged` must
        stay at 1 (tests/test_scheduler.py asserts it), so a shape leak
        in the chunk plan can't silently recompile mid-serve."""
        out = {}
        for name in ("_prefill", "_prefill_chunk", "_insert", "_insert_d",
                     "_decode", "_decode_window", "_decode_paged",
                     "_decode_window_paged", "_insert_pages",
                     "_zero_pages", "_copy_page", "_spec", "_spec_paged",
                     "_prefill_draft"):
            fn = getattr(self, name, None)
            if fn is not None and hasattr(fn, "_cache_size"):
                out[name] = int(fn._cache_size())
        return out

    def entry_points(self) -> list[dict]:
        """The static-analysis registry (repro.analysis, DESIGN.md §4.13):
        every jitted dispatch the serve loop can reach for *this* engine's
        configuration, with example arguments at its real shapes and the
        out-sharding contract each must pin. Tracing an entry never runs
        device code (`jax.make_jaxpr` only), and the example rows/arrays
        are never inserted into live state.

        Each entry: name, fn (the jit), args (example tuple),
        static_argnums, expected_out (pytree of NamedShardings for
        arena/row-returning jits under TP — the same `kv_cache_specs` /
        replicated trees the constructor pinned — or None when unsharded
        or the output carries no arena)."""
        tok = jnp.zeros((self.max_slots, 1), jnp.int32)
        pos = jnp.zeros((self.max_slots,), jnp.int32)
        prompt = jnp.zeros((1, min(8, self.max_seq)), jnp.int32)
        rep, arena, row_sh = self._rep, self._arena_sh, self._row_sh
        tp = self.mesh is not None
        eps: list[dict] = []

        def add(name, fn, args, static=(), out=None):
            eps.append(dict(name=name, fn=fn, args=tuple(args),
                            static_argnums=tuple(static),
                            expected_out=out if tp else None))

        add("prefill", self._prefill, (self.params, self.qparams, prompt),
            out=(rep, row_sh))
        row = self._fresh_row()
        if self.paged:
            pt = jnp.asarray(self.page_table)
            npp = paging.pages_for_rows(int(prompt.shape[1]), self.page_size)
            phys = jnp.zeros((npp,), jnp.int32)
            ids = jnp.zeros((4,), jnp.int32)
            add("insert_pages", self._insert_pages,
                (self.caches, row, jnp.int32(0), phys, npp), static=(4,),
                out=arena)
            add("zero_pages", self._zero_pages, (self.caches, ids),
                out=arena)
            add("copy_page", self._copy_page,
                (self.caches, jnp.int32(1), jnp.int32(2)), out=arena)
            if self.draft is None:
                add("decode_paged", self._decode_paged,
                    (self.params, self.qparams, self.caches, tok, pos, pt),
                    out=(rep, arena))
                add("decode_window_paged", self._decode_window_paged,
                    (self.params, self.qparams, self.caches, tok, pos, pt,
                     2), static=(6,), out=(rep, arena))
        else:
            add("insert", self._insert, (self.caches, row, jnp.int32(0)),
                out=arena)
            if self.draft is None:
                add("decode", self._decode,
                    (self.params, self.qparams, self.caches, tok, pos),
                    out=(rep, arena))
                add("decode_window", self._decode_window,
                    (self.params, self.qparams, self.caches, tok, pos, 2),
                    static=(5,), out=(rep, arena))
        if self.draft is not None:
            from repro.launch.speculative import pow2_floor
            k = pow2_floor(self.draft_k)
            add("prefill_draft", self._prefill_draft,
                (self.draft.params, self.draft.qparams, prompt),
                out=self._drow_sh)
            drow = self.draft.lm.init_cache(1, self.max_seq,
                                            dtype=self._cache_dtype)
            if tp:
                drow = jax.device_put(drow, self._drow_sh)
            if self.paged:
                add("spec_paged", self._spec_paged,
                    (self.params, self.qparams, self.draft.params,
                     self.draft.qparams, self.caches, self.dcaches, tok,
                     pos, jnp.asarray(self.page_table), k), static=(9,),
                    out=(rep, rep, arena, self._darena_sh))
                add("insert_pages_d", self._insert_pages_d,
                    (self.dcaches, drow, jnp.int32(0), phys, npp),
                    static=(4,), out=self._darena_sh)
            else:
                add("spec", self._spec,
                    (self.params, self.qparams, self.draft.params,
                     self.draft.qparams, self.caches, self.dcaches, tok,
                     pos, k), static=(8,),
                    out=(rep, rep, arena, self._darena_sh))
                add("insert_d", self._insert_d,
                    (self.dcaches, drow, jnp.int32(0)),
                    out=self._darena_sh)
        if self._chunk:
            add("prefill_chunk", self._prefill_chunk,
                (self.params, self.qparams, self._fresh_row(),
                 jnp.zeros((1, self._chunk), jnp.int32),
                 jnp.zeros((1,), jnp.int32)), out=(rep, row_sh))
        return eps

    def _window(self) -> bool:
        """Admit, then decode up to the next scheduled eviction in one
        fused dispatch. Token-identical to repeated `step()` — the window
        length is the minimum remaining budget over active slots, so no
        admission opportunity is skipped."""
        if self.draft is not None:
            # the fused window scans one committed token per step per
            # slot; a speculative round commits 1..k_eff+1, so every
            # count-based event schedule in here would misfire
            raise RuntimeError(
                "speculative engines decode through step(): _window's "
                "event accounting assumes exactly one token per slot "
                "per step")
        if self._chunk:
            raise RuntimeError(
                "chunked-prefill engines decode through step(): a fused "
                "window cannot interleave prefill chunks — it would "
                "reintroduce the head-of-line block chunking removes")
        self._admit()
        if self.n_active == 0:
            return False
        k = min(req.max_new_tokens - len(req.tokens)
                for req in self.active if req is not None)
        # quantize to powers of two so the set of compiled window lengths
        # is bounded (and warmable) instead of one compile per workload
        k = min(1 << (k.bit_length() - 1), self.MAX_WINDOW)
        tok = jnp.asarray(self.last_tok)[:, None]
        pos = jnp.asarray(self.pos)
        t0 = time.time()
        if self.paged:
            toks, self.caches = self._decode_window_paged(
                self.params, self.qparams, self.caches, tok, pos,
                jnp.asarray(self.page_table), k)
        else:
            toks, self.caches = self._decode_window(
                self.params, self.qparams, self.caches, tok, pos, k)
        toks = np.asarray(jax.block_until_ready(toks))   # (k, slots)
        self.stats["decode_s"] += time.time() - t0
        self.stats["decode_steps"] += k
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.stats["decode_tokens"] += k
            req.tokens.extend(int(t) for t in toks[:, slot])
            self.last_tok[slot] = toks[-1, slot]
            self.pos[slot] += k
            if req.done:
                self._finish(req)
        return True

    def run(self) -> dict[int, np.ndarray]:
        """Drain the queue; returns rid -> generated tokens (prompt not
        included) for every request finished since the last drain, in rid
        order, and releases them — a long-lived engine stays bounded and
        a later drain never re-reports earlier batches. Decodes in
        event-free windows (one dispatch + one host sync per window);
        a speculative engine rounds through `step()` instead — each
        round already fuses k_eff+1 positions into one dispatch — and a
        chunked-prefill engine steps through `step()` so prefill chunks
        interleave with decode."""
        drive = (self.step if (self.draft is not None or self._chunk)
                 else self._window)
        while self.pending:
            if not drive() and (self.queue or self._handoff):
                raise RuntimeError("queue stuck with no active slots")
        if self.paged:
            # drain leaves no dirty quarantine behind: every released
            # page is zeroed and back on the free list
            self._flush_dirty()
        out = {rid: np.asarray(req.tokens, np.int32)
               for rid, req in sorted(self.done.items())}
        self.done.clear()
        return out

    def throughput(self) -> dict[str, float]:
        s = self.stats
        out = {
            "decode_tok_per_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
            "prefill_tok_per_s": (s["prefill_tokens"]
                                  / max(s["prefill_s"], 1e-9)),
            "slot_occupancy": (s["decode_tokens"]
                               / max(s["decode_steps"] * self.max_slots, 1)),
        }
        if self.draft is not None:
            # decode_tokens only ever counts committed tokens, so the
            # headline rate *is* accepted-tokens/s — the alias makes the
            # benchmark metric explicit
            out["accepted_tok_per_s"] = out["decode_tok_per_s"]
            out["acceptance_rate"] = (s["spec_accepted"]
                                      / max(s["spec_drafted"], 1))
        return out

    @staticmethod
    def _leaf_nbytes(leaf, per_device: bool) -> int:
        """Bytes of one array — per addressable shard when `per_device`
        (a TP-sharded leaf stores 1/tp of its rows on each device; a
        replicated leaf stores all of them everywhere)."""
        if per_device:
            shards = getattr(leaf, "addressable_shards", None)
            if shards:
                return int(shards[0].data.nbytes)
        return int(leaf.nbytes)

    def kv_bytes(self, per_device: bool = False) -> int:
        """KV bytes the engine is *using*. A pruned model's arena only
        holds rows for surviving kv heads / mamba channels / rwkv heads
        (LM.init_cache sizes from the SlimPlan shapes), so this shrinks
        with realized sparsity. A speculative engine's draft arena counts
        too — it is pinned HBM the serve needs, and excluding it
        under-reported every `--speculative` kv_bytes stat. Paged engines
        count only *allocated* pages (live + reserved) pro-rated over the
        pooled leaves, plus state leaves and the page table — the headline
        stat the ≥2x-concurrency bench leans on.

        `per_device` reports one device's share under TP: KV-head-sharded
        leaves weigh 1/tp, replicated fallbacks weigh full — the
        ~1/tp-shrink acceptance stat (tests/test_tp_engine.py)."""
        if not self.paged:
            leaves = jax.tree_util.tree_leaves(self.caches)
            if self.dcaches is not None:
                leaves += jax.tree_util.tree_leaves(self.dcaches)
            return sum(self._leaf_nbytes(lf, per_device) for lf in leaves)
        n_alloc = self.alloc.n_live + paging.N_RESERVED
        total = self.page_table.nbytes      # host numpy: replicated
        arenas = [self.caches]
        if self.dcaches is not None:
            arenas.append(self.dcaches)
        for caches in arenas:
            for key, leaf in caches.items():
                if (key.endswith(".k") or key.endswith(".v")
                        or key.endswith("_scale")):
                    total += (self._leaf_nbytes(leaf, per_device)
                              // self.n_pages) * n_alloc
                else:
                    # mamba/rwkv state: slot-sized
                    total += self._leaf_nbytes(leaf, per_device)
        return total

    def kv_pool_bytes(self) -> int:
        """KV bytes the engine *pins* in HBM regardless of load: the full
        pool(s) plus the page table. For a contiguous engine this equals
        kv_bytes(); for a paged engine it is the fixed budget that
        kv_bytes() draws against."""
        total = tree_bytes(self.caches)
        if self.dcaches is not None:
            total += tree_bytes(self.dcaches)
        if self.paged:
            total += self.page_table.nbytes
        return total

    def param_bytes(self, per_device: bool = False) -> int:
        """Bytes of the served param dict (codes + scales + dense rest).

        Counts the containers as served: a `--packed` engine's sub-byte
        word streams weigh their packed bytes, so this tracks
        `mean_bits` instead of flooring at the int8 container.
        `per_device` reports one device's share under TP (sharded leaves
        weigh 1/tp, replicated fallbacks weigh full)."""
        if not per_device:
            return tree_bytes(self.params)
        return sum(self._leaf_nbytes(lf, True)
                   for lf in jax.tree_util.tree_leaves(self.params))


# ----------------------------------------------------------------- drivers
def build_engine(arch: str, smoke: bool = True, *, quantized: bool = True,
                 compressed: bool = False, packed: bool = False,
                 pruned: bool = False, sparsity: float = 0.5,
                 keep_masks: dict | None = None, bits_init: float = 8.0,
                 max_slots: int = 4, max_seq: int = 64, seed: int = 0,
                 verbose: bool = False, speculative: bool = False,
                 draft_k: int = 4, draft_sparsity: float = 0.5,
                 draft_bits: float = 2.0, paged: bool = False,
                 page_size: int = 16, kv_bits: int | None = None,
                 n_pages: int | None = None,
                 prefix_sharing: bool = True, tp: int = 0,
                 prefill_chunk: int | None = None,
                 mesh=None) -> tuple[Engine, LM]:
    """Init an LM at `arch` scale and wrap it in an Engine.

    `pruned` serves the physically sliced subnet: `prepare_serving` builds
    keep masks (`keep_masks` from a GETA run, or magnitude masks at
    `sparsity`), materializes the sliced params, and installs the SlimPlan
    on `lm` — so this engine's decode dispatches, and its KV arena, run at
    the surviving widths. Passing `keep_masks` implies `pruned` (a mask
    dict that silently did nothing — or pruned under a dense label —
    would be worse than either behavior). Composes with `compressed`
    (int codes on pruned shapes) and `packed` (sub-byte word streams —
    implies `compressed`; `bits_init` sets the quantizer init width, so
    `bits_init=4` serves a genuinely 4-bit packed artifact).

    `speculative` attaches a self-speculative draft: the *same* init
    params sliced to `draft_sparsity` and packed at `draft_bits`
    (`launch/speculative.build_draft` — shared checkpoint, shared
    quantizer-init order, so the draft is GETA-calibrated to the target),
    decoding in draft/verify rounds of up to `draft_k` proposals. The
    output stream stays token-identical to the non-speculative engine —
    the `--speculative --smoke` parity check asserts it.

    `tp > 1` serves tensor-parallel over a (1, tp) device mesh
    (`make_tp_mesh`): params and KV arena shard per DESIGN.md §4.12, the
    token stream stays identical to tp=1. `prefill_chunk` swaps in a
    `ChunkedPrefillScheduler` so prefill interleaves with decode in
    `prefill_chunk`-row chunks. The two compose."""
    pruned = pruned or keep_masks is not None
    compressed = compressed or packed
    cfg = get_arch(arch, smoke=smoke)
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(seed))
    draft = None
    if speculative:
        from repro.launch.speculative import build_draft
        # built from the same init params the target serves, *before*
        # prepare_serving resolves the target pair (the draft runs its
        # own prepare_serving on its own LM instance)
        draft = build_draft(arch, smoke, params, sparsity=draft_sparsity,
                            bits=draft_bits, seed=seed)
    params, qparams, meta = prepare_serving(
        lm, params, quantized=quantized, compressed=compressed,
        packed=packed, bits_init=bits_init, keep_masks=keep_masks,
        prune_sparsity=(sparsity if pruned and keep_masks is None else None))
    # an explicit `mesh` overrides tp — the static analyzer passes a
    # 1-device TP mesh so the sharding-pin audit runs on single-device CI
    if mesh is None and tp and tp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(tp)
    scheduler = None
    if prefill_chunk:
        from repro.launch.scheduler import ChunkedPrefillScheduler
        scheduler = ChunkedPrefillScheduler(chunk=int(prefill_chunk))
    eng = Engine(lm, params, qparams, max_slots=max_slots, max_seq=max_seq,
                 draft=draft, draft_k=draft_k, paged=paged,
                 page_size=page_size, kv_bits=kv_bits, n_pages=n_pages,
                 prefix_sharing=prefix_sharing, mesh=mesh, param_axes=axes,
                 scheduler=scheduler)
    meta["kv_bytes"] = eng.kv_bytes()
    if mesh is not None:
        meta["tp"] = {
            "devices": int(mesh.size),
            "param_bytes_per_device": eng.param_bytes(per_device=True),
            "kv_bytes_per_device": eng.kv_bytes(per_device=True),
            "replicated_fallbacks": sorted({n for n, _, _
                                            in eng.tp_fallbacks}),
        }
    if prefill_chunk:
        meta["prefill_chunk"] = int(prefill_chunk)
    if paged:
        meta["paged"] = {
            "page_size": int(eng.page_size),
            "n_pages": int(eng.n_pages),
            "kv_bits": eng.kv_bits,
            "kv_pool_bytes": eng.kv_pool_bytes(),
        }
    meta["decode_attn"] = model_layers.decode_attn_enabled()
    if draft is not None:
        meta["speculative"] = {
            "draft_k": int(draft_k),
            "draft_sparsity": float(draft.meta.get("sparsity", 0.0)),
            "draft_bits": float(draft_bits),
            "draft_param_bytes": tree_bytes(draft.params),
            "draft_kv_bytes": tree_bytes(eng.dcaches),
        }
    eng.serving_meta = meta
    if verbose and (compressed or pruned):
        print(compression_report(arch, meta))
    return eng, lm


def build_masked_reference_engine(arch: str, smoke: bool = True, *,
                                  sparsity: float = 0.5,
                                  quantized: bool = True, max_slots: int = 4,
                                  max_seq: int = 64, seed: int = 0
                                  ) -> tuple[Engine, LM]:
    """The pruned path's correctness oracle: the same model served dense
    and keep-all, with the same magnitude masks *multiplied in* instead of
    sliced away. Shares seed, masks and quantizer init with
    `build_engine(pruned=True)`, so decode must be token-identical — the
    CI smoke and `tests/test_slim_serving.py` assert exactly that."""
    from repro.core.subnet import masked_reference_params
    cfg = get_arch(arch, smoke=smoke)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(seed))
    masked, qparams = masked_reference_params(lm, params, sparsity,
                                              quantized=quantized)
    return Engine(lm, masked, qparams, max_slots=max_slots,
                  max_seq=max_seq), lm


def synthetic_prompts(cfg, prompt_lens: list[int], seed: int = 0
                      ) -> list[np.ndarray]:
    """Deterministic per-request prompts: request i is the first
    prompt_lens[i] tokens of row i of the synthetic LM stream — row j of
    `serve_loop`'s prompt matrix when lengths are equal, which is what the
    engine-vs-static parity test leans on."""
    mx = max(prompt_lens)
    mat = np.asarray(batch_for(cfg, seed, 0, len(prompt_lens), mx)["tokens"])
    return [mat[i, :n].astype(np.int32)
            for i, n in enumerate(prompt_lens)]


def engine_serve(arch: str, smoke: bool, prompt_lens: list[int], gen: int,
                 *, quantized: bool = True, compressed: bool = False,
                 packed: bool = False, pruned: bool = False,
                 sparsity: float = 0.5, bits_init: float = 8.0,
                 max_slots: int = 4, seed: int = 0, verbose: bool = True,
                 decode_attn: bool | None = None,
                 speculative: bool = False, draft_k: int = 4,
                 draft_sparsity: float = 0.5, draft_bits: float = 2.0,
                 paged: bool = False, page_size: int = 16,
                 kv_bits: int | None = None, tp: int = 0,
                 prefill_chunk: int | None = None,
                 stats: dict | None = None) -> dict[int, np.ndarray]:
    """Submit one request per prompt length, run to drain, report tok/s.

    `decode_attn` pins the fused flash-decode attention kernel on (True)
    or off (False) for this serve — build, warmup and drain all run under
    the override; None leaves the process default (on) untouched."""
    max_seq = max(prompt_lens) + gen
    ctx = (model_layers.use_decode_attn(decode_attn)
           if decode_attn is not None else contextlib.nullcontext())
    with ctx:
        eng, lm = build_engine(arch, smoke, quantized=quantized,
                               compressed=compressed, packed=packed,
                               pruned=pruned, sparsity=sparsity,
                               bits_init=bits_init, max_slots=max_slots,
                               max_seq=max_seq, seed=seed, verbose=verbose,
                               speculative=speculative, draft_k=draft_k,
                               draft_sparsity=draft_sparsity,
                               draft_bits=draft_bits, paged=paged,
                               page_size=page_size, kv_bits=kv_bits,
                               tp=tp, prefill_chunk=prefill_chunk)
        for p in synthetic_prompts(lm.cfg, prompt_lens, seed):
            eng.submit(p, gen)
        eng.warmup()
        out = eng.run()
    if stats is not None:
        stats.update(eng.stats, **eng.throughput(),
                     param_bytes=eng.param_bytes(), kv_bytes=eng.kv_bytes(),
                     kv_pool_bytes=eng.kv_pool_bytes())
    if verbose:
        th = eng.throughput()
        mode = "compressed" if (compressed or packed) else "dense"
        if packed:
            mode += "+packed"
        if pruned:
            mode += f"+pruned@{eng.serving_meta.get('sparsity', 0.0):.2f}"
        if speculative:
            sm = eng.serving_meta.get("speculative", {})
            mode += (f"+spec(k={sm.get('draft_k', draft_k)}, draft "
                     f"s{100 * sm.get('draft_sparsity', 0.0):.0f}/"
                     f"b{sm.get('draft_bits', draft_bits):.0f})")
        if paged:
            mode += "+paged"
            if kv_bits is not None:
                mode += f"@kv{kv_bits}"
        if tp and tp > 1:
            mode += f"+tp{tp}"
        if prefill_chunk:
            mode += f"+chunked@{prefill_chunk}"
        line = (f"{arch} [engine/{mode}]: {len(prompt_lens)} requests "
                f"({', '.join(str(n) for n in prompt_lens)} prompt tokens, "
                f"{gen} new each) on {max_slots} slots — "
                f"{eng.stats['decode_tokens']} decode tokens in "
                f"{eng.stats['decode_s']:.2f}s "
                f"({th['decode_tok_per_s']:.1f} tok/s, occupancy "
                f"{th['slot_occupancy']:.2f}); one-shot prefill "
                f"{th['prefill_tok_per_s']:.1f} tok/s")
        if speculative:
            line += (f"; acceptance {th['acceptance_rate']:.2f} over "
                     f"{eng.stats['spec_steps']} rounds")
        print(line)
    return out

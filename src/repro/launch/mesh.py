"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches JAX
device state, so tests/benches keep their 1-CPU view and only dryrun.py
(which sets XLA_FLAGS first) sees 512 host devices.

`make_mesh` is the version-compatible entry point: newer JAX grows an
`axis_types=` kwarg (explicit-sharding work) whose Auto value matches the
older default — pass it when supported, omit it when not.
"""
from __future__ import annotations

import jax
import numpy as np


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types across JAX versions."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def abstract_mesh(shape, axes):
    """jax.sharding.AbstractMesh across the signature change: newer JAX
    takes (axis_sizes, axis_names); older takes ((name, size), ...) pairs."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist (CPU smoke/tests): a 1D data mesh."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def make_subset_mesh(n: int, axes=("data", "model")):
    """A (n, 1) mesh over the FIRST n local devices.

    `jax.make_mesh` insists the axis product covers every device; the
    sharded-parity tests and scaling benches need a 1-device reference mesh
    and an n-device mesh side by side in one multi-device process, so this
    builds the Mesh directly from a device subset."""
    devs = jax.devices()
    if n > len(devs):
        raise ValueError(f"requested {n} devices, host has {len(devs)}")
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape((n, 1)), axes)


def make_tp_mesh(n: int, axes=("data", "model")):
    """A (1, n) mesh over the FIRST n local devices — `model` carries n.

    The serving-engine complement of `make_subset_mesh` (which is
    data-major for DP/FSDP training): the TP engine shards attention
    heads / MLP hidden / the KV arena over `model`, and decode batches are
    tiny, so the whole device budget goes to tensor parallelism. Built
    directly from a device subset for the same reason as above — parity
    tests hold a 1-device reference engine and an n-device engine in one
    process."""
    devs = jax.devices()
    if n > len(devs):
        raise ValueError(f"requested {n} devices, host has {len(devs)}")
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape((1, n)), axes)

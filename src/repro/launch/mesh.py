"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches JAX
device state, so tests/benches keep their 1-CPU view and only dryrun.py
(which sets XLA_FLAGS first) sees 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist (CPU smoke/tests): a 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

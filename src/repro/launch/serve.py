"""Serving driver: batched KV-cache decode of a (compressed) LM.

Reduced-scale smoke (runs here):
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.synthetic import batch_for
from repro.models.transformer import LM


def make_serve_step(lm: LM):
    def serve_step(params, qparams, caches, token, pos):
        logits, caches = lm.decode_step(params, qparams, caches, token, pos)
        if lm.cfg.num_codebooks:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            nxt = nxt[:, None, :]    # (B, 1, C)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            nxt = nxt[:, None]
        return nxt, caches

    return serve_step


def serve_loop(arch: str, smoke: bool, batch: int, prompt_len: int,
               gen: int, seed: int = 0, quantized: bool = True,
               verbose: bool = True):
    cfg = get_arch(arch, smoke=smoke)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(seed))
    qparams = lm.init_qparams(params, bits_init=8.0) if quantized else None
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    caches = lm.init_cache(batch, prompt_len + gen, dtype=dt)
    step = jax.jit(make_serve_step(lm))

    prompt = batch_for(cfg, seed, 0, batch, prompt_len)["tokens"]
    if cfg.family == "vlm":
        prompt = prompt[:, :prompt_len]

    # prefill via sequential decode (cache-building path)
    tok = prompt[:, :1]
    for p in range(prompt_len):
        tok = prompt[:, p:p + 1]
        nxt, caches = step(params, qparams, caches, tok, jnp.int32(p))
    out = [nxt]
    t0 = time.time()
    for g in range(gen - 1):
        nxt, caches = step(params, qparams, caches, out[-1],
                           jnp.int32(prompt_len + g))
        out.append(nxt)
    jax.block_until_ready(out[-1])
    dt_s = time.time() - t0
    toks = batch * (gen - 1)
    if verbose:
        print(f"{arch}: generated {toks} tokens in {dt_s:.2f}s "
              f"({toks/max(dt_s,1e-9):.1f} tok/s, batch={batch})")
    seq = jnp.concatenate(out, axis=1)
    return seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--no-quant", dest="quantized", action="store_false",
                    default=True)
    args = ap.parse_args()
    serve_loop(args.arch, args.smoke, args.batch, args.prompt_len, args.gen,
               quantized=args.quantized)


if __name__ == "__main__":
    main()

"""Serving driver: batched KV-cache decode of a (compressed) LM.

Two weight paths:
  default       — dense params; weight-quant sites applied as fake-quant
                  (QAT numerics, f32/bf16 weights in HBM).
  --compressed  — the deployment path: projection weights are replaced by a
                  `Subnet`'s integer codes + scales (`core.subnet`), and
                  every routed matmul decodes them through the quant-dequant
                  epilogue on the shared GEMM core (int8 streams HBM->VMEM,
                  `codes * scale` inside VMEM). This is the paper's BOPs
                  claim actually executed, not just counted.

Reduced-scale smoke (runs here):
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --batch 4 --prompt-len 16 --gen 32 [--compressed]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.subnet import compress_lm, residual_qparams, servable_params
from repro.data.synthetic import batch_for
from repro.models.transformer import LM


def make_serve_step(lm: LM):
    def serve_step(params, qparams, caches, token, pos):
        logits, caches = lm.decode_step(params, qparams, caches, token, pos)
        if lm.cfg.num_codebooks:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            nxt = nxt[:, None, :]    # (B, 1, C)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            nxt = nxt[:, None]
        return nxt, caches

    return serve_step


def serve_loop(arch: str, smoke: bool, batch: int, prompt_len: int,
               gen: int, seed: int = 0, quantized: bool = True,
               compressed: bool = False, verbose: bool = True,
               stats: dict | None = None):
    """Decode `gen` tokens after a sequential prefill; returns the token
    matrix. If `stats` is given it receives decode-only timing (the
    prefill warms the jit, so compile/init never pollute it)."""
    cfg = get_arch(arch, smoke=smoke)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(seed))
    qparams = lm.init_qparams(params, bits_init=8.0) \
        if (quantized or compressed) else None
    if compressed:
        subnet = compress_lm(lm, params, qparams)
        if verbose:
            m = subnet.meta
            print(f"{arch}: compressed {m['n_sites']} sites to "
                  f"{m['mean_bits']:.1f} mean bits "
                  f"({m['weight_bytes_dense']/2**20:.1f} MiB -> "
                  f"{m['weight_bytes_compressed']/2**20:.1f} MiB)")
        params = servable_params(subnet)
        # routed weights are integer codes now; non-routed sites (head, MoE
        # einsums) keep their fake-quant so numerics match the dense QAT
        # path. --compressed implies quantization: a half-quantized model
        # (codes + unquantized head) would match neither baseline.
        qparams = residual_qparams(subnet, qparams)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    caches = lm.init_cache(batch, prompt_len + gen, dtype=dt)
    step = jax.jit(make_serve_step(lm))

    prompt = batch_for(cfg, seed, 0, batch, prompt_len)["tokens"]
    if cfg.family == "vlm":
        prompt = prompt[:, :prompt_len]

    # prefill via sequential decode (cache-building path)
    tok = prompt[:, :1]
    for p in range(prompt_len):
        tok = prompt[:, p:p + 1]
        nxt, caches = step(params, qparams, caches, tok, jnp.int32(p))
    out = [nxt]
    t0 = time.time()
    for g in range(gen - 1):
        nxt, caches = step(params, qparams, caches, out[-1],
                           jnp.int32(prompt_len + g))
        out.append(nxt)
    jax.block_until_ready(out[-1])
    dt_s = time.time() - t0
    toks = batch * (gen - 1)
    if stats is not None:
        stats.update(decode_s=dt_s, tokens=toks,
                     tok_per_s=toks / max(dt_s, 1e-9))
    if verbose:
        mode = "compressed" if compressed else "dense"
        print(f"{arch} [{mode}]: generated {toks} tokens in {dt_s:.2f}s "
              f"({toks/max(dt_s,1e-9):.1f} tok/s, batch={batch})")
    seq = jnp.concatenate(out, axis=1)
    return seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--no-quant", dest="quantized", action="store_false",
                    default=True)
    ap.add_argument("--compressed", action="store_true", default=False,
                    help="decode from Subnet int codes via the quant-dequant "
                         "GEMM epilogue instead of dense params (implies "
                         "quantization; overrides --no-quant)")
    args = ap.parse_args()
    serve_loop(args.arch, args.smoke, args.batch, args.prompt_len, args.gen,
               quantized=args.quantized, compressed=args.compressed)


if __name__ == "__main__":
    main()

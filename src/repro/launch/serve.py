"""Serving CLI: continuous-batching engine by default, static loop kept as
the lockstep reference path.

Two weight paths (both modes):
  default       — dense params; weight-quant sites applied as fake-quant
                  (QAT numerics, f32/bf16 weights in HBM).
  --compressed  — the deployment path: projection weights are replaced by a
                  `Subnet`'s integer codes + scales (`core.subnet`), and
                  every routed matmul decodes them through the quant-dequant
                  epilogue on the shared GEMM core (int8 streams HBM->VMEM,
                  `codes * scale` inside VMEM). This is the paper's BOPs
                  claim actually executed, not just counted.
  --packed      — sub-byte storage on top of --compressed (implied): codes
                  bit-pack along K into int32 word streams at each site's
                  learned storage width (2/3/4/8), decoded in VMEM by the
                  unpack-dequant epilogue — a 4-bit site moves half the
                  HBM bytes of its int8 container (DESIGN.md §4.8).

Two execution modes:
  engine (default) — `launch.engine.Engine`: request queue with
                  admission/eviction, slot-based KV arena, per-slot decode
                  positions, one-shot parallel prefill. `--prompt-lens`
                  takes per-request prompt lengths (mixed lengths are the
                  point).
  --static      — the legacy `serve_loop`: one fixed batch in lockstep with
                  a sequential per-token prefill. Kept as the engine's
                  parity oracle (tests/test_engine.py) and the benchmark
                  baseline.

Reduced-scale smoke (runs here):
  PYTHONPATH=src python -m repro.launch.serve --smoke --compressed \
      --prompt-lens 12,5 --gen 8
  PYTHONPATH=src python -m repro.launch.serve --smoke --static \
      --batch 4 --prompt-len 16 --gen 32 [--compressed]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.subnet import compression_report, prepare_serving
from repro.data.synthetic import batch_for
from repro.models.transformer import LM


def make_serve_step(lm: LM):
    def serve_step(params, qparams, caches, token, pos):
        logits, caches = lm.decode_step(params, qparams, caches, token, pos)
        if lm.cfg.num_codebooks:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            nxt = nxt[:, None, :]    # (B, 1, C)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            nxt = nxt[:, None]
        return nxt, caches

    return serve_step


def serve_loop(arch: str, smoke: bool, batch: int, prompt_len: int,
               gen: int, seed: int = 0, quantized: bool = True,
               compressed: bool = False, packed: bool = False,
               pruned: bool = False, sparsity: float = 0.5,
               bits_init: float = 8.0, verbose: bool = True,
               stats: dict | None = None, prompts=None):
    """Static lockstep reference: decode `gen` tokens after a *sequential*
    per-token prefill; returns the (batch, gen) token matrix. If `stats`
    is given it receives decode-only timing (the prefill warms the jit, so
    compile/init never pollute it). `prompts` overrides the synthetic
    (batch, prompt_len) prompt matrix — `tests/test_engine.py` feeds the
    identical requests through this loop and the engine with it. `pruned`
    decodes the physically sliced subnet at magnitude masks of `sparsity`
    (the shrunk KV arena included)."""
    cfg = get_arch(arch, smoke=smoke)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(seed))
    params, qparams, meta = prepare_serving(
        lm, params, quantized=quantized, compressed=compressed,
        packed=packed, bits_init=bits_init,
        prune_sparsity=(sparsity if pruned else None))
    if (compressed or packed or pruned) and verbose:
        print(compression_report(arch, meta))
    if prompts is None:
        prompts = batch_for(cfg, seed, 0, batch, prompt_len)["tokens"]
        if cfg.family == "vlm":
            prompts = prompts[:, :prompt_len]
    prompt = jnp.asarray(prompts)
    prompt_len = prompt.shape[1]   # an explicit matrix sets the length

    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    caches = lm.init_cache(batch, prompt_len + gen, dtype=dt)
    step = jax.jit(make_serve_step(lm))

    # prefill via sequential decode (cache-building path)
    tok = prompt[:, :1]
    for p in range(prompt_len):
        tok = prompt[:, p:p + 1]
        nxt, caches = step(params, qparams, caches, tok, jnp.int32(p))
    out = [nxt]
    t0 = time.time()
    for g in range(gen - 1):
        nxt, caches = step(params, qparams, caches, out[-1],
                           jnp.int32(prompt_len + g))
        out.append(nxt)
    jax.block_until_ready(out[-1])
    dt_s = time.time() - t0
    toks = batch * (gen - 1)
    if stats is not None:
        stats.update(decode_s=dt_s, tokens=toks,
                     tok_per_s=toks / max(dt_s, 1e-9))
    if verbose:
        mode = "compressed" if (compressed or packed) else "dense"
        if packed:
            mode += "+packed"
        print(f"{arch} [static/{mode}]: generated {toks} tokens in "
              f"{dt_s:.2f}s ({toks/max(dt_s,1e-9):.1f} tok/s, "
              f"batch={batch})")
    seq = jnp.concatenate(out, axis=1)
    return seq


def pruned_parity_check(arch: str, smoke: bool, prompt_lens: list[int],
                        gen: int, *, sparsity: float, quantized: bool,
                        compressed: bool = False, max_slots: int,
                        seed: int = 0, verbose: bool = True) -> dict:
    """Assert the pruned engine's decode is token-identical to the masked
    dense reference (same seed, masks and quantizer init; zeroed units
    contribute exact zeros, so slicing them away must not change a single
    greedy token). Raises AssertionError on divergence — this is the CI
    smoke for `serve --pruned --smoke`. Returns the pruned engine's
    output, so the caller reports throughput without decoding a second
    engine."""
    import numpy as np

    from repro.launch.engine import (build_masked_reference_engine,
                                     engine_serve, synthetic_prompts)
    max_seq = max(prompt_lens) + gen
    # `compressed` implies quantization on the pruned arm (prepare_serving
    # resolves qparams either way), so the reference must quantize too or
    # the two arms would run different numerics under --no-quant
    ref, lm = build_masked_reference_engine(
        arch, smoke, sparsity=sparsity,
        quantized=(quantized or compressed),
        max_slots=max_slots, max_seq=max_seq, seed=seed)
    for p in synthetic_prompts(lm.cfg, prompt_lens, seed):
        ref.submit(p, gen)
    want = ref.run()
    got = engine_serve(arch, smoke, prompt_lens, gen, quantized=quantized,
                       compressed=compressed, pruned=True, sparsity=sparsity,
                       max_slots=max_slots, seed=seed, verbose=verbose)
    assert sorted(got) == sorted(want), (sorted(got), sorted(want))
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"pruned decode diverged from masked reference "
                    f"(request {rid})")
    print(f"{arch}: pruned decode (sparsity {sparsity:.2f}) token-identical "
          f"to the masked dense reference over {len(want)} requests")
    return got


def packed_parity_check(arch: str, smoke: bool, prompt_lens: list[int],
                        gen: int, *, pruned: bool = False,
                        sparsity: float = 0.5, bits_init: float = 8.0,
                        max_slots: int, seed: int = 0,
                        verbose: bool = True) -> dict:
    """Assert the packed engine's decode is token-identical to the
    unpacked int8 path. `unpack_codes(pack_codes(c, b), b)` is exact and
    both arms share seed, scales and clamped codes, so the dequantized
    weights — and every greedy token — must match bit-for-bit; a packing
    or sign-extension regression shows up as divergence here. Stacks with
    `pruned` (both arms then serve the same sliced shapes). Raises
    AssertionError on divergence — the CI smoke for `serve --packed
    --smoke`. Returns the packed engine's output (the serving run that
    printed the throughput report)."""
    import numpy as np

    from repro.launch.engine import engine_serve
    want = engine_serve(arch, smoke, prompt_lens, gen, compressed=True,
                        packed=False, pruned=pruned, sparsity=sparsity,
                        bits_init=bits_init, max_slots=max_slots, seed=seed,
                        verbose=False)
    got = engine_serve(arch, smoke, prompt_lens, gen, compressed=True,
                       packed=True, pruned=pruned, sparsity=sparsity,
                       bits_init=bits_init, max_slots=max_slots, seed=seed,
                       verbose=verbose)
    assert sorted(got) == sorted(want), (sorted(got), sorted(want))
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"packed decode diverged from the unpacked int8 "
                    f"reference (request {rid})")
    print(f"{arch}: packed decode token-identical to the unpacked int8 "
          f"path over {len(want)} requests"
          + (f" (pruned @ {sparsity:.2f})" if pruned else ""))
    return got


def decode_attn_parity_check(arch: str, smoke: bool, prompt_lens: list[int],
                             gen: int, *, compressed: bool = False,
                             packed: bool = False, pruned: bool = False,
                             sparsity: float = 0.5, bits_init: float = 8.0,
                             max_slots: int, seed: int = 0,
                             verbose: bool = True) -> dict:
    """Assert engine decode with the fused flash-decode attention kernel
    is token-identical to the legacy full-length einsum path, on the same
    weights/prompts/seed. Both arms share every GEMM; only the decode
    attention composition differs, and the kernel's xla-ref backend runs
    the einsum math bit-for-bit (ref.decode_attn_ref) while the Pallas
    backends agree to the parity tier's 1e-4 — so greedy tokens must
    match exactly on any host. Stacks with --pruned / --packed (the
    kernel is parameterized by LayerShapes, so sliced head counts flow
    through). Raises AssertionError on divergence — the CI smoke for
    `serve --smoke --decode-attn-parity`. Returns the kernel arm's
    output (the run that printed the throughput report)."""
    import numpy as np

    from repro.launch.engine import engine_serve
    want = engine_serve(arch, smoke, prompt_lens, gen,
                        compressed=compressed, packed=packed, pruned=pruned,
                        sparsity=sparsity, bits_init=bits_init,
                        max_slots=max_slots, seed=seed, verbose=False,
                        decode_attn=False)
    got = engine_serve(arch, smoke, prompt_lens, gen,
                       compressed=compressed, packed=packed, pruned=pruned,
                       sparsity=sparsity, bits_init=bits_init,
                       max_slots=max_slots, seed=seed, verbose=verbose,
                       decode_attn=True)
    assert sorted(got) == sorted(want), (sorted(got), sorted(want))
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"flash-decode attention diverged from the einsum "
                    f"reference path (request {rid})")
    mode = ("packed" if packed else
            "compressed" if compressed else "dense")
    if pruned:
        mode += f"+pruned@{sparsity:.2f}"
    print(f"{arch}: flash-decode attention token-identical to the einsum "
          f"reference over {len(want)} requests ({mode})")
    return got


def speculative_parity_check(arch: str, smoke: bool,
                             prompt_lens: list[int], gen: int, *,
                             quantized: bool = True,
                             compressed: bool = False, packed: bool = False,
                             pruned: bool = False, sparsity: float = 0.5,
                             bits_init: float = 8.0, draft_k: int = 4,
                             draft_sparsity: float = 0.5,
                             draft_bits: float = 2.0, max_slots: int,
                             seed: int = 0, verbose: bool = True) -> dict:
    """Assert the speculative engine's decode is token-identical to the
    non-speculative engine on the same target weights/prompts/seed.

    The draft/verify loop commits only the *target's* argmaxes (the
    accepted prefix plus the verify pass's free token), so identity is
    the protocol's structural guarantee — any divergence means the
    rollback or position bookkeeping corrupted the target arena, which
    is exactly what this smoke exists to catch. The draft config is
    deliberately aggressive (s50 + b2 by default): a near-zero-acceptance
    draft maximizes rollback traffic. Raises AssertionError on
    divergence — the CI smoke for `serve --speculative --smoke`. Returns
    the speculative arm's output (the run that printed the report)."""
    import numpy as np

    from repro.launch.engine import engine_serve
    want = engine_serve(arch, smoke, prompt_lens, gen, quantized=quantized,
                        compressed=compressed, packed=packed, pruned=pruned,
                        sparsity=sparsity, bits_init=bits_init,
                        max_slots=max_slots, seed=seed, verbose=False)
    got = engine_serve(arch, smoke, prompt_lens, gen, quantized=quantized,
                       compressed=compressed, packed=packed, pruned=pruned,
                       sparsity=sparsity, bits_init=bits_init,
                       max_slots=max_slots, seed=seed, verbose=verbose,
                       speculative=True, draft_k=draft_k,
                       draft_sparsity=draft_sparsity, draft_bits=draft_bits)
    assert sorted(got) == sorted(want), (sorted(got), sorted(want))
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"speculative decode diverged from the "
                    f"non-speculative engine (request {rid})")
    print(f"{arch}: speculative decode (draft k={draft_k}, "
          f"s{100 * draft_sparsity:.0f}/b{draft_bits:.0f}) token-identical "
          f"to the non-speculative engine over {len(want)} requests")
    return got


def paged_parity_check(arch: str, smoke: bool, prompt_lens: list[int],
                       gen: int, *, quantized: bool = True,
                       compressed: bool = False, packed: bool = False,
                       pruned: bool = False, sparsity: float = 0.5,
                       bits_init: float = 8.0, speculative: bool = False,
                       draft_k: int = 4, draft_sparsity: float = 0.5,
                       draft_bits: float = 2.0, page_size: int = 16,
                       max_slots: int, seed: int = 0,
                       verbose: bool = True) -> dict:
    """Assert the paged engine's decode is token-identical to the
    contiguous-arena engine on the same weights/prompts/seed.

    The paged arena changes only *where* KV rows live (page pools behind
    per-slot page tables, prefix-shared pages, zero-page backing) — every
    gathered view is sliced back to the exact max_seq row count the
    contiguous engine reduces over, and prefix sharing only ever reuses
    bitwise-identical whole-prompt pages, so greedy tokens must match
    bit-for-bit. Stacks with --pruned/--packed/--speculative (the page
    pools take the sliced KV shapes; the draft arena pages through the
    same tables). Raises AssertionError on divergence — the CI smoke for
    `serve --paged --smoke`. Returns the paged arm's output (the run
    that printed the throughput report)."""
    import numpy as np

    from repro.launch.engine import engine_serve
    common = dict(quantized=quantized, compressed=compressed, packed=packed,
                  pruned=pruned, sparsity=sparsity, bits_init=bits_init,
                  speculative=speculative, draft_k=draft_k,
                  draft_sparsity=draft_sparsity, draft_bits=draft_bits,
                  max_slots=max_slots, seed=seed)
    want = engine_serve(arch, smoke, prompt_lens, gen, verbose=False,
                        **common)
    got = engine_serve(arch, smoke, prompt_lens, gen, verbose=verbose,
                       paged=True, page_size=page_size, **common)
    assert sorted(got) == sorted(want), (sorted(got), sorted(want))
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"paged decode diverged from the contiguous arena "
                    f"(request {rid})")
    mode = ("packed" if packed else
            "compressed" if compressed else "dense")
    if pruned:
        mode += f"+pruned@{sparsity:.2f}"
    if speculative:
        mode += f"+spec(k={draft_k})"
    print(f"{arch}: paged KV decode (page_size={page_size}) "
          f"token-identical to the contiguous arena over {len(want)} "
          f"requests ({mode})")
    return got


def tp_parity_check(arch: str, smoke: bool, prompt_lens: list[int],
                    gen: int, *, tp: int, quantized: bool = True,
                    compressed: bool = False, packed: bool = False,
                    pruned: bool = False, sparsity: float = 0.5,
                    bits_init: float = 8.0, speculative: bool = False,
                    draft_k: int = 4, draft_sparsity: float = 0.5,
                    draft_bits: float = 2.0, paged: bool = False,
                    page_size: int = 16, prefill_chunk: int | None = None,
                    max_slots: int, seed: int = 0,
                    verbose: bool = True) -> dict:
    """Assert the tensor-parallel engine's decode is token-identical to
    the single-device engine on the same weights/prompts/seed.

    TP sharding is column/head-parallel by construction (DESIGN.md
    §4.12): every output column and KV head lives wholly on one device,
    so no contraction is ever split across devices and no cross-device
    reduction reassociates a sum — greedy argmaxes must match bit for
    bit, across the whole compression stack. Raises AssertionError on
    divergence — the CI smoke for `serve --tp N --smoke`. Returns the TP
    arm's output (the run that printed the throughput report), and
    reports any shapes the mesh couldn't divide (replication
    fallbacks)."""
    import numpy as np

    from repro.launch.engine import engine_serve
    common = dict(quantized=quantized, compressed=compressed, packed=packed,
                  pruned=pruned, sparsity=sparsity, bits_init=bits_init,
                  speculative=speculative, draft_k=draft_k,
                  draft_sparsity=draft_sparsity, draft_bits=draft_bits,
                  paged=paged, page_size=page_size,
                  prefill_chunk=prefill_chunk, max_slots=max_slots,
                  seed=seed)
    want = engine_serve(arch, smoke, prompt_lens, gen, verbose=False,
                        **common)
    st: dict = {}
    got = engine_serve(arch, smoke, prompt_lens, gen, verbose=verbose,
                       tp=tp, stats=st, **common)
    assert sorted(got) == sorted(want), (sorted(got), sorted(want))
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"tp={tp} decode diverged from the single-device "
                    f"engine (request {rid})")
    mode = ("packed" if packed else
            "compressed" if compressed else "dense")
    if pruned:
        mode += f"+pruned@{sparsity:.2f}"
    if paged:
        mode += "+paged"
    if speculative:
        mode += f"+spec(k={draft_k})"
    print(f"{arch}: tp={tp} decode token-identical to the single-device "
          f"engine over {len(want)} requests ({mode})")
    return got


def chunked_prefill_parity_check(arch: str, smoke: bool,
                                 prompt_lens: list[int], gen: int, *,
                                 prefill_chunk: int, quantized: bool = True,
                                 compressed: bool = False,
                                 packed: bool = False, pruned: bool = False,
                                 sparsity: float = 0.5,
                                 bits_init: float = 8.0, tp: int = 0,
                                 max_slots: int, seed: int = 0,
                                 verbose: bool = True) -> dict:
    """Assert the chunked-prefill engine's decode is token-identical to
    the one-shot-prefill engine, and that decode actually ran while a
    prefill was in flight (`decode_steps_mid_prefill > 0` whenever a
    multi-chunk prompt and an active slot coexisted) — the
    disaggregation is only worth its machinery if both hold. Raises
    AssertionError on divergence — the CI smoke for
    `serve --chunked-prefill N --smoke`. Returns the chunked arm's
    output (the run that printed the throughput report)."""
    import numpy as np

    from repro.launch.engine import engine_serve
    common = dict(quantized=quantized, compressed=compressed, packed=packed,
                  pruned=pruned, sparsity=sparsity, bits_init=bits_init,
                  tp=tp, max_slots=max_slots, seed=seed)
    want = engine_serve(arch, smoke, prompt_lens, gen, verbose=False,
                        **common)
    st: dict = {}
    got = engine_serve(arch, smoke, prompt_lens, gen, verbose=verbose,
                       prefill_chunk=prefill_chunk, stats=st, **common)
    assert sorted(got) == sorted(want), (sorted(got), sorted(want))
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"chunked prefill (chunk={prefill_chunk}) diverged "
                    f"from the one-shot engine (request {rid})")
    if len(prompt_lens) > 1 and any(n > prefill_chunk
                                    for n in prompt_lens[1:]):
        # a later prompt needed several chunks while request 0 decoded,
        # so disaggregation must have interleaved at least once
        assert st["decode_steps_mid_prefill"] > 0, st
    print(f"{arch}: chunked prefill (chunk={prefill_chunk}) "
          f"token-identical to the one-shot engine over {len(want)} "
          f"requests; {st['prefill_chunks']} chunks, "
          f"{st['decode_steps_mid_prefill']} decode steps ran mid-prefill")
    return got


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--static", action="store_true", default=False,
                    help="legacy lockstep serve_loop instead of the "
                         "continuous-batching engine")
    ap.add_argument("--batch", type=int, default=4,
                    help="static mode: lockstep batch size")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="static mode: shared prompt length")
    ap.add_argument("--prompt-lens", default=None,
                    help="engine mode: comma-separated per-request prompt "
                         "lengths, e.g. 16,4,9 (default: --batch requests "
                         "of --prompt-len each)")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine mode: decode slots (concurrent requests)")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--no-quant", dest="quantized", action="store_false",
                    default=True)
    ap.add_argument("--compressed", action="store_true", default=False,
                    help="decode from Subnet int codes via the quant-dequant "
                         "GEMM epilogue instead of dense params (implies "
                         "quantization; overrides --no-quant)")
    ap.add_argument("--packed", action="store_true", default=False,
                    help="store the codes as sub-byte packed int32 word "
                         "streams and decode through the unpack-dequant "
                         "epilogue (implies --compressed); in --smoke mode "
                         "also asserts decode tokens are identical to the "
                         "unpacked int8 path")
    ap.add_argument("--bits", type=float, default=8.0,
                    help="quantizer init width: the learned per-site bit "
                         "widths start here, so --packed --bits 4 serves a "
                         "genuinely 4-bit artifact (half the int8 container "
                         "bytes; 2 -> a quarter)")
    ap.add_argument("--pruned", action="store_true", default=False,
                    help="physically slice the model to magnitude masks at "
                         "--sparsity and serve the pruned shapes (smaller "
                         "GEMMs + shrunk KV arena); in --smoke mode also "
                         "asserts decode tokens are identical to the masked "
                         "dense reference")
    ap.add_argument("--sparsity", type=float, default=0.5,
                    help="pruned mode: target fraction of prunable units "
                         "removed (default 0.5)")
    ap.add_argument("--speculative", action="store_true", default=False,
                    help="engine mode: self-speculative decoding — a "
                         "pruned+packed subnet of the same checkpoint "
                         "drafts up to --draft-k tokens per round and the "
                         "target verifies them in one chunked pass; output "
                         "tokens are always the target's (in --smoke mode "
                         "also asserts token identity vs the "
                         "non-speculative engine)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative mode: max draft proposals per round")
    ap.add_argument("--draft-sparsity", type=float, default=50.0,
                    help="speculative mode: draft subnet sparsity — a "
                         "percentage (50) or fraction (0.5); 0 keeps all "
                         "units (packed-only draft)")
    ap.add_argument("--draft-bits", type=float, default=2.0,
                    help="speculative mode: draft quantizer init width "
                         "(packed storage bits)")
    ap.add_argument("--paged", action="store_true", default=False,
                    help="engine mode: paged KV arena — fixed-size KV "
                         "pages in one pool behind per-slot page tables, "
                         "with whole-prompt prefix sharing (repeated "
                         "prompts share refcounted pages and skip their "
                         "prefill); in --smoke mode also asserts decode "
                         "tokens are identical to the contiguous arena "
                         "(DESIGN.md §4.11)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged mode: KV rows per page (multiple of 8)")
    ap.add_argument("--kv-bits", type=int, default=None,
                    choices=[4, 8],
                    help="paged mode: quantize the page store to int8 or "
                         "nibble-packed int4 codes + per-row scales, "
                         "decoded in-VMEM by the flash-decode kernel "
                         "(approximate numerics: skips the --smoke "
                         "token-identity check)")
    ap.add_argument("--tp", type=int, default=0,
                    help="engine mode: tensor-parallel serving over a "
                         "(1, N) device mesh — params shard by attention "
                         "head / MLP hidden / vocab, the KV arena by KV "
                         "head (DESIGN.md §4.12); in --smoke mode also "
                         "asserts decode tokens are identical to the "
                         "single-device engine")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N XLA host-platform devices (CPU only; "
                         "sets --xla_force_host_platform_device_count "
                         "before the backend initializes) so --tp runs "
                         "on a single-CPU host")
    ap.add_argument("--chunked-prefill", type=int, default=None,
                    metavar="CHUNK",
                    help="engine mode: split each prompt's prefill into "
                         "CHUNK-row chunks interleaved with decode steps "
                         "(disaggregated prefill/decode — long prompts "
                         "stop head-of-line-blocking active slots); in "
                         "--smoke mode also asserts decode tokens are "
                         "identical to the one-shot engine")
    ap.add_argument("--no-decode-attn", dest="decode_attn",
                    action="store_false", default=True,
                    help="disable the fused flash-decode attention kernel "
                         "and decode through the legacy full-length "
                         "einsum+softmax path (DESIGN.md §4.9)")
    ap.add_argument("--decode-attn-parity", action="store_true",
                    default=False,
                    help="engine mode: serve twice — flash-decode kernel "
                         "forced on and forced off — and assert the greedy "
                         "tokens are identical (the decode-attn CI smoke; "
                         "honors --compressed/--packed/--pruned)")
    args = ap.parse_args()
    if args.devices and args.devices > 1:
        # must land before the first backend touch; harmless if XLA is
        # already up with enough devices, fatal (jax raises in
        # make_tp_mesh) if it's up with fewer
        import os
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count="
                        f"{args.devices}").strip()
    if not args.decode_attn:
        from repro.models.layers import set_decode_attn
        set_decode_attn(False)
    cfg = get_arch(args.arch, smoke=args.smoke)
    if not args.static and (cfg.num_codebooks or cfg.vision_patches):
        # the engine serves plain token LMs; these archs keep working
        # through the lockstep loop exactly as before this CLI existed
        print(f"{args.arch}: codebook/VLM prompts need a modality "
              f"frontend — falling back to the static loop")
        args.static = True
    if args.static:
        serve_loop(args.arch, args.smoke, args.batch, args.prompt_len,
                   args.gen, quantized=args.quantized,
                   compressed=args.compressed, packed=args.packed,
                   pruned=args.pruned, sparsity=args.sparsity,
                   bits_init=args.bits)
        return
    from repro.launch.engine import engine_serve
    if args.prompt_lens:
        lens = [int(x) for x in args.prompt_lens.split(",")]
    else:
        lens = [args.prompt_len] * args.batch
    # --kv-bits quantizes the *paged* page store; asking for it implies
    # the paged arena rather than erroring on a flag the user clearly
    # wanted to take effect
    if args.kv_bits is not None:
        args.paged = True
    # `--draft-sparsity 50` and `--draft-sparsity 0.5` mean the same thing
    draft_sparsity = (args.draft_sparsity / 100.0
                      if args.draft_sparsity > 1.0 else args.draft_sparsity)
    if args.tp and args.tp > 1 and args.smoke:
        # CI smoke contract: N-device decode == 1-device decode, token
        # for token, across whatever compression/paged/speculative stack
        # is active — the `serve --tp --smoke` parity step.
        tp_parity_check(args.arch, args.smoke, lens, args.gen,
                        tp=args.tp, quantized=args.quantized,
                        compressed=args.compressed, packed=args.packed,
                        pruned=args.pruned, sparsity=args.sparsity,
                        bits_init=args.bits, speculative=args.speculative,
                        draft_k=args.draft_k, draft_sparsity=draft_sparsity,
                        draft_bits=args.draft_bits, paged=args.paged,
                        page_size=args.page_size,
                        prefill_chunk=args.chunked_prefill,
                        max_slots=args.slots)
        return
    if args.chunked_prefill and args.smoke:
        # CI smoke contract: chunked prefill == one-shot prefill, token
        # for token, AND decode steps demonstrably ran mid-prefill.
        chunked_prefill_parity_check(
            args.arch, args.smoke, lens, args.gen,
            prefill_chunk=args.chunked_prefill, quantized=args.quantized,
            compressed=args.compressed, packed=args.packed,
            pruned=args.pruned, sparsity=args.sparsity,
            bits_init=args.bits, tp=args.tp, max_slots=args.slots)
        return
    if args.paged and args.smoke and args.kv_bits is None:
        # CI smoke contract: paged decode == contiguous decode, token for
        # token, across whatever compression/speculative stack is active.
        # Quantized pages (--kv-bits) are deliberately lossy, so they
        # serve without the identity assertion.
        paged_parity_check(args.arch, args.smoke, lens, args.gen,
                           quantized=args.quantized,
                           compressed=args.compressed, packed=args.packed,
                           pruned=args.pruned, sparsity=args.sparsity,
                           bits_init=args.bits,
                           speculative=args.speculative,
                           draft_k=args.draft_k,
                           draft_sparsity=draft_sparsity,
                           draft_bits=args.draft_bits,
                           page_size=args.page_size, max_slots=args.slots)
        return
    if args.speculative and args.smoke:
        # CI smoke contract: speculative decode == non-speculative decode,
        # token for token (the draft only sets speed). The speculative arm
        # *is* the serving run, so nothing decodes twice.
        speculative_parity_check(args.arch, args.smoke, lens, args.gen,
                                 quantized=args.quantized,
                                 compressed=args.compressed,
                                 packed=args.packed, pruned=args.pruned,
                                 sparsity=args.sparsity,
                                 bits_init=args.bits, draft_k=args.draft_k,
                                 draft_sparsity=draft_sparsity,
                                 draft_bits=args.draft_bits,
                                 max_slots=args.slots)
        return
    if args.decode_attn_parity:
        # CI smoke contract: flash-decode kernel == einsum reference,
        # token for token. The kernel arm *is* the serving run (it prints
        # the throughput report), so nothing decodes a third time.
        decode_attn_parity_check(args.arch, args.smoke, lens, args.gen,
                                 compressed=args.compressed,
                                 packed=args.packed, pruned=args.pruned,
                                 sparsity=args.sparsity,
                                 bits_init=args.bits,
                                 max_slots=args.slots)
        return
    if args.packed and args.smoke:
        # CI smoke contract: packed decode == unpacked int8 decode, token
        # for token (stacks with --pruned: both arms slice first). The
        # packed arm *is* the serving run, so nothing decodes twice.
        packed_parity_check(args.arch, args.smoke, lens, args.gen,
                            pruned=args.pruned, sparsity=args.sparsity,
                            bits_init=args.bits, max_slots=args.slots)
        return
    if args.pruned and args.smoke:
        # CI smoke contract: pruned decode == masked dense reference,
        # token for token. The check's pruned arm *is* the serving run
        # (it prints the throughput report), so nothing decodes twice.
        pruned_parity_check(args.arch, args.smoke, lens, args.gen,
                            sparsity=args.sparsity,
                            quantized=args.quantized,
                            compressed=args.compressed,
                            max_slots=args.slots)
        return
    engine_serve(args.arch, args.smoke, lens, args.gen,
                 quantized=args.quantized, compressed=args.compressed,
                 packed=args.packed, pruned=args.pruned,
                 sparsity=args.sparsity, bits_init=args.bits,
                 max_slots=args.slots, speculative=args.speculative,
                 draft_k=args.draft_k, draft_sparsity=draft_sparsity,
                 draft_bits=args.draft_bits, paged=args.paged,
                 page_size=args.page_size, kv_bits=args.kv_bits,
                 tp=args.tp, prefill_chunk=args.chunked_prefill)


if __name__ == "__main__":
    main()

"""Config schema: model architecture, input shapes, run/compression options."""
from __future__ import annotations

import dataclasses
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    every: int = 1              # MoE FFN every N layers (others dense MLP)
    capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4-style always-on shared expert
    impl: str = "einsum"        # "einsum" (baseline) | "alltoall" (shard_map)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> d_model // 16
    chunk: int = 64             # chunked selective-scan block


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64        # rank of the data-dependent decay LoRA
    chunk: int = 64             # chunked wkv block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm_rwkv | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    qkv_bias: bool = False
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    attn_every: int = 1         # hybrid: 1 attention layer per `attn_every`
    window: int = 0             # sliding-window attention (0 = full causal)
    num_codebooks: int = 0      # audio: EnCodec codebooks
    vision_patches: int = 0     # vlm: stub patch-embedding count
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # attention implementation: "auto" picks blockwise beyond this seq len
    attn_block_threshold: int = 2048
    attn_block_size: int = 512
    remat: bool = True

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def gqa_group(self) -> int:
        return max(self.n_heads // max(self.n_kv_heads, 1), 1)

    @property
    def sub_quadratic(self) -> bool:
        """True when 500k-token decode is feasible (SSM/hybrid/windowed)."""
        return self.family in ("hybrid", "ssm_rwkv") or self.window > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_padded
        emb = V * D * (1 if self.tie_embeddings else 2)
        attn = self.q_dim * D * 2 + self.kv_dim * D * 2
        mlp = 3 * D * F
        n = emb
        for i in range(L):
            is_attn = (i % self.attn_every == 0) if self.family == "hybrid" \
                else (self.family != "ssm_rwkv")
            if is_attn and self.n_heads:
                n += attn
            if self.family == "hybrid" and not is_attn and self.mamba:
                di = self.mamba.expand * D
                dtr = self.mamba.dt_rank or D // 16
                n += D * 2 * di + di * (dtr + 2 * self.mamba.d_state) \
                    + dtr * di + di * self.mamba.d_state + di * D \
                    + self.mamba.d_conv * di
            if self.family == "ssm_rwkv":
                n += 6 * D * D + 3 * D * F // 2  # time-mix + channel-mix
            if self.moe and (i % self.moe.every == self.moe.every - 1):
                n += self.moe.n_experts * 3 * D * F + D * self.moe.n_experts
                if self.moe.shared_expert:
                    n += 3 * D * F
            elif self.family not in ("ssm_rwkv",):
                n += mlp
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        n_moe_layers = self.n_layers // self.moe.every
        full_expert = self.moe.n_experts * 3 * D * F * n_moe_layers
        active_expert = self.moe.top_k * 3 * D * F * n_moe_layers
        return self.param_count() - full_expert + active_expert


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """GETA knobs surfaced per run (white-box control — Eq 7b/7c)."""
    enabled: bool = True
    target_sparsity: float = 0.3
    bit_lower: float = 4.0
    bit_upper: float = 16.0
    act_quant: bool = False
    warmup_steps: int = 50
    projection_periods: int = 3
    projection_steps: int = 30
    bit_reduction: float = 2.0
    pruning_periods: int = 5
    pruning_steps: int = 30
    cooldown_steps: int = 100


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)
    base_optimizer: str = "adamw"
    learning_rate: float = 3e-4
    # distribution
    fsdp: bool = False           # shard params/opt-state over the data axes
    remat_policy: str = "dots"   # none | dots | full
    gradient_compression: bool = False
    seed: int = 0

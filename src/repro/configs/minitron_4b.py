"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron. [arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, d_head=128, d_ff=9216, vocab=256000)

SMOKE = ModelConfig(
    name="minitron-4b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=6, n_kv_heads=2, d_head=32, d_ff=288, vocab=512,
    dtype="float32", remat=False)

SHARDING_OVERRIDES = {}

"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

FSDP mandatory (400B). Experts sharded on the model axis (EP: 128/16 = 8
experts per group)."""
from repro.configs.base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv_heads=8, d_head=128, d_ff=8192,
    vocab=202048,
    moe=MoEConfig(n_experts=128, top_k=1, every=2, shared_expert=True))

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=1, every=1, shared_expert=True),
    dtype="float32", remat=False)

SHARDING_OVERRIDES = {"fsdp": True, "base_optimizer": "momentum",
                      "experts_axis": "model"}

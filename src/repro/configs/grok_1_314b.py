"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2. [hf:xai-org/grok-1; unverified]

FSDP mandatory (314B). 8 experts < 16-way model axis, so EP on the expert
axis is infeasible — experts are instead tensor-parallel on the expert-MLP
hidden dim (32768/16 = 2048 per device)."""
from repro.configs.base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, d_head=128, d_ff=32768, vocab=131072,
    moe=MoEConfig(n_experts=8, top_k=2, every=1))

SMOKE = ModelConfig(
    name="grok-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=512, vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2, every=1),
    dtype="float32", remat=False)

SHARDING_OVERRIDES = {"fsdp": True, "base_optimizer": "momentum",
                      "experts_axis": None, "expert_mlp_axis": "model"}

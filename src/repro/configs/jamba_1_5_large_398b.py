"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave.
[arXiv:2403.19887; hf]

FSDP is mandatory at this scale (398B params); attention layers are full
causal but only 1-in-8 layers attend, so 500k-token decode stays feasible
(sub-quadratic overall — the Mamba state carries the context)."""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_head=128, d_ff=24576, vocab=65536,
    attn_every=8,
    moe=MoEConfig(n_experts=16, top_k=2, every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=512))

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid", n_layers=4, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=512,
    attn_every=2,
    moe=MoEConfig(n_experts=4, top_k=2, every=2),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=8),
    dtype="float32", remat=False)

SHARDING_OVERRIDES = {"fsdp": True, "base_optimizer": "momentum"}

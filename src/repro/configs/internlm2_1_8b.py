"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544. [arXiv:2403.17297; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_head=128, d_ff=8192, vocab=92544)

SMOKE = ModelConfig(
    name="internlm2-1.8b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=512,
    dtype="float32", remat=False)

SHARDING_OVERRIDES = {}

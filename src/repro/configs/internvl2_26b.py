"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 (InternViT + InternLM2). [arXiv:2404.16821; hf]

The InternViT frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings (B, 1024, d_model) prepended to the text."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_head=128, d_ff=16384, vocab=92553,
    vision_patches=1024)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=512,
    vision_patches=8, dtype="float32", remat=False)

SHARDING_OVERRIDES = {}

"""rwkv6-3b [ssm] — Finch: 32L d_model=2560 (attn-free) d_ff=8960
vocab=65536, data-dependent decay. [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, RWKVConfig

FULL = ModelConfig(
    name="rwkv6-3b", family="ssm_rwkv", n_layers=32, d_model=2560,
    n_heads=0, n_kv_heads=0, d_head=64, d_ff=8960, vocab=65536,
    rwkv=RWKVConfig(head_size=64, decay_lora=64))

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm_rwkv", n_layers=2, d_model=128,
    n_heads=0, n_kv_heads=0, d_head=32, d_ff=448, vocab=512,
    rwkv=RWKVConfig(head_size=32, decay_lora=8),
    dtype="float32", remat=False)

SHARDING_OVERRIDES = {}

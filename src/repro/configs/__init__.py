"""Architecture registry: --arch <id> resolution for every entry point.

Each assigned architecture ships its exact published config (FULL), a
reduced same-family smoke config (SMOKE), and per-arch sharding overrides.
The paper's own experiment substrates (VGG7, ResNet20/56, BERT-small) are
registered alongside.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (SHAPES, CompressionConfig, MambaConfig,
                                ModelConfig, MoEConfig, RWKVConfig,
                                RunConfig, ShapeConfig)

_ARCH_MODULES = {
    "stablelm-3b": "repro.configs.stablelm_3b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "minitron-4b": "repro.configs.minitron_4b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "musicgen-large": "repro.configs.musicgen_large",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "grok-1-314b": "repro.configs.grok_1_314b",
}

ASSIGNED_ARCHS = list(_ARCH_MODULES)


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.SMOKE if smoke else mod.FULL


def get_overrides(name: str) -> dict:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return dict(getattr(mod, "SHARDING_OVERRIDES", {}))


def arch_shapes(name: str) -> list[str]:
    """Shape cells assigned to an arch. long_500k only for sub-quadratic
    families (DESIGN.md §3) — skipped cells are reported as skip(design)."""
    cfg = get_arch(name)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")
    return shapes


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ASSIGNED_ARCHS for s in arch_shapes(a)]


__all__ = [
    "SHAPES", "ShapeConfig", "ModelConfig", "MoEConfig", "MambaConfig",
    "RWKVConfig", "RunConfig", "CompressionConfig", "ASSIGNED_ARCHS",
    "get_arch", "get_overrides", "arch_shapes", "all_cells",
]

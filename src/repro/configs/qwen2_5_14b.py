"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_head=128, d_ff=13824, vocab=152064,
    qkv_bias=True)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=512,
    qkv_bias=True, dtype="float32", remat=False)

SHARDING_OVERRIDES = {}

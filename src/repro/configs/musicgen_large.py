"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32 => MHA)
d_ff=8192 vocab=2048, decoder-only over EnCodec tokens (4 codebooks).
[arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per assignment: input_specs() provides the
4-codebook token frames directly; the model owns the codebook embeddings."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_head=64, d_ff=8192, vocab=2048,
    num_codebooks=4)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_head=32, d_ff=256, vocab=128,
    num_codebooks=4, dtype="float32", remat=False)

SHARDING_OVERRIDES = {}

"""BERT-style encoder (per-layer, unstacked) — Table 3's substrate.

Full-fidelity GETA path for transformers: per-layer quant sites, per-layer
attention-head and MLP-channel pruning families (the QADG appendix-D graph).
Used for the joint-vs-(prune-then-PTQ) comparison on a synthetic QA task.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.bops import LayerMacs
from repro.core.graph import FamilySpec, GraphBuilder
from repro.core.quant import fake_quant, init_quant_params
from repro.models.layers import attention_dense


def _qw(params, qparams, name):
    w = params[name]
    site = name + ".wq"
    if qparams is not None and site in qparams:
        qp = qparams[site]
        w = fake_quant(w, qp.d, qp.q_m, qp.t)
    return w


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(
        x.dtype)


class BertEncoder:
    def __init__(self, n_layers=4, d_model=256, n_heads=4, d_ff=1024,
                 vocab=8192, max_seq=512):
        self.L = n_layers
        self.D = d_model
        self.H = n_heads
        self.dh = d_model // n_heads
        self.F = d_ff
        self.V = vocab
        self.S = max_seq

    def init(self, key):
        D, F, V = self.D, self.F, self.V
        p = {}
        ks = iter(jax.random.split(key, 8 * self.L + 8))
        p["embed"] = jax.random.normal(next(ks), (V, D)) * 0.02
        p["pos_embed"] = jax.random.normal(next(ks), (self.S, D)) * 0.02
        for i in range(self.L):
            pre = f"enc.{i}"
            std = D ** -0.5
            for nm, shape in [("wq", (D, D)), ("wk", (D, D)), ("wv", (D, D)),
                              ("wo", (D, D))]:
                p[f"{pre}.attn.{nm}"] = jax.random.normal(next(ks), shape) * std
            p[f"{pre}.attn.bq"] = jnp.zeros((D,))
            p[f"{pre}.attn.bk"] = jnp.zeros((D,))
            p[f"{pre}.attn.bv"] = jnp.zeros((D,))
            p[f"{pre}.ln1.scale"] = jnp.ones((D,))
            p[f"{pre}.ln1.bias"] = jnp.zeros((D,))
            p[f"{pre}.mlp.w1"] = jax.random.normal(next(ks), (D, F)) * std
            p[f"{pre}.mlp.b1"] = jnp.zeros((F,))
            p[f"{pre}.mlp.w2"] = jax.random.normal(next(ks), (F, D)) * F ** -0.5
            p[f"{pre}.mlp.b2"] = jnp.zeros((D,))
            p[f"{pre}.ln2.scale"] = jnp.ones((D,))
            p[f"{pre}.ln2.bias"] = jnp.zeros((D,))
        p["qa_head.w"] = jax.random.normal(next(ks), (D, 2)) * D ** -0.5
        p["qa_head.b"] = jnp.zeros((2,))
        return p

    def apply(self, params, qparams, tokens):
        S = tokens.shape[1]
        x = params["embed"][tokens] + params["pos_embed"][:S]
        for i in range(self.L):
            pre = f"enc.{i}"
            q = x @ _qw(params, qparams, f"{pre}.attn.wq") \
                + params[f"{pre}.attn.bq"]
            k = x @ _qw(params, qparams, f"{pre}.attn.wk") \
                + params[f"{pre}.attn.bk"]
            v = x @ _qw(params, qparams, f"{pre}.attn.wv") \
                + params[f"{pre}.attn.bv"]
            B = x.shape[0]
            q = q.reshape(B, S, self.H, self.dh)
            k = k.reshape(B, S, self.H, self.dh)
            v = v.reshape(B, S, self.H, self.dh)
            a = attention_dense(q, k, v, causal=False)
            a = a.reshape(B, S, self.D)
            x = layernorm(x + a @ _qw(params, qparams, f"{pre}.attn.wo"),
                          params[f"{pre}.ln1.scale"],
                          params[f"{pre}.ln1.bias"])
            h = jax.nn.gelu(x @ _qw(params, qparams, f"{pre}.mlp.w1")
                            + params[f"{pre}.mlp.b1"])
            h = h @ _qw(params, qparams, f"{pre}.mlp.w2") \
                + params[f"{pre}.mlp.b2"]
            x = layernorm(x + h, params[f"{pre}.ln2.scale"],
                          params[f"{pre}.ln2.bias"])
        return x @ _qw(params, qparams, "qa_head.w") + params["qa_head.b"]

    def loss(self, params, qparams, batch):
        """SQuAD-style span loss: predict start/end positions."""
        logits = self.apply(params, qparams, batch["tokens"])  # (B, S, 2)
        logits = logits.astype(jnp.float32)
        out = 0.0
        for j, key in enumerate(("start", "end")):
            lj = logits[..., j]
            logz = jax.nn.logsumexp(lj, axis=-1)
            gold = jnp.take_along_axis(lj, batch[key][:, None], axis=-1)[:, 0]
            out += jnp.mean(logz - gold)
        return out / 2.0

    def exact_match(self, params, qparams, batch):
        logits = self.apply(params, qparams, batch["tokens"])
        s = jnp.argmax(logits[..., 0], -1)
        e = jnp.argmax(logits[..., 1], -1)
        return jnp.mean(jnp.logical_and(s == batch["start"],
                                        e == batch["end"]))

    # ------------------------------------------------------------- graph
    def build_graph(self, act_quant: bool = False) -> GraphBuilder:
        gb = GraphBuilder()
        gb.input("in")
        gb.embedding("embed", "embed", out_dim=self.D, non_prunable=True)
        resid = "embed"
        for i in range(self.L):
            pre = f"enc.{i}"
            members = [(f"{pre}.attn.wq", 1, self.dh),
                       (f"{pre}.attn.wk", 1, self.dh),
                       (f"{pre}.attn.wv", 1, self.dh),
                       (f"{pre}.attn.bq", 0, self.dh),
                       (f"{pre}.attn.bk", 0, self.dh),
                       (f"{pre}.attn.bv", 0, self.dh),
                       (f"{pre}.attn.wo", 0, self.dh)]
            spec = FamilySpec(name=f"{pre}.attn.heads", units=self.H,
                              members=members, kind="head_group")
            attn = gb.composite(
                f"{pre}.attn", "attention", spec,
                params={f"p{j}": m[0] for j, m in enumerate(members)},
                in_members=[(f"{pre}.attn.wq", 0), (f"{pre}.attn.wk", 0),
                            (f"{pre}.attn.wv", 0)],
                resid_members=[(f"{pre}.attn.wo", 1)],
                after=resid)
            for w in ("wq", "wk", "wv", "wo"):
                gb.attach_weight_quant(attn, f"{pre}.attn.{w}.wq",
                                       target_param=f"{pre}.attn.{w}")
            a1 = gb.add(f"{pre}.add1", [resid, attn])
            gb.norm(f"{pre}.ln1", scale=f"{pre}.ln1.scale",
                    bias=f"{pre}.ln1.bias", after=a1)
            fc1 = gb.linear(f"{pre}.mlp.fc1", f"{pre}.mlp.w1",
                            bias=f"{pre}.mlp.b1", out_dim=self.F,
                            after=f"{pre}.ln1")
            gb.attach_weight_quant(fc1, f"{pre}.mlp.w1.wq")
            act = gb.act(f"{pre}.mlp.gelu")
            fc2 = gb.linear(f"{pre}.mlp.fc2", f"{pre}.mlp.w2",
                            bias=f"{pre}.mlp.b2", out_dim=self.D,
                            non_prunable=True, after=act)
            if act_quant:
                gb.insert_act_quant(act, fc2, f"{pre}.mlp.gelu.aq")
            gb.attach_weight_quant(fc2, f"{pre}.mlp.w2.wq")
            a2 = gb.add(f"{pre}.add2", [f"{pre}.ln1", fc2])
            gb.norm(f"{pre}.ln2", scale=f"{pre}.ln2.scale",
                    bias=f"{pre}.ln2.bias", after=a2)
            resid = f"{pre}.ln2"
        head = gb.linear("qa_head", "qa_head.w", bias="qa_head.b",
                         out_dim=2, non_prunable=True, after=resid)
        gb.attach_weight_quant(head, "qa_head.w.wq")
        gb.output("out")
        return gb

    def quant_weight_names(self):
        names = []
        for i in range(self.L):
            pre = f"enc.{i}"
            names += [f"{pre}.attn.{w}" for w in ("wq", "wk", "wv", "wo")]
            names += [f"{pre}.mlp.w1", f"{pre}.mlp.w2"]
        names.append("qa_head.w")
        return names

    def init_qparams(self, params, bits_init=8.0, act_quant=False):
        qp = {}
        for name in self.quant_weight_names():
            qp[name + ".wq"] = init_quant_params(params[name], bits=bits_init)
        if act_quant:
            for i in range(self.L):
                qp[f"enc.{i}.mlp.gelu.aq"] = init_quant_params(
                    q_m=4.0, bits=bits_init)
        return qp

    def layer_macs(self, batch: int, seq: int) -> list[LayerMacs]:
        out = []
        toks = float(batch * seq)
        for i in range(self.L):
            pre = f"enc.{i}"
            for w in ("wq", "wk", "wv", "wo"):
                out.append(LayerMacs(f"{pre}.attn", toks * self.D * self.D,
                                     f"{pre}.attn.{w}"))
            out.append(LayerMacs(f"{pre}.mlp.fc1", toks * self.D * self.F,
                                 f"{pre}.mlp.w1"))
            out.append(LayerMacs(f"{pre}.mlp.fc2", toks * self.F * self.D,
                                 f"{pre}.mlp.w2"))
        out.append(LayerMacs("qa_head", toks * self.D * 2, "qa_head.w"))
        return out

"""Unified decoder-LM assembly for every assigned architecture family.

Layer heterogeneity (hybrid attn:mamba interleave, MoE-every-k) is handled
by a *period plan*: the layer pattern repeats with period p, parameters are
stacked over n_blocks = L / p per position-in-period, and the layer stack is
a single lax.scan over n_blocks whose body unrolls the p sublayers. This
keeps the HLO small (compile time ~seconds at 512 devices) while supporting
Jamba-style 1:7 interleave and MoE-every-2.

Weight quantization keeps per-stack (d, q_m, t) granularity (DESIGN.md
§2.2). Sites on projections routed through `layers.dense_proj` fuse into
the GEMM's RHS tile load inside the block body (no quantized stack is ever
materialized); the rest (einsum weights, head/embed) are fake-quanted once
per stack *outside* the scan. Activation quantizers apply inside the body.

Dense/attention projections route through the kernel dispatch layer
(`repro.kernels.dispatch`, DESIGN.md §4) via `layers.dense_proj`. The same
entry point consumes compressed Subnet weights: a param dict may replace a
2-D weight `<name>` with `<name>.codes` (int8/int16 codes, scan-stacked
like the dense tensor) + `<name>.scale`, and the block body then decodes
through the quant-dequant GEMM epilogue — the `--compressed` serving path.
Sub-byte sites ride as `<name>.packed{bits}` int32 word streams instead
(the storage width stays static in the key) and decode through the
unpack-dequant epilogue — the `--packed` path (DESIGN.md §4.8).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.graph import FamilySpec, GraphBuilder
from repro.core.quant import QuantParams, fake_quant, init_quant_params
from repro.models import layers as Lyr
from repro.models.layers import qw


@dataclasses.dataclass(frozen=True)
class SubLayer:
    j: int
    mixer: str     # attn | mamba | rwkv
    ffn: str       # mlp | moe | chanmix | none


def layer_plan(cfg: ModelConfig) -> tuple[list[SubLayer], int]:
    """(per-period sublayer specs, n_blocks)."""
    if cfg.family == "ssm_rwkv":
        return [SubLayer(0, "rwkv", "chanmix")], cfg.n_layers
    period = 1
    if cfg.family == "hybrid":
        period = cfg.attn_every
    if cfg.moe is not None:
        period = int(_lcm(period, cfg.moe.every))
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    plan = []
    for j in range(period):
        if cfg.family == "hybrid":
            mixer = "attn" if j % cfg.attn_every == 0 else "mamba"
        else:
            mixer = "attn"
        if cfg.moe is not None and j % cfg.moe.every == cfg.moe.every - 1:
            ffn = "moe"
        else:
            ffn = "mlp"
        plan.append(SubLayer(j, mixer, ffn))
    return plan, cfg.n_layers // period


def _lcm(a, b):
    return a * b // math.gcd(a, b)


# Which params receive weight-quant sites (per sublayer component).
_QUANT_WEIGHTS = {
    "attn": ["wq", "wk", "wv", "wo"],
    "mlp": ["w_gate", "w_up", "w_down"],
    "moe": ["router", "we_gate", "we_up", "we_down"],
    "mamba": ["in_proj_x", "in_proj_z", "x_proj", "dt_proj", "out_proj"],
    "rwkv": ["wr", "wk", "wv", "wg", "wo", "decay_w1", "decay_w2"],
    "chanmix": ["cm_k", "cm_v", "cm_r"],
}
_ACT_SITES = {
    "attn": ["attn_out"],
    "mlp": ["mlp_act"],
    "moe": [],
    "mamba": ["mamba_out"],
    "rwkv": ["tm_out"],
    "chanmix": ["cm_act"],
}


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan, self.n_blocks = layer_plan(cfg)
        # Physical dims per position-in-period. The forward/prefill/decode
        # bodies and init_cache consume these instead of the global config,
        # so a pruned subnet (core.subnet.derive_slim_plan) executes — and
        # allocates KV — at its sliced widths. Per-stack pruning granularity
        # (DESIGN.md §2.2) means every layer of a stack shares its
        # position's shapes: the layer scan stays shape-homogeneous and the
        # compiled-shape set is bounded by the period.
        self.shapes: list[Lyr.LayerShapes] = [
            Lyr.LayerShapes.from_config(cfg) for _ in self.plan]
        self.slim_plan = None
        # Optional NamedSharding for the (B, S, D) residual stream. Without
        # this pin, GSPMD's fixed-point for the scan carry can settle on
        # (batch-replicated, D-model-sharded) — measured 16x activation
        # blow-up on the 398B configs. Set by launch/dryrun/train.
        self.act_sharding = None
        # Optional dict name -> NamedSharding: pins fake-quantized weights
        # to their parameter sharding so the f32 quantization chain runs at
        # shard-local width (GSPMD otherwise quantizes *after* the FSDP
        # all-gather — measured ~35 gathered f32 expert-weight copies).
        self.param_shardings = None

    def _constrain(self, x):
        if self.act_sharding is not None and x.ndim == 3:
            x = jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    def apply_slim_plan(self, plan) -> None:
        """Execute at a `core.subnet.SlimPlan`'s physical widths.

        After this, forward/prefill/decode_step expect *sliced* params
        (`PruningSpace.materialize` output) and init_cache allocates the
        shrunk KV/state arena (surviving kv heads / mamba channels / rwkv
        heads only)."""
        if len(plan.layer_shapes) != len(self.plan):
            raise ValueError(
                f"slim plan has {len(plan.layer_shapes)} sublayer shapes, "
                f"model period has {len(self.plan)}")
        self.shapes = list(plan.layer_shapes)
        self.slim_plan = plan

    # ------------------------------------------------------------- params
    def init(self, key) -> tuple[dict, dict]:
        cfg = self.cfg
        dt = Lyr._dt(cfg)
        D, Vp = cfg.d_model, cfg.vocab_padded
        params: dict = {}
        axes: dict = {}
        keys = jax.random.split(key, 4 + len(self.plan) * 2)

        # embeddings
        if cfg.num_codebooks:
            params["embed"] = jax.random.normal(
                keys[0], (cfg.num_codebooks, Vp, D), dt) * 0.02
            axes["embed"] = ("codebooks", "vocab", "embed")
            params["head"] = jax.random.normal(
                keys[1], (D, cfg.num_codebooks * Vp), dt) * D ** -0.5
            axes["head"] = ("embed", "vocab_out")
        else:
            params["embed"] = jax.random.normal(keys[0], (Vp, D), dt) * 0.02
            axes["embed"] = ("vocab", "embed")
            if not cfg.tie_embeddings:
                params["head"] = jax.random.normal(
                    keys[1], (D, Vp), dt) * D ** -0.5
                axes["head"] = ("embed", "vocab_out")
        params["final_norm"] = jnp.ones((D,), jnp.float32)
        axes["final_norm"] = ("embed",)

        for i, sub in enumerate(self.plan):
            kmix, kffn = jax.random.split(keys[4 + i], 2)
            pre = f"blocks.{sub.j}"
            params[f"{pre}.norm1"] = jnp.ones((self.n_blocks, D), jnp.float32)
            axes[f"{pre}.norm1"] = ("layers", "embed")
            if sub.ffn != "none":
                params[f"{pre}.norm2"] = jnp.ones((self.n_blocks, D),
                                                  jnp.float32)
                axes[f"{pre}.norm2"] = ("layers", "embed")

            if sub.mixer == "attn":
                p, a = Lyr.init_attention(kmix, cfg, f"{pre}.attn",
                                          self.n_blocks, dt)
            elif sub.mixer == "mamba":
                p, a = Lyr.init_mamba(kmix, cfg, f"{pre}.mamba",
                                      self.n_blocks, dt)
                # split in_proj for clean pruning groups
                ip = p.pop(f"{pre}.mamba.in_proj")
                ax = a.pop(f"{pre}.mamba.in_proj")
                half = ip.shape[-1] // 2
                p[f"{pre}.mamba.in_proj_x"] = ip[..., :half]
                p[f"{pre}.mamba.in_proj_z"] = ip[..., half:]
                a[f"{pre}.mamba.in_proj_x"] = ax[:-1] + ("mamba_inner",)
                a[f"{pre}.mamba.in_proj_z"] = ax[:-1] + ("mamba_inner",)
            else:  # rwkv
                p, a = Lyr.init_rwkv(kmix, cfg, f"{pre}.rwkv",
                                     self.n_blocks, dt)
            params.update(p)
            axes.update(a)

            if sub.ffn == "mlp":
                p, a = Lyr.init_mlp(kffn, cfg, f"{pre}.mlp", self.n_blocks, dt)
                params.update(p)
                axes.update(a)
            elif sub.ffn == "moe":
                p, a = Lyr.init_moe(kffn, cfg, f"{pre}.moe", self.n_blocks, dt)
                params.update(p)
                axes.update(a)
        return params, axes

    # --------------------------------------------------------- quantization
    def quant_weight_names(self) -> list[str]:
        names = []
        for sub in self.plan:
            pre = f"blocks.{sub.j}"
            comp = sub.mixer
            names += [f"{pre}.{comp}.{w}" for w in _QUANT_WEIGHTS[comp]]
            if sub.ffn in ("mlp", "moe"):
                names += [f"{pre}.{sub.ffn}.{w}"
                          for w in _QUANT_WEIGHTS[sub.ffn]]
                if sub.ffn == "moe" and self.cfg.moe.shared_expert:
                    names += [f"{pre}.moe.shared.{w}"
                              for w in _QUANT_WEIGHTS["mlp"]]
            elif sub.ffn == "chanmix":
                names += [f"{pre}.rwkv.{w}" for w in _QUANT_WEIGHTS["chanmix"]]
        names.append("head" if not self.cfg.tie_embeddings
                     or self.cfg.num_codebooks else "embed")
        return names

    def act_site_names(self) -> list[str]:
        names = []
        for sub in self.plan:
            pre = f"blocks.{sub.j}"
            names += [f"{pre}.{sub.mixer}.{s}.aq"
                      for s in _ACT_SITES[sub.mixer]]
            if sub.ffn in ("mlp", "moe"):
                names += [f"{pre}.{sub.ffn}.{s}.aq"
                          for s in _ACT_SITES[sub.ffn]]
            elif sub.ffn == "chanmix":
                names += [f"{pre}.rwkv.{s}.aq" for s in _ACT_SITES["chanmix"]]
        return names

    def init_qparams(self, params: dict, bits_init: float = 8.0,
                     act_quant: bool = False) -> dict[str, QuantParams]:
        qp = {}
        for name in self.quant_weight_names():
            if name in params:
                qp[name + ".wq"] = init_quant_params(params[name],
                                                     bits=bits_init)
        if act_quant:
            for site in self.act_site_names():
                qp[site] = init_quant_params(q_m=4.0, bits=bits_init)
        return qp

    # Stacked 2-D projections of these components run through
    # `layers.dense_proj` inside the block body — their weight quantizer
    # fuses into the GEMM's RHS tile load (`fq_matmul_op`), so the stack
    # never materializes a quantized copy in HBM. Per-stack (d, q_m, t)
    # granularity is unchanged: the same scalars apply to every layer
    # slice (elementwise op commutes with the scan slicing).
    _FUSED_QAT_COMPONENTS = Lyr.ROUTED_COMPONENTS

    def _fused_qat_site(self, name: str, w) -> bool:
        parts = name.split(".")
        return (Lyr.kernel_dispatch_enabled() and name.startswith("blocks.")
                and len(parts) >= 3
                and parts[-2] in self._FUSED_QAT_COMPONENTS and w.ndim == 3)

    def _prequantize(self, params: dict, qparams: Optional[dict]
                     ) -> tuple[dict, Optional[dict]]:
        """Split weight quantizers into fused-in-body sites (routed 2-D
        projections, applied inside the GEMM epilogue by `dense_proj`) and
        prequantized stacks (einsum weights, head/embed — fake-quanted once
        outside the layer scan). Returns (params, body qparams)."""
        if qparams is None:
            return params, None
        out = dict(params)
        fused_q: dict = {}
        for name in self.quant_weight_names():
            site = name + ".wq"
            if name in out and site in qparams:
                if self._fused_qat_site(name, out[name]):
                    fused_q[site] = qparams[site]
                    continue
                q = qparams[site]
                w = fake_quant(out[name], q.d, q.q_m, q.t)
                if self.param_shardings is not None \
                        and name in self.param_shardings:
                    w = jax.lax.with_sharding_constraint(
                        w, self.param_shardings[name])
                out[name] = w
        body_q = {k: v for k, v in qparams.items() if k.endswith(".aq")}
        body_q.update(fused_q)
        return out, (body_q or None)

    # -------------------------------------------------------------- forward
    def _embed_tokens(self, params, tokens):
        cfg = self.cfg
        if cfg.num_codebooks:
            # tokens: (B, S, n_codebooks) -> sum of per-codebook embeddings
            embs = [params["embed"][c][tokens[..., c]]
                    for c in range(cfg.num_codebooks)]
            return sum(embs)
        return params["embed"][tokens]

    def _head(self, params, h):
        cfg = self.cfg
        if cfg.tie_embeddings and not cfg.num_codebooks:
            return h @ params["embed"].T
        return Lyr.dense_proj(h, params, None, "head")

    def _block_params(self, params: dict) -> dict:
        return {k: v for k, v in params.items() if k.startswith("blocks.")}

    def _body(self, qp_body, rope, window_rope=None):
        cfg = self.cfg

        def body(x, lp):
            x = self._constrain(x)
            for sub, shp in zip(self.plan, self.shapes):
                pre = f"blocks.{sub.j}"
                h = Lyr.rmsnorm(x, lp[f"{pre}.norm1"], cfg.norm_eps)
                if sub.mixer == "attn":
                    mix, _ = Lyr.attn_apply(
                        lp, qp_body, cfg, h, rope=rope, window=cfg.window,
                        prefix=f"{pre}.attn", shapes=shp)
                elif sub.mixer == "mamba":
                    mix, _ = Lyr.mamba_apply(lp, qp_body, cfg, h,
                                             prefix=f"{pre}.mamba", shapes=shp)
                else:
                    mix, _ = Lyr.rwkv_timemix_apply(lp, qp_body, cfg, h,
                                                    prefix=f"{pre}.rwkv",
                                                    shapes=shp)
                x = x + mix
                if sub.ffn == "none":
                    continue
                h2 = Lyr.rmsnorm(x, lp[f"{pre}.norm2"], cfg.norm_eps)
                if sub.ffn == "mlp":
                    f = Lyr.mlp_apply(lp, qp_body, cfg, h2, prefix=f"{pre}.mlp")
                elif sub.ffn == "moe":
                    f = Lyr.moe_apply(lp, qp_body, cfg, h2, prefix=f"{pre}.moe",
                                      shapes=shp)
                else:
                    f, _ = Lyr.rwkv_chanmix_apply(lp, qp_body, cfg, h2,
                                                  prefix=f"{pre}.rwkv")
                x = x + f
            return x, None

        return body

    def forward(self, params: dict, qparams: Optional[dict], tokens,
                vision_embeds=None):
        """tokens: (B, S[, n_codebooks]); vision_embeds: (B, P, D) for vlm.
        Returns logits (B, S_total, ...)."""
        cfg = self.cfg
        params, qp_body = self._prequantize(params, qparams)
        x = self._embed_tokens(params, tokens)
        if cfg.vision_patches and vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        x = self._constrain(x)
        S = x.shape[1]
        rope = Lyr.rope_tables(S, cfg.d_head, cfg.rope_theta)
        body = self._body(qp_body, rope)
        if cfg.remat:
            # full remat of the block body: only the per-layer residual
            # stream survives to the backward (measured 2x temp reduction
            # vs dots_with_no_batch_dims at 4k seq)
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        bp = self._block_params(params)
        if self.n_blocks <= 2:
            # unrolled: the roofline's depth-1/depth-2 differencing needs
            # per-layer costs visible to HloCostAnalysis (a while body is
            # visited once regardless of trip count)
            for i in range(self.n_blocks):
                x, _ = body(x, {k: v[i] for k, v in bp.items()})
        else:
            x, _ = jax.lax.scan(body, x, bp)
        x = self._constrain(x)
        x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)
        if cfg.num_codebooks:
            B, St = logits.shape[:2]
            logits = logits.reshape(B, St, cfg.num_codebooks, cfg.vocab_padded)
        return logits

    # ----------------------------------------------------------------- loss
    def loss(self, params, qparams, batch) -> jax.Array:
        """Next-token cross-entropy, vocab-shard friendly.

        The gold logit is extracted with an iota-compare masked reduction
        (fuses under GSPMD when the vocab axis is model-sharded) instead of
        take_along_axis, which would all-gather the full (B, S, V) logits —
        measured at +24 GB/device temp on the 92k-vocab archs."""
        cfg = self.cfg
        tokens = batch["tokens"]
        logits = self.forward(params, qparams, tokens,
                              vision_embeds=batch.get("vision_embeds"))
        if cfg.vision_patches:
            logits = logits[:, cfg.vision_patches:]
        pred = logits[:, :-1].astype(jnp.float32)
        tgt = tokens[:, 1:]
        logz = jax.nn.logsumexp(pred, axis=-1)
        vocab_iota = jnp.arange(pred.shape[-1], dtype=tgt.dtype)
        gold = jnp.sum(jnp.where(vocab_iota == tgt[..., None], pred, 0.0),
                       axis=-1)
        return jnp.mean(logz - gold)

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        """Decode cache arena, sized from the per-sublayer shapes — a
        pruned subnet allocates KV rows for *surviving* kv heads (and
        mamba channels / rwkv heads) only, so the HBM the arena pins
        shrinks with realized sparsity, not just the weight bytes."""
        cfg = self.cfg
        caches = {}
        for sub, shp in zip(self.plan, self.shapes):
            pre = f"blocks.{sub.j}"
            nb = self.n_blocks
            if sub.mixer == "attn":
                S = min(max_seq, cfg.window) if cfg.window > 0 else max_seq
                caches[f"{pre}.k"] = jnp.zeros(
                    (nb, batch, S, shp.n_kv_heads, shp.d_head), dtype)
                caches[f"{pre}.v"] = jnp.zeros(
                    (nb, batch, S, shp.n_kv_heads, shp.d_head), dtype)
            elif sub.mixer == "mamba":
                Di = shp.mamba_inner
                caches[f"{pre}.h"] = jnp.zeros(
                    (nb, batch, Di, cfg.mamba.d_state), jnp.float32)
                caches[f"{pre}.conv"] = jnp.zeros(
                    (nb, batch, cfg.mamba.d_conv - 1, Di), dtype)
            else:  # rwkv
                D = shp.d_model
                H = shp.rwkv_heads
                dh = cfg.rwkv.head_size
                caches[f"{pre}.tm_shift"] = jnp.zeros((nb, batch, D),
                                                      jnp.float32)
                caches[f"{pre}.wkv"] = jnp.zeros((nb, batch, H, dh, dh),
                                                 jnp.float32)
                caches[f"{pre}.cm_shift"] = jnp.zeros((nb, batch, D),
                                                      jnp.float32)
        return caches

    def init_paged_cache(self, batch: int, n_pages: int, page_size: int,
                         dtype=jnp.bfloat16, kv_bits: Optional[int] = None):
        """Paged decode arena: attention K/V become *pools* of
        (nb, n_pages, page_size, KVh, dh) pages shared by every slot and
        addressed through per-slot page tables (`Lyr.PagedView`), so HBM
        scales with written rows, not slots × max_seq. With `kv_bits`
        (8 | 4) the pools hold int8 codes (nibble pairs halve the byte
        width at 4) plus per-row f32 scale planes `<pre>.k_scale` /
        `<pre>.v_scale`, decoded in-kernel at read time. Recurrent state
        (mamba / rwkv) is O(1) per slot and stays a contiguous (nb,
        batch, ...) arena — only attention rows page. `batch` sizes those
        state leaves (= max_slots)."""
        cfg = self.cfg
        if cfg.window > 0:
            raise ValueError("paged KV arena needs full (non-ring) caches; "
                             f"window={cfg.window}")
        if kv_bits is not None:
            from repro.core.quant import KV_STORAGE_BITS
            if kv_bits not in KV_STORAGE_BITS:
                raise ValueError(f"kv_bits must be in {KV_STORAGE_BITS}, "
                                 f"got {kv_bits}")
        caches = {}
        for sub, shp in zip(self.plan, self.shapes):
            pre = f"blocks.{sub.j}"
            nb = self.n_blocks
            if sub.mixer == "attn":
                KVh, dh = shp.n_kv_heads, shp.d_head
                if kv_bits is None:
                    z = jnp.zeros((nb, n_pages, page_size, KVh, dh), dtype)
                    caches[f"{pre}.k"] = z
                    caches[f"{pre}.v"] = z
                else:
                    if kv_bits == 4 and dh % 2:
                        raise ValueError(f"kv_bits=4 packs code pairs; "
                                         f"d_head={dh} must be even")
                    dhs = dh // 2 if kv_bits == 4 else dh
                    zc = jnp.zeros((nb, n_pages, page_size, KVh, dhs),
                                   jnp.int8)
                    zs = jnp.zeros((nb, n_pages, page_size, KVh),
                                   jnp.float32)
                    caches[f"{pre}.k"] = zc
                    caches[f"{pre}.v"] = zc
                    caches[f"{pre}.k_scale"] = zs
                    caches[f"{pre}.v_scale"] = zs
            elif sub.mixer == "mamba":
                Di = shp.mamba_inner
                caches[f"{pre}.h"] = jnp.zeros(
                    (nb, batch, Di, cfg.mamba.d_state), jnp.float32)
                caches[f"{pre}.conv"] = jnp.zeros(
                    (nb, batch, cfg.mamba.d_conv - 1, Di), dtype)
            else:  # rwkv
                D = shp.d_model
                H = shp.rwkv_heads
                dh = cfg.rwkv.head_size
                caches[f"{pre}.tm_shift"] = jnp.zeros((nb, batch, D),
                                                      jnp.float32)
                caches[f"{pre}.wkv"] = jnp.zeros((nb, batch, H, dh, dh),
                                                 jnp.float32)
                caches[f"{pre}.cm_shift"] = jnp.zeros((nb, batch, D),
                                                      jnp.float32)
        return caches

    def decode_step(self, params: dict, qparams: Optional[dict], caches: dict,
                    token, pos, pages=None):
        """One-token decode. token: (B, 1[, n_codebooks]); pos: scalar
        (static batching, every sequence in lockstep) or (B,) int vector
        (continuous batching: each slot at its own absolute position).
        `pages` (a `Lyr.PagedView`) switches attention caches to the
        paged pools of `init_paged_cache` — the view's table indirects
        every K/V write and read; recurrent state is untouched.
        Returns (logits, new_caches)."""
        cfg = self.cfg
        params, qp_body = self._prequantize(params, qparams)
        x = self._embed_tokens(params, token)
        B = x.shape[0]
        # rope at each sequence's absolute position
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
        posf = pos.astype(jnp.float32)
        freqs = cfg.rope_theta ** (-jnp.arange(0, cfg.d_head, 2,
                                               dtype=jnp.float32) / cfg.d_head)
        ang = posf[:, None] * freqs[None, :]
        rope = (jnp.cos(ang)[:, None], jnp.sin(ang)[:, None])   # (B, 1, dh/2)

        def body(x, inp):
            lp = inp["p"]
            cc = inp["c"]
            new_c = {}
            for sub, shp in zip(self.plan, self.shapes):
                pre = f"blocks.{sub.j}"
                h = Lyr.rmsnorm(x, lp[f"{pre}.norm1"], cfg.norm_eps)
                if sub.mixer == "attn" and pages is not None:
                    cache = (cc[f"{pre}.k"], cc[f"{pre}.v"], pos)
                    if pages.kv_bits is not None:
                        cache += (cc[f"{pre}.k_scale"], cc[f"{pre}.v_scale"])
                    mix, nc = Lyr.attn_apply(
                        lp, qp_body, cfg, h, rope=rope, window=cfg.window,
                        prefix=f"{pre}.attn", shapes=shp, cache=cache,
                        pages=pages)
                    new_c[f"{pre}.k"], new_c[f"{pre}.v"], _, nks, nvs = nc
                    if pages.kv_bits is not None:
                        new_c[f"{pre}.k_scale"] = nks
                        new_c[f"{pre}.v_scale"] = nvs
                elif sub.mixer == "attn":
                    mix, nc = Lyr.attn_apply(
                        lp, qp_body, cfg, h, rope=rope, window=cfg.window,
                        prefix=f"{pre}.attn", shapes=shp,
                        cache=(cc[f"{pre}.k"], cc[f"{pre}.v"], pos))
                    new_c[f"{pre}.k"], new_c[f"{pre}.v"], _ = nc
                elif sub.mixer == "mamba":
                    mix, ns = Lyr.mamba_apply(
                        lp, qp_body, cfg, h, prefix=f"{pre}.mamba", shapes=shp,
                        state=(cc[f"{pre}.h"], cc[f"{pre}.conv"]))
                    new_c[f"{pre}.h"], new_c[f"{pre}.conv"] = ns
                else:
                    mix, ns = Lyr.rwkv_timemix_apply(
                        lp, qp_body, cfg, h, prefix=f"{pre}.rwkv", shapes=shp,
                        state=(cc[f"{pre}.tm_shift"], cc[f"{pre}.wkv"]))
                    new_c[f"{pre}.tm_shift"], new_c[f"{pre}.wkv"] = ns
                x = x + mix
                if sub.ffn == "none":
                    continue
                h2 = Lyr.rmsnorm(x, lp[f"{pre}.norm2"], cfg.norm_eps)
                if sub.ffn == "mlp":
                    f = Lyr.mlp_apply(lp, qp_body, cfg, h2, prefix=f"{pre}.mlp")
                elif sub.ffn == "moe":
                    f = Lyr.moe_apply(lp, qp_body, cfg, h2, prefix=f"{pre}.moe",
                                      shapes=shp)
                else:
                    f, ns = Lyr.rwkv_chanmix_apply(
                        lp, qp_body, cfg, h2, prefix=f"{pre}.rwkv",
                        state=cc[f"{pre}.cm_shift"])
                    new_c[f"{pre}.cm_shift"] = ns
                x = x + f
            return x, new_c

        bp = self._block_params(params)
        if self.n_blocks <= 2:
            new_list = []
            for i in range(self.n_blocks):
                x, nc = body(x, {"p": {k: v[i] for k, v in bp.items()},
                                 "c": {k: v[i] for k, v in caches.items()}})
                new_list.append(nc)
            new_caches = {k: jnp.stack([nc[k] for nc in new_list])
                          for k in new_list[0]}
        else:
            x, new_caches = jax.lax.scan(body, x, {"p": bp, "c": caches})
        x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)
        if cfg.num_codebooks:
            B = logits.shape[0]
            logits = logits.reshape(B, 1, cfg.num_codebooks, cfg.vocab_padded)
        return logits, new_caches

    def verify_chunk(self, params: dict, qparams: Optional[dict],
                     caches: dict, tokens, pos,
                     last_logit_only: bool = False):
        """Score a T-token chunk mid-sequence against the live caches —
        the speculative verify pass. tokens: (B, T) where column 0 is the
        last committed token of each slot and columns 1..T-1 are draft
        proposals; pos: (B,) absolute position of column 0. One batched
        pass writes K/V rows [pos, pos+T) per slot and returns logits for
        all T positions (a leading-match acceptance rule then commits the
        argmaxes) — T target decode steps for the price of one dispatch,
        the same GEMM-shaping win one-shot prefill gets at admission.

        Attention-mixer layers only: mamba/rwkv carry a recurrent state
        that a rejected suffix cannot roll back (KV rows can be zeroed;
        an SSM state cannot be un-stepped). Full (window == 0) arenas
        only, for the same reason — ring wrap overwrites history.
        Returns (logits (B, T, V), new_caches); `last_logit_only` projects
        just the final position through the head, like prefill's — the
        engine's chunked prefill only feeds on the last chunk's last
        position, so every earlier head GEMM would be dead work."""
        cfg = self.cfg
        bad = [sub.mixer for sub in self.plan if sub.mixer != "attn"]
        if bad:
            raise ValueError(
                f"verify_chunk needs attention mixers everywhere (rollback "
                f"zeroes KV rows); plan has {sorted(set(bad))} layers whose "
                f"recurrent state cannot be rolled back")
        if cfg.num_codebooks:
            raise ValueError("verify_chunk serves plain token LMs")
        params, qp_body = self._prequantize(params, qparams)
        x = self._embed_tokens(params, tokens)
        B, T = x.shape[0], x.shape[1]
        # rope at each slot's absolute positions pos[b] + [0, T)
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
        posf = (pos[:, None] + jnp.arange(T)[None, :]).astype(jnp.float32)
        freqs = cfg.rope_theta ** (-jnp.arange(0, cfg.d_head, 2,
                                               dtype=jnp.float32) / cfg.d_head)
        ang = posf[..., None] * freqs[None, None, :]
        rope = (jnp.cos(ang), jnp.sin(ang))                 # (B, T, dh/2)

        def body(x, inp):
            lp = inp["p"]
            cc = inp["c"]
            new_c = {}
            for sub, shp in zip(self.plan, self.shapes):
                pre = f"blocks.{sub.j}"
                h = Lyr.rmsnorm(x, lp[f"{pre}.norm1"], cfg.norm_eps)
                mix, nc = Lyr.attn_apply(
                    lp, qp_body, cfg, h, rope=rope, window=cfg.window,
                    prefix=f"{pre}.attn", shapes=shp, chunked=True,
                    cache=(cc[f"{pre}.k"], cc[f"{pre}.v"], pos))
                new_c[f"{pre}.k"], new_c[f"{pre}.v"], _ = nc
                x = x + mix
                if sub.ffn == "none":
                    continue
                h2 = Lyr.rmsnorm(x, lp[f"{pre}.norm2"], cfg.norm_eps)
                if sub.ffn == "mlp":
                    f = Lyr.mlp_apply(lp, qp_body, cfg, h2,
                                      prefix=f"{pre}.mlp")
                else:
                    # serving semantics, like prefill: chunk tokens never
                    # compete for expert capacity (one-token decode can't
                    # overflow, so a dropping verify would diverge from
                    # the sequential decode it stands in for)
                    f = Lyr.moe_apply(lp, qp_body, cfg, h2,
                                      prefix=f"{pre}.moe",
                                      full_capacity=True, shapes=shp)
                x = x + f
            return x, new_c

        bp = self._block_params(params)
        if self.n_blocks <= 2:
            new_list = []
            for i in range(self.n_blocks):
                x, nc = body(x, {"p": {k: v[i] for k, v in bp.items()},
                                 "c": {k: v[i] for k, v in caches.items()}})
                new_list.append(nc)
            new_caches = {k: jnp.stack([nc[k] for nc in new_list])
                          for k in new_list[0]}
        else:
            x, new_caches = jax.lax.scan(body, x, {"p": bp, "c": caches})
        if last_logit_only:
            x = x[:, -1:]
        x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)
        return logits, new_caches

    def prefill(self, params: dict, qparams: Optional[dict], caches: dict,
                tokens, vision_embeds=None, last_logit_only: bool = False):
        """One-shot parallel prefill: a single full-sequence pass that also
        fills the decode caches — K/V rows written at positions [0, S) in
        one slice update per layer, SSM/RWKV states left as they stand
        after the last prompt token. Numerically equivalent to S sequential
        `decode_step` calls but with GEMM-shaped (B, S) matmuls instead of
        S token-by-token dispatches (the engine's admission path).

        tokens: (B, S[, n_codebooks]). `caches` must be freshly initialized
        for these sequences (rows are overwritten from position 0) and,
        on windowed-attention configs, S must fit inside the window.
        Returns (logits (B, S, ...), caches); `last_logit_only` projects
        just the final position through the head (decode only feeds on
        that one — skips an (S-1) x vocab GEMM per admission)."""
        cfg = self.cfg
        params, qp_body = self._prequantize(params, qparams)
        x = self._embed_tokens(params, tokens)
        if cfg.vision_patches and vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        S = x.shape[1]
        rope = Lyr.rope_tables(S, cfg.d_head, cfg.rope_theta)

        def body(x, inp):
            lp = inp["p"]
            cc = inp["c"]
            new_c = {}
            for sub, shp in zip(self.plan, self.shapes):
                pre = f"blocks.{sub.j}"
                h = Lyr.rmsnorm(x, lp[f"{pre}.norm1"], cfg.norm_eps)
                if sub.mixer == "attn":
                    mix, nc = Lyr.attn_apply(
                        lp, qp_body, cfg, h, rope=rope, window=cfg.window,
                        prefix=f"{pre}.attn", shapes=shp,
                        cache=(cc[f"{pre}.k"], cc[f"{pre}.v"],
                               jnp.zeros((), jnp.int32)))
                    new_c[f"{pre}.k"], new_c[f"{pre}.v"], _ = nc
                elif sub.mixer == "mamba":
                    mix, ns = Lyr.mamba_apply(lp, qp_body, cfg, h,
                                              prefix=f"{pre}.mamba",
                                              shapes=shp)
                    new_c[f"{pre}.h"], new_c[f"{pre}.conv"] = ns
                else:
                    mix, ns = Lyr.rwkv_timemix_apply(lp, qp_body, cfg, h,
                                                     prefix=f"{pre}.rwkv",
                                                     shapes=shp)
                    new_c[f"{pre}.tm_shift"], new_c[f"{pre}.wkv"] = ns
                x = x + mix
                if sub.ffn == "none":
                    continue
                h2 = Lyr.rmsnorm(x, lp[f"{pre}.norm2"], cfg.norm_eps)
                if sub.ffn == "mlp":
                    f = Lyr.mlp_apply(lp, qp_body, cfg, h2, prefix=f"{pre}.mlp")
                elif sub.ffn == "moe":
                    # serving semantics: prompt tokens never compete for
                    # expert capacity (one-token decode can't overflow, so
                    # a dropping prefill would silently diverge from it)
                    f = Lyr.moe_apply(lp, qp_body, cfg, h2,
                                      prefix=f"{pre}.moe", full_capacity=True,
                                      shapes=shp)
                else:
                    f, ns = Lyr.rwkv_chanmix_apply(lp, qp_body, cfg, h2,
                                                   prefix=f"{pre}.rwkv")
                    new_c[f"{pre}.cm_shift"] = ns
                x = x + f
            return x, new_c

        bp = self._block_params(params)
        if self.n_blocks <= 2:
            new_list = []
            for i in range(self.n_blocks):
                x, nc = body(x, {"p": {k: v[i] for k, v in bp.items()},
                                 "c": {k: v[i] for k, v in caches.items()}})
                new_list.append(nc)
            new_caches = {k: jnp.stack([nc[k] for nc in new_list])
                          for k in new_list[0]}
        else:
            x, new_caches = jax.lax.scan(body, x, {"p": bp, "c": caches})
        if last_logit_only:
            x = x[:, -1:]
        x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)
        if cfg.num_codebooks:
            B, St = logits.shape[:2]
            logits = logits.reshape(B, St, cfg.num_codebooks, cfg.vocab_padded)
        return logits, new_caches

    # -------------------------------------------------------------- graph
    def build_graph(self, act_quant: bool = False) -> GraphBuilder:
        """Trace graph + quant branches for QADG analysis.

        Stacked tensors: one vertex per (position-in-period, component);
        families over head groups / experts / channels apply uniformly
        across the n_blocks stack (per-stack granularity, DESIGN.md §2.2).
        """
        cfg = self.cfg
        gb = GraphBuilder()
        gb.input("in")
        gb.embedding("embed", "embed", out_dim=cfg.d_model,
                     non_prunable=True, after="in",
                     out_axis=(2 if cfg.num_codebooks else 1))
        resid = "embed"
        for sub in self.plan:
            pre = f"blocks.{sub.j}"
            gb.norm(f"{pre}.norm1", scale=f"{pre}.norm1", after=resid,
                    param_axis=1)
            mixer_v = self._graph_mixer(gb, sub, pre)
            resid = gb.add(f"{pre}.add1", [resid, mixer_v])
            if sub.ffn == "none":
                continue
            gb.norm(f"{pre}.norm2", scale=f"{pre}.norm2", after=resid,
                    param_axis=1)
            ffn_v = self._graph_ffn(gb, sub, pre, act_quant)
            resid = gb.add(f"{pre}.add2", [resid, ffn_v])
        gb.norm("final_norm", scale="final_norm", after=resid)
        tied = cfg.tie_embeddings and not cfg.num_codebooks
        head_param = "embed" if tied else "head"
        head_out = cfg.vocab_padded * max(cfg.num_codebooks, 1)
        gb.linear("head", head_param, out_dim=head_out,
                  non_prunable=True,
                  in_axis=(1 if tied else 0), out_axis=(0 if tied else 1),
                  after="final_norm")
        gb.attach_weight_quant("head", f"{head_param}.wq",
                               target_param=head_param)
        gb.output("out", after="head")
        return gb

    def _graph_mixer(self, gb: GraphBuilder, sub: SubLayer, pre: str) -> str:
        cfg = self.cfg
        if sub.mixer == "attn":
            gsz = cfg.gqa_group
            dh = cfg.d_head
            members = [(f"{pre}.attn.wq", 2, gsz * dh),
                       (f"{pre}.attn.wk", 2, dh),
                       (f"{pre}.attn.wv", 2, dh),
                       (f"{pre}.attn.wo", 1, gsz * dh)]
            if cfg.qkv_bias:
                members += [(f"{pre}.attn.bq", 1, gsz * dh),
                            (f"{pre}.attn.bk", 1, dh),
                            (f"{pre}.attn.bv", 1, dh)]
            spec = FamilySpec(name=f"{pre}.attn.kv_groups",
                              units=cfg.n_kv_heads, members=members,
                              kind="head_group")
            vid = gb.composite(
                f"{pre}.attn", "attention", spec,
                params={f"p{i}": m[0] for i, m in enumerate(members)},
                in_members=[(f"{pre}.attn.wq", 1), (f"{pre}.attn.wk", 1),
                            (f"{pre}.attn.wv", 1)],
                resid_members=[(f"{pre}.attn.wo", 2)],
                after=f"{pre}.norm1")
            for w in _QUANT_WEIGHTS["attn"]:
                gb.attach_weight_quant(vid, f"{pre}.attn.{w}.wq",
                                       target_param=f"{pre}.attn.{w}")
            return vid
        if sub.mixer == "mamba":
            Di = cfg.mamba.expand * cfg.d_model
            members = [(f"{pre}.mamba.in_proj_x", 2, 1),
                       (f"{pre}.mamba.in_proj_z", 2, 1),
                       (f"{pre}.mamba.conv_w", 2, 1),
                       (f"{pre}.mamba.x_proj", 1, 1),
                       (f"{pre}.mamba.dt_proj", 2, 1),
                       (f"{pre}.mamba.dt_bias", 1, 1),
                       (f"{pre}.mamba.A_log", 1, 1),
                       (f"{pre}.mamba.D", 1, 1),
                       (f"{pre}.mamba.out_proj", 1, 1)]
            spec = FamilySpec(name=f"{pre}.mamba.channels", units=Di,
                              members=members, kind="state")
            vid = gb.composite(
                f"{pre}.mamba", "mamba", spec,
                params={f"p{i}": m[0] for i, m in enumerate(members)},
                in_members=[(f"{pre}.mamba.in_proj_x", 1),
                            (f"{pre}.mamba.in_proj_z", 1)],
                resid_members=[(f"{pre}.mamba.out_proj", 2)],
                after=f"{pre}.norm1")
            for w in _QUANT_WEIGHTS["mamba"]:
                gb.attach_weight_quant(vid, f"{pre}.mamba.{w}.wq",
                                       target_param=f"{pre}.mamba.{w}")
            return vid
        # rwkv time-mix: heads are the removable unit
        dh = cfg.rwkv.head_size
        H = cfg.d_model // dh
        members = [(f"{pre}.rwkv.wr", 2, dh), (f"{pre}.rwkv.wk", 2, dh),
                   (f"{pre}.rwkv.wv", 2, dh), (f"{pre}.rwkv.wg", 2, dh),
                   (f"{pre}.rwkv.wo", 1, dh),
                   (f"{pre}.rwkv.decay_w2", 2, dh),
                   (f"{pre}.rwkv.decay_w0", 1, dh), (f"{pre}.rwkv.u", 1, dh),
                   (f"{pre}.rwkv.lnx_scale", 1, dh),
                   (f"{pre}.rwkv.lnx_bias", 1, dh)]
        spec = FamilySpec(name=f"{pre}.rwkv.heads", units=H, members=members,
                          kind="head_group")
        vid = gb.composite(
            f"{pre}.rwkv", "rwkv_timemix", spec,
            params={f"p{i}": m[0] for i, m in enumerate(members)},
            in_members=[(f"{pre}.rwkv.wr", 1), (f"{pre}.rwkv.wk", 1),
                        (f"{pre}.rwkv.wv", 1), (f"{pre}.rwkv.wg", 1),
                        (f"{pre}.rwkv.decay_w1", 1)],
            resid_members=[(f"{pre}.rwkv.wo", 2)],
            after=f"{pre}.norm1")
        for w in _QUANT_WEIGHTS["rwkv"]:
            gb.attach_weight_quant(vid, f"{pre}.rwkv.{w}.wq",
                                   target_param=f"{pre}.rwkv.{w}")
        return vid

    def _graph_ffn(self, gb: GraphBuilder, sub: SubLayer, pre: str,
                   act_quant: bool) -> str:
        cfg = self.cfg
        if sub.ffn == "mlp":
            # gate/up produce the hidden space (tied via the product),
            # down consumes it — expressed with generic vertices so the
            # dependency analysis (and inserted act-quant branches) apply.
            g = gb.linear(f"{pre}.mlp.gate", f"{pre}.mlp.w_gate",
                          out_dim=cfg.d_ff, in_axis=1, out_axis=2,
                          after=f"{pre}.norm2")
            u = gb.linear(f"{pre}.mlp.up", f"{pre}.mlp.w_up",
                          out_dim=cfg.d_ff, in_axis=1, out_axis=2,
                          after=f"{pre}.norm2")
            m = gb.add(f"{pre}.mlp.prod", [g, u])
            a = gb.act(f"{pre}.mlp.silu", after=m)
            dn = gb.linear(f"{pre}.mlp.down", f"{pre}.mlp.w_down",
                           in_axis=1, out_axis=2, out_dim=cfg.d_model,
                           non_prunable=True, after=a)
            for w in ("gate", "up", "down"):
                gb.attach_weight_quant(f"{pre}.mlp.{w}",
                                       f"{pre}.mlp.w_{w}.wq")
            if act_quant:
                gb.insert_act_quant(a, dn, f"{pre}.mlp.mlp_act.aq")
            return dn
        if sub.ffn == "moe":
            E = cfg.moe.n_experts
            members = [(f"{pre}.moe.router", 2, 1),
                       (f"{pre}.moe.we_gate", 1, 1),
                       (f"{pre}.moe.we_up", 1, 1),
                       (f"{pre}.moe.we_down", 1, 1)]
            spec = FamilySpec(name=f"{pre}.moe.experts", units=E,
                              members=members, kind="expert")
            in_m = [(f"{pre}.moe.router", 1), (f"{pre}.moe.we_gate", 2),
                    (f"{pre}.moe.we_up", 2)]
            res_m = [(f"{pre}.moe.we_down", 3)]
            if cfg.moe.shared_expert:
                in_m += [(f"{pre}.moe.shared.w_gate", 1),
                         (f"{pre}.moe.shared.w_up", 1)]
                res_m += [(f"{pre}.moe.shared.w_down", 2)]
            vid = gb.composite(
                f"{pre}.moe", "moe", spec,
                params={f"p{i}": m[0] for i, m in enumerate(members)},
                in_members=in_m, resid_members=res_m, after=f"{pre}.norm2")
            for w in _QUANT_WEIGHTS["moe"]:
                gb.attach_weight_quant(vid, f"{pre}.moe.{w}.wq",
                                       target_param=f"{pre}.moe.{w}")
            return vid
        # rwkv channel-mix: hidden channels family
        members = [(f"{pre}.rwkv.cm_k", 2, 1), (f"{pre}.rwkv.cm_v", 1, 1)]
        spec = FamilySpec(name=f"{pre}.rwkv.cm_hidden", units=cfg.d_ff,
                          members=members, kind="channel")
        vid = gb.composite(
            f"{pre}.rwkv.cm", "rwkv_chanmix", spec,
            params={f"p{i}": m[0] for i, m in enumerate(members)},
            in_members=[(f"{pre}.rwkv.cm_k", 1), (f"{pre}.rwkv.cm_r", 1)],
            resid_members=[(f"{pre}.rwkv.cm_v", 2), (f"{pre}.rwkv.cm_r", 2)],
            after=f"{pre}.norm2")
        for w in _QUANT_WEIGHTS["chanmix"]:
            gb.attach_weight_quant(vid, f"{pre}.rwkv.{w}.wq",
                                   target_param=f"{pre}.rwkv.{w}")
        return vid

"""CNN family (VGG7, ResNet20/56) — the paper's own experiment substrate.

Unlike the stacked LM zoo, CNNs are built *per-layer* (no scan): every conv
gets its own trace-graph vertex, attached weight-quant branch, optional
inserted act-quant branch, per-layer (d, q_m, t) site and per-layer pruning
families — the full-fidelity GETA path used to reproduce Tables 2/4/5 and
the Fig 4 ablations on synthetic data.

Layout: NHWC activations, HWIO weights. BatchNorm uses batch statistics
(training mode; the paper trains CIFAR nets from scratch).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bops import LayerMacs
from repro.core.graph import GraphBuilder
from repro.core.quant import QuantParams, fake_quant, init_quant_params


def conv2d(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * scale + bias


def maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


def _qw(params, qparams, name):
    w = params[name]
    site = name + ".wq"
    if qparams is not None and site in qparams:
        qp = qparams[site]
        w = fake_quant(w, qp.d, qp.q_m, qp.t)
    return w


def _qa(x, qparams, site):
    if qparams is not None and site in qparams:
        qp = qparams[site]
        x = fake_quant(x, qp.d, qp.q_m, qp.t)
    return x


@dataclasses.dataclass
class CNNSpec:
    name: str
    kind: str                 # "vgg" | "resnet"
    widths: list              # vgg: conv widths; resnet: stage widths
    blocks_per_stage: int = 3  # resnet
    fc_dim: int = 1024         # vgg classifier hidden
    num_classes: int = 10
    in_hw: int = 32


VGG7 = CNNSpec("vgg7", "vgg", [128, 128, 256, 256, 512, 512])
RESNET20 = CNNSpec("resnet20", "resnet", [16, 32, 64], blocks_per_stage=3)
RESNET56 = CNNSpec("resnet56", "resnet", [16, 32, 64], blocks_per_stage=9)


class CNN:
    def __init__(self, spec: CNNSpec):
        self.spec = spec
        self._plan = self._build_plan()

    # ------------------------------------------------------------- plan
    def _build_plan(self):
        """List of op dicts; shared by init / apply / graph / macs."""
        s = self.spec
        plan = []
        if s.kind == "vgg":
            cin, hw = 3, s.in_hw
            for i, w in enumerate(s.widths):
                plan.append(dict(op="conv", name=f"conv{i}", cin=cin, cout=w,
                                 k=3, stride=1, hw=hw))
                plan.append(dict(op="bn", name=f"bn{i}", c=w))
                plan.append(dict(op="relu", name=f"relu{i}"))
                if i % 2 == 1:
                    plan.append(dict(op="pool", name=f"pool{i}"))
                    hw //= 2
                cin = w
            plan.append(dict(op="flatten", name="flatten",
                             factor=hw * hw))
            plan.append(dict(op="fc", name="fc0", cin=cin * hw * hw,
                             cout=s.fc_dim))
            plan.append(dict(op="relu", name="fc0.relu"))
            plan.append(dict(op="fc", name="fc1", cin=s.fc_dim,
                             cout=s.num_classes, final=True))
        else:  # resnet (CIFAR style: 3 stages)
            hw = s.in_hw
            plan.append(dict(op="conv", name="stem", cin=3, cout=s.widths[0],
                             k=3, stride=1, hw=hw))
            plan.append(dict(op="bn", name="stem.bn", c=s.widths[0]))
            plan.append(dict(op="relu", name="stem.relu"))
            cin = s.widths[0]
            for st, w in enumerate(s.widths):
                for b in range(s.blocks_per_stage):
                    stride = 2 if (st > 0 and b == 0) else 1
                    if stride == 2:
                        hw //= 2
                    pre = f"s{st}b{b}"
                    plan.append(dict(op="block", name=pre, cin=cin, cout=w,
                                     stride=stride, hw=hw,
                                     proj=(cin != w or stride != 1)))
                    cin = w
            plan.append(dict(op="gap", name="gap"))
            plan.append(dict(op="fc", name="fc", cin=cin,
                             cout=s.num_classes, final=True))
        return plan

    # ------------------------------------------------------------- params
    def init(self, key) -> dict:
        params = {}
        ks = iter(jax.random.split(key, 256))

        def conv_init(name, k, cin, cout):
            std = (k * k * cin) ** -0.5
            params[f"{name}.w"] = jax.random.normal(
                next(ks), (k, k, cin, cout)) * std

        def bn_init(name, c):
            params[f"{name}.scale"] = jnp.ones((c,))
            params[f"{name}.bias"] = jnp.zeros((c,))

        for item in self._plan:
            if item["op"] == "conv":
                conv_init(item["name"], item["k"], item["cin"], item["cout"])
            elif item["op"] == "bn":
                bn_init(item["name"], item["c"])
            elif item["op"] == "fc":
                std = item["cin"] ** -0.5
                params[f"{item['name']}.w"] = jax.random.normal(
                    next(ks), (item["cin"], item["cout"])) * std
                params[f"{item['name']}.b"] = jnp.zeros((item["cout"],))
            elif item["op"] == "block":
                n, cin, cout = item["name"], item["cin"], item["cout"]
                conv_init(f"{n}.conv1", 3, cin, cout)
                bn_init(f"{n}.bn1", cout)
                conv_init(f"{n}.conv2", 3, cout, cout)
                bn_init(f"{n}.bn2", cout)
                if item["proj"]:
                    conv_init(f"{n}.proj", 1, cin, cout)
                    bn_init(f"{n}.bn_proj", cout)
        return params

    # -------------------------------------------------------------- apply
    def apply(self, params, qparams, x):
        for item in self._plan:
            op, n = item["op"], item["name"]
            if op == "conv":
                x = conv2d(x, _qw(params, qparams, f"{n}.w"),
                           stride=item.get("stride", 1))
            elif op == "bn":
                x = batchnorm(x, params[f"{n}.scale"], params[f"{n}.bias"])
            elif op == "relu":
                x = jax.nn.relu(x)
                x = _qa(x, qparams, f"{n}.aq")
            elif op == "pool":
                x = maxpool(x)
            elif op == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif op == "gap":
                x = jnp.mean(x, axis=(1, 2))
            elif op == "fc":
                x = x @ _qw(params, qparams, f"{n}.w") + params[f"{n}.b"]
                if not item.get("final"):
                    pass
            elif op == "block":
                sc = x
                h = conv2d(x, _qw(params, qparams, f"{n}.conv1.w"),
                           stride=item["stride"])
                h = batchnorm(h, params[f"{n}.bn1.scale"],
                              params[f"{n}.bn1.bias"])
                h = jax.nn.relu(h)
                h = _qa(h, qparams, f"{n}.relu1.aq")
                h = conv2d(h, _qw(params, qparams, f"{n}.conv2.w"))
                h = batchnorm(h, params[f"{n}.bn2.scale"],
                              params[f"{n}.bn2.bias"])
                if item["proj"]:
                    sc = conv2d(sc, _qw(params, qparams, f"{n}.proj.w"),
                                stride=item["stride"])
                    sc = batchnorm(sc, params[f"{n}.bn_proj.scale"],
                                   params[f"{n}.bn_proj.bias"])
                x = jax.nn.relu(h + sc)
                x = _qa(x, qparams, f"{n}.out.aq")
        return x

    def loss(self, params, qparams, batch):
        logits = self.apply(params, qparams, batch["images"])
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    def accuracy(self, params, qparams, batch):
        logits = self.apply(params, qparams, batch["images"])
        return jnp.mean(jnp.argmax(logits, -1) == batch["labels"])

    # -------------------------------------------------------------- graph
    def build_graph(self, act_quant: bool = False) -> GraphBuilder:
        gb = GraphBuilder()
        gb.input("in")
        pending_act = None     # last relu vertex awaiting act-quant insertion

        def wire_act_quant(consumer_vid):
            # the inserted branch goes between the activation and its
            # *immediate* consumer (paper Fig 2b)
            nonlocal pending_act
            if act_quant and pending_act is not None:
                gb.insert_act_quant(pending_act, consumer_vid,
                                    f"{pending_act}.aq")
            pending_act = None

        for item in self._plan:
            op, n = item["op"], item["name"]
            if op == "conv":
                vid = gb.conv(n, f"{n}.w", out_dim=item["cout"])
                wire_act_quant(vid)
                gb.attach_weight_quant(n, f"{n}.w.wq")
            elif op == "bn":
                gb.bn(n, f"{n}.scale", f"{n}.bias")
            elif op == "relu":
                gb.act(n)
                pending_act = n
            elif op == "pool":
                gb.pool(n)
                wire_act_quant(n)
            elif op == "flatten":
                gb.pool(n, flatten_factor=item["factor"],
                        flatten_layout="interleaved")
                wire_act_quant(n)
            elif op == "gap":
                gb.pool(n)
                wire_act_quant(n)
            elif op == "fc":
                vid = gb.linear(n, f"{n}.w", bias=f"{n}.b",
                                out_dim=item["cout"],
                                non_prunable=item.get("final", False))
                wire_act_quant(vid)
                gb.attach_weight_quant(n, f"{n}.w.wq")
            elif op == "block":
                entry = gb._last
                c1 = gb.conv(f"{n}.conv1", f"{n}.conv1.w",
                             out_dim=item["cout"], after=entry)
                wire_act_quant(c1)
                gb.attach_weight_quant(c1, f"{n}.conv1.w.wq")
                gb.bn(f"{n}.bn1", f"{n}.bn1.scale", f"{n}.bn1.bias")
                r1 = gb.act(f"{n}.relu1")
                c2 = gb.conv(f"{n}.conv2", f"{n}.conv2.w",
                             out_dim=item["cout"], after=r1)
                if act_quant:
                    gb.insert_act_quant(r1, c2, f"{n}.relu1.aq")
                gb.attach_weight_quant(c2, f"{n}.conv2.w.wq")
                b2 = gb.bn(f"{n}.bn2", f"{n}.bn2.scale", f"{n}.bn2.bias")
                if item["proj"]:
                    pj = gb.conv(f"{n}.proj", f"{n}.proj.w",
                                 out_dim=item["cout"], after=entry)
                    gb.attach_weight_quant(pj, f"{n}.proj.w.wq")
                    bp = gb.bn(f"{n}.bn_proj", f"{n}.bn_proj.scale",
                               f"{n}.bn_proj.bias", after=pj)
                    sc = bp
                else:
                    sc = entry
                ad = gb.add(f"{n}.add", [b2, sc])
                gb.act(f"{n}.out", after=ad)
                pending_act = f"{n}.out"
        gb.output("out")
        return gb

    # ------------------------------------------------------------- quant
    def quant_weight_names(self) -> list[str]:
        names = []
        for item in self._plan:
            op, n = item["op"], item["name"]
            if op in ("conv", "fc"):
                names.append(f"{n}.w")
            elif op == "block":
                names += [f"{n}.conv1.w", f"{n}.conv2.w"]
                if item["proj"]:
                    names.append(f"{n}.proj.w")
        return names

    def init_qparams(self, params, bits_init=32.0, act_quant=False):
        qp = {}
        for name in self.quant_weight_names():
            qp[name + ".wq"] = init_quant_params(params[name],
                                                 bits=bits_init)
        if act_quant:
            for item in self._plan:
                op, n = item["op"], item["name"]
                if op == "relu":
                    qp[f"{n}.aq"] = init_quant_params(q_m=4.0,
                                                      bits=bits_init)
                elif op == "block":
                    qp[f"{n}.relu1.aq"] = init_quant_params(q_m=4.0,
                                                            bits=bits_init)
                    qp[f"{n}.out.aq"] = init_quant_params(q_m=4.0,
                                                          bits=bits_init)
        return qp

    # -------------------------------------------------------------- bops
    def layer_macs(self, batch: int = 1) -> list[LayerMacs]:
        out = []
        for item in self._plan:
            op, n = item["op"], item["name"]
            if op == "conv":
                hw = item["hw"] // item.get("stride", 1)
                out.append(LayerMacs(
                    n, float(batch) * hw * hw * item["k"] ** 2
                    * item["cin"] * item["cout"], f"{n}.w"))
            elif op == "fc":
                out.append(LayerMacs(n, float(batch) * item["cin"]
                                     * item["cout"], f"{n}.w"))
            elif op == "block":
                hw = item["hw"]
                cin, cout = item["cin"], item["cout"]
                out.append(LayerMacs(f"{n}.conv1", float(batch) * hw * hw
                                     * 9 * cin * cout, f"{n}.conv1.w"))
                out.append(LayerMacs(f"{n}.conv2", float(batch) * hw * hw
                                     * 9 * cout * cout, f"{n}.conv2.w"))
                if item["proj"]:
                    out.append(LayerMacs(f"{n}.proj", float(batch) * hw * hw
                                         * cin * cout, f"{n}.proj.w"))
        return out

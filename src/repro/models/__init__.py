from repro.models.bert import BertEncoder
from repro.models.cnn import CNN, RESNET20, RESNET56, VGG7, CNNSpec
from repro.models.transformer import LM, layer_plan

"""Composable model layers (pure functions over a flat param dict).

Conventions:
- params: flat dict[str, jax.Array]; stacked layer tensors carry a leading
  (L,) axis and are consumed by lax.scan over the layer stack.
- qparams: dict[str, QuantParams]; weight sites are applied with
  `qw(params, qparams, name)` — fake-quant if a site exists, pass-through
  otherwise (so the same model code serves QAT and vanilla training).
- activations in cfg.dtype (bf16 default); softmax/norm/SSM state in f32.
- every init_* returns (params, axes) where axes maps each param to a tuple
  of *logical* axis names consumed by repro.distributed.sharding.
  Those names also drive tensor-parallel *serving* (DESIGN.md §4.12):
  `make_plan(mode="tp")` shards the head / mlp / vocab axes over the mesh's
  "model" axis, and `serving_axes_for` extends the mapping to the derived
  `.codes` / `.packed{bits}` / `.scale` leaves compressed serving adds —
  layer code never changes, GSPMD partitions the same einsums.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import os
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant import PACKED_STORAGE_BITS, QuantParams, fake_quant
from repro.kernels import ops as Kops

Dtype = Any

# Route Dense/attention projections through the kernel dispatch layer
# (repro.kernels.dispatch: pallas-tpu on TPU, xla-ref elsewhere, per-call
# override). Flag-gated, default on; `set_kernel_dispatch(False)` restores
# the plain `x @ qw(...)` composition. See DESIGN.md §4.
_KERNEL_DISPATCH = {"enabled": True}

# Components whose 2-D weights execute through `dense_proj` (and therefore
# can consume `<name>.codes` / fuse their `.wq` quantizer into the GEMM).
# Single source of truth for transformer._prequantize and core.subnet.
ROUTED_COMPONENTS = ("attn", "mlp", "mamba", "rwkv", "shared")

# Sub-byte packed weights ride the param dict as `<name>.packed{bits}`
# (int32 word stream, K-packed) + `<name>.scale`: the storage width lives
# in the *key*, so it stays a static value through jit while the words
# scan-stack over the layer axis exactly like the dense tensor did.
# Derived from the producer's width set (`compress_lm` emits exactly these
# suffixes via `packed_storage_bits`) so the two can't drift.
PACKED_PARAM_BITS = tuple(sorted(PACKED_STORAGE_BITS, reverse=True))


@dataclasses.dataclass(frozen=True)
class LayerShapes:
    """Physical dims one sublayer executes at.

    The global `ModelConfig` states the *architecture*; a pruned subnet
    executes at *smaller* per-sublayer widths (surviving kv-head groups,
    MLP hidden units, experts, mamba channels, rwkv heads). Every apply
    below reshapes/derives against these dims, so the same layer code
    serves the dense model (`LayerShapes.from_config`) and a physically
    sliced one (`core.subnet.derive_slim_plan`). `d_model` is the residual
    width — non-prunable in every LM graph (embed/head pin it), carried
    anyway so the invariant is explicit.
    """
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    n_experts: int = 0
    mamba_inner: int = 0
    rwkv_heads: int = 0
    cm_hidden: int = 0

    @classmethod
    def from_config(cls, cfg: ModelConfig) -> "LayerShapes":
        return cls(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head,
            d_ff=cfg.d_ff,
            n_experts=cfg.moe.n_experts if cfg.moe else 0,
            mamba_inner=cfg.mamba.expand * cfg.d_model if cfg.mamba else 0,
            rwkv_heads=(cfg.d_model // cfg.rwkv.head_size) if cfg.rwkv else 0,
            cm_hidden=cfg.d_ff,
        )


def set_kernel_dispatch(enabled: bool) -> None:
    _KERNEL_DISPATCH["enabled"] = bool(enabled)


def kernel_dispatch_enabled() -> bool:
    return _KERNEL_DISPATCH["enabled"]


# Fused flash-decode attention (kernels/decode_attn.py) on the single-query
# decode branch. Default on; `set_decode_attn(False)` (or
# REPRO_DECODE_ATTN=0) restores the full-length einsum+softmax composition.
# The op dispatches like every other kernel (pallas-tpu on TPU, the
# bit-identical xla-ref oracle elsewhere), so flipping the flag on a CPU
# host changes which *code path* runs, not the tokens. See DESIGN.md §4.9.
_DECODE_ATTN = {"enabled": os.environ.get("REPRO_DECODE_ATTN", "1") != "0"}


def set_decode_attn(enabled: bool) -> None:
    _DECODE_ATTN["enabled"] = bool(enabled)


def decode_attn_enabled() -> bool:
    return _DECODE_ATTN["enabled"]


@contextlib.contextmanager
def use_decode_attn(enabled: bool):
    """Scoped flag flip (tests / parity smokes / benchmarks)."""
    prev = _DECODE_ATTN["enabled"]
    _DECODE_ATTN["enabled"] = bool(enabled)
    try:
        yield
    finally:
        _DECODE_ATTN["enabled"] = prev

# Optional NamedSharding for decode attention scores (B, KV, g, 1, S).
# When the KV cache is d_head-sharded (GQA kv-heads don't divide the model
# axis), XLA's default strategy re-gathers the whole cache per step
# ('involuntary full rematerialization'); pinning the score sharding makes
# it contract d_head locally and psum the (small) partial scores instead.
# Set by launch/dryrun (serve_attn='psum'); None = compiler's choice.
DECODE_SCORE_SHARDING = None


@dataclasses.dataclass(frozen=True)
class PagedView:
    """How one decode step addresses the paged KV pool (DESIGN.md §4.11).

    `table` is the (B, Lp) logical->physical page map (traced; rebuilt
    inside each jitted step from the engine's host table). The rest is
    static geometry: `page_size` rows per page, `seq_len` the *logical*
    arena length — attention masks/slices to exactly this many rows so
    an unquantized paged decode is bitwise the contiguous arena's —
    and `kv_bits` (None | 8 | 4) selecting the quantized page store.
    """
    table: jax.Array
    page_size: int
    seq_len: int
    kv_bits: Optional[int] = None


def _dt(cfg: ModelConfig) -> Dtype:
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def qw(params: dict, qparams: Optional[dict], name: str) -> jax.Array:
    """Weight fetch through the (optional) parameterized quantizer."""
    w = params[name]
    site = name + ".wq"
    if qparams is not None and site in qparams:
        qp: QuantParams = qparams[site]
        w = fake_quant(w, qp.d, qp.q_m, qp.t)
    return w


def qa(x: jax.Array, qparams: Optional[dict], site: str) -> jax.Array:
    """Activation pass through the (optional) parameterized quantizer."""
    if qparams is not None and site in qparams:
        qp: QuantParams = qparams[site]
        x = fake_quant(x, qp.d, qp.q_m, qp.t)
    return x


def dense_proj(x: jax.Array, lp: dict, qp: Optional[dict], name: str, *,
               mask: Optional[jax.Array] = None,
               backend: Optional[str] = None) -> jax.Array:
    """Dense projection x @ (fake_quant(w) * mask), kernel-dispatch routed.

    One entry point for every 2-D weight projection:
    - dense weight, no quant site      -> matmul_op
    - dense weight + weight-quant site -> fq_matmul_op (fused fake-quant
      epilogue: one HBM pass of W instead of quantize -> matmul)
    - + column mask (GETA joint stage) -> fq_masked_matmul_op /
      masked_matmul_op (mask fused into the RHS tile load)
    - int codes (`<name>.codes` / `<name>.scale` from a compressed Subnet)
      -> quant_matmul_op (dequant inside VMEM; the serving path)
    - packed sub-byte codes (`<name>.packed{bits}` + `<name>.scale`)
      -> packed_quant_matmul_op (unpack-dequant inside VMEM; int32 words
      at 32//bits codes each stream from HBM — the `--packed` path)

    A column mask may also ride the param dict as `<name>.colmask` so it
    stacks over the layer axis and scans with the block body.
    """
    codes = lp.get(name + ".codes")
    if mask is None:
        mask = lp.get(name + ".colmask")
    site = name + ".wq"
    qpw: Optional[QuantParams] = qp.get(site) if qp is not None else None

    for pbits in PACKED_PARAM_BITS:
        packed = lp.get(f"{name}.packed{pbits}")
        if packed is not None:
            scale = jnp.asarray(lp[name + ".scale"], jnp.float32)
            if scale.ndim == 0:
                scale = jnp.broadcast_to(scale, (packed.shape[-1],))
            x2 = x.reshape(-1, x.shape[-1])
            y = Kops.packed_quant_matmul_op(x2, packed, pbits, scale,
                                            backend=backend)
            return y.reshape(*x.shape[:-1], packed.shape[-1])

    if codes is not None:
        scale = jnp.asarray(lp[name + ".scale"], jnp.float32)
        if scale.ndim == 0:
            scale = jnp.broadcast_to(scale, (codes.shape[-1],))
        x2 = x.reshape(-1, x.shape[-1])
        y = Kops.quant_matmul_op(x2, codes, scale, backend=backend)
        return y.reshape(*x.shape[:-1], codes.shape[-1])

    w = lp[name]
    if not kernel_dispatch_enabled() or w.ndim != 2 \
            or (qpw is None and mask is None):
        # plain dense (or flag off): XLA's native dot is the fastest
        # correct path and — unlike an opaque pallas_call — partitions
        # under GSPMD. The kernels only earn their keep when there is an
        # epilogue to fuse.
        if qpw is not None:
            w = fake_quant(w, qpw.d, qpw.q_m, qpw.t)
        if mask is not None:
            w = w * mask.astype(w.dtype)[None, :]
        return x @ w

    x2 = x.reshape(-1, x.shape[-1])
    if qpw is not None and mask is not None:
        y = Kops.fq_masked_matmul_op(x2, w, mask, qpw.d, qpw.q_m, qpw.t,
                                     backend=backend)
    elif qpw is not None:
        y = Kops.fq_matmul_op(x2, w, qpw.d, qpw.q_m, qpw.t, backend=backend)
    else:
        y = Kops.masked_matmul_op(x2, w, mask, backend=backend)
    return y.reshape(*x.shape[:-1], w.shape[-1])


# ------------------------------------------------------------------ norms
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(x: jax.Array, scale: jax.Array, bias: jax.Array,
                    n_heads: int, eps: float = 1e-5) -> jax.Array:
    """Per-head groupnorm (RWKV ln_x). x: (..., H*dh)."""
    shp = x.shape
    x32 = x.astype(jnp.float32).reshape(*shp[:-1], n_heads, -1)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(shp)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- rope
def rope_tables(seq_len: int, d_head: int, theta: float,
                offset: int = 0) -> tuple[jax.Array, jax.Array]:
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)
    freqs = theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    ang = pos[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, dh); cos/sin: (S, dh/2), or (B, S, dh/2) when every
    sequence sits at its own absolute position (per-slot decode)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 3:
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    else:
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# -------------------------------------------------------------- attention
def _causal_mask(sq: int, sk: int, q_off: int, window: int) -> jax.Array:
    qi = jnp.arange(sq)[:, None] + q_off
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window > 0:
        m = jnp.logical_and(m, ki > qi - window)
    return m


def attention_dense(q, k, v, *, window: int = 0, q_offset: int = 0,
                    causal: bool = True):
    """Full materialized attention (exact; used when S is modest).

    q: (B, Sq, H, dh); k/v: (B, Sk, KV, dh) — GQA handled by reshape.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qh = q.reshape(B, Sq, KV, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    if causal:
        mask = _causal_mask(Sq, k.shape[1], q_offset, window)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def attention_blockwise(q, k, v, *, block: int = 1024, window: int = 0):
    """Flash-style online-softmax attention (never materializes S x S).

    Outer scan over query blocks; inner scan over KV blocks with running
    (max, denom, acc). Exact (same math as attention_dense).

    Both loop bodies are jax.checkpoint'ed so the backward pass *recomputes*
    the block scores instead of saving them — without this, the scan VJPs
    persist every (q-block x kv-block) score tile simultaneously during the
    layer backward (measured +17 GB/device at 4k seq on internlm2-1.8b).
    """
    B, S, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    nb = S // block
    assert S % block == 0, (S, block)
    qb = q.reshape(B, nb, block, KV, g, dh)
    kb = k.reshape(B, nb, block, KV, dh)
    vb = v.reshape(B, nb, block, KV, dh)

    def q_block(qi, q_i):
        # q_i: (B, block, KV, g, dh)
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_j, v_j = inp
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) / math.sqrt(dh)
            mask = _causal_mask(block, block, (qi - ki) * block, window)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_j.astype(jnp.float32))
            # skip fully-masked future blocks (they contribute zeros anyway,
            # masked by -1e30 -> exp ~ 0)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, g, block), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, g, block), jnp.float32)
        a0 = jnp.zeros((B, KV, g, block, dh), jnp.float32)
        ks = jnp.arange(nb)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (ks, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1)  # (B, block, KV, g, dh)

    outs = jax.lax.map(jax.checkpoint(lambda args: q_block(*args)),
                       (jnp.arange(nb), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, dh)
    return out.astype(q.dtype)


def attention(q, k, v, cfg: ModelConfig, *, window: int = 0,
              q_offset: int = 0):
    S = q.shape[1]
    if S > cfg.attn_block_threshold and S % cfg.attn_block_size == 0 \
            and q.shape[1] == k.shape[1]:
        return attention_blockwise(q, k, v, block=cfg.attn_block_size,
                                   window=window)
    return attention_dense(q, k, v, window=window, q_offset=q_offset)


# --------------------------------------------------------- attention block
def init_attention(key, cfg: ModelConfig, prefix: str, n_layers: int,
                   dtype) -> tuple[dict, dict]:
    D, Q, KVd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = D ** -0.5
    L = (n_layers,) if n_layers else ()
    lax_ = ("layers",) if n_layers else ()
    p = {
        f"{prefix}.wq": jax.random.normal(k1, L + (D, Q), dtype) * std,
        f"{prefix}.wk": jax.random.normal(k2, L + (D, KVd), dtype) * std,
        f"{prefix}.wv": jax.random.normal(k3, L + (D, KVd), dtype) * std,
        f"{prefix}.wo": jax.random.normal(k4, L + (Q, D), dtype) * std,
    }
    axes = {
        f"{prefix}.wq": lax_ + ("embed", "q_heads"),
        f"{prefix}.wk": lax_ + ("embed", "kv_heads"),
        f"{prefix}.wv": lax_ + ("embed", "kv_heads"),
        f"{prefix}.wo": lax_ + ("q_heads", "embed"),
    }
    if cfg.qkv_bias:
        p[f"{prefix}.bq"] = jnp.zeros(L + (Q,), dtype)
        p[f"{prefix}.bk"] = jnp.zeros(L + (KVd,), dtype)
        p[f"{prefix}.bv"] = jnp.zeros(L + (KVd,), dtype)
        axes[f"{prefix}.bq"] = lax_ + ("q_heads",)
        axes[f"{prefix}.bk"] = lax_ + ("kv_heads",)
        axes[f"{prefix}.bv"] = lax_ + ("kv_heads",)
    return p, axes


def attn_apply(lp: dict, qp: Optional[dict], cfg: ModelConfig, x, *,
               rope: tuple, window: int = 0, prefix: str,
               cache: Optional[tuple] = None, q_offset: int = 0,
               shapes: Optional[LayerShapes] = None, chunked: bool = False,
               pages: Optional[PagedView] = None):
    """lp: per-layer (unstacked) params view. cache: (k_cache, v_cache,
    write_pos) for decode. `shapes` carries this sublayer's physical dims
    (pruned subnets run fewer heads than the config states); default is
    the dense config. `chunked` scores an S-token chunk mid-sequence
    against the live cache (the speculative verify pass) instead of
    treating S > 1 as a from-scratch prefill. With `pages`, the decode
    branch treats the cache k/v as paged *pools* ((n_pages, P, KVh, dh*)
    + optional per-row scale planes appended to the cache tuple) and
    scatter-writes / page-gathers through the view's table instead of
    row-indexing a per-slot arena. Returns (out, new_cache)."""
    B, S, D = x.shape
    shapes = shapes or LayerShapes.from_config(cfg)
    H, KVh, dh = shapes.n_heads, shapes.n_kv_heads, shapes.d_head
    q = dense_proj(x, lp, qp, f"{prefix}.wq")
    k = dense_proj(x, lp, qp, f"{prefix}.wk")
    v = dense_proj(x, lp, qp, f"{prefix}.wv")
    if cfg.qkv_bias:
        q = q + lp[f"{prefix}.bq"]
        k = k + lp[f"{prefix}.bk"]
        v = v + lp[f"{prefix}.bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, KVh, dh)
    v = v.reshape(B, S, KVh, dh)
    cos, sin = rope
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None and chunked:
        # chunked verify (speculative decoding): append S contiguous rows
        # at each slot's own position and attend all S queries over the
        # arena at once. Query i sits at absolute position pos[b]+i, so it
        # sees arena rows [0, pos[b]+i] — the causal prefix including the
        # rows this very chunk just wrote. Full arenas only: a ring write
        # can overwrite pre-wrap rows, which a rejection could then never
        # roll back (the engine gates speculation on window == 0).
        if window > 0:
            raise ValueError(
                f"{prefix}: chunked cache scoring needs a full (non-ring) "
                f"arena; window={window} layers overwrite rows on wrap")
        ck, cv, pos = cache
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
        row_upd = lambda c, u, s: jax.lax.dynamic_update_slice(
            c, u, (s, 0, 0))
        ck = jax.vmap(row_upd)(ck, k.astype(ck.dtype), pos)
        cv = jax.vmap(row_upd)(cv, v.astype(cv.dtype), pos)
        g = H // KVh
        qh = q.reshape(B, S, KVh, g, dh)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qh.astype(jnp.float32),
                            ck.astype(jnp.float32)) / math.sqrt(dh)
        valid = (jnp.arange(ck.shape[1])[None, None, :]
                 <= pos[:, None, None] + jnp.arange(S)[None, :, None])
        scores = jnp.where(valid[:, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cv.astype(jnp.float32))
        out = out.reshape(B, S, H * dh).astype(x.dtype)
        out = qa(out, qp, f"{prefix}.attn_out.aq")
        return dense_proj(out, lp, qp, f"{prefix}.wo"), (ck, cv, pos + S)
    if cache is not None and S > 1:
        # one-shot prefill: write the whole prompt's K/V at positions
        # [0, S) in a single slice update and attend causally over the
        # prompt itself — no cache read, so a fresh (zeroed) cache row is
        # required. Windowed layers ring-wrap per token; a one-shot write
        # is only position-faithful while the prompt fits the ring.
        ck, cv, pos = cache
        assert window <= 0 or S <= ck.shape[1], (S, ck.shape[1])
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, 0, 0, 0))
        out = attention(q, k, v, cfg, window=window, q_offset=0)
        out = out.reshape(B, S, H * dh)
        out = qa(out, qp, f"{prefix}.attn_out.aq")
        return dense_proj(out, lp, qp, f"{prefix}.wo"), (ck, cv, pos + S)
    if cache is not None and pages is not None:
        # paged decode: the cache tuple holds shared *pools* — scatter the
        # token's K/V row at its slot's physical row (page_table[pos // P]
        # * P + pos % P) and attend through the page-indirect kernel.
        # Idle slots' tables point every logical page at the reserved
        # trash page, so their (discarded) writes can't touch live pages.
        if window > 0:
            raise ValueError(
                f"{prefix}: the paged arena needs full (non-ring) caches; "
                f"window={window} layers ring-wrap rows")
        if len(cache) == 5:
            ck, cv, pos, ksc, vsc = cache
        else:
            (ck, cv, pos), ksc, vsc = cache, None, None
        P = pages.page_size
        n_pages = ck.shape[0]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
        phys = jnp.take_along_axis(pages.table, (pos // P)[:, None],
                                   axis=1)[:, 0] * P + pos % P      # (B,)
        rowk, rowv = k[:, 0], v[:, 0]                     # (B, KVh, dh)
        if pages.kv_bits is not None:
            from repro.core.quant import kv_quant_encode
            rowk, rsk = kv_quant_encode(rowk, pages.kv_bits)
            rowv, rsv = kv_quant_encode(rowv, pages.kv_bits)
            ksc = ksc.reshape(n_pages * P, KVh).at[phys].set(rsk).reshape(
                ksc.shape)
            vsc = vsc.reshape(n_pages * P, KVh).at[phys].set(rsv).reshape(
                vsc.shape)
        flat = (n_pages * P,) + ck.shape[2:]
        ck = ck.reshape(flat).at[phys].set(rowk.astype(ck.dtype)).reshape(
            ck.shape)
        cv = cv.reshape(flat).at[phys].set(rowv.astype(cv.dtype)).reshape(
            cv.shape)
        g = H // KVh
        use_kernel = (_DECODE_ATTN["enabled"] and _KERNEL_DISPATCH["enabled"]
                      and DECODE_SCORE_SHARDING is None)
        out = Kops.paged_decode_attn_op(
            q.reshape(B, KVh, g, dh), ck, cv, pos, pages.table,
            page_size=P, seq_len=pages.seq_len, kv_bits=pages.kv_bits,
            k_scale=ksc, v_scale=vsc, window=window,
            backend=(None if use_kernel else "xla-ref"))
        out = out.reshape(B, 1, H, dh).astype(x.dtype)
        out = out.reshape(B, S, H * dh)
        out = qa(out, qp, f"{prefix}.attn_out.aq")
        new_cache = (ck, cv, pos + 1, ksc, vsc)
        return dense_proj(out, lp, qp, f"{prefix}.wo"), new_cache
    if cache is not None:
        ck, cv, pos = cache
        # decode: append the new token at `pos` (ring for windowed layers).
        # pos may be a scalar (static batch, every sequence in lockstep) or
        # a (B,) vector (continuous batching: every slot at its own
        # progress); both normalize to the per-row path.
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
        slot = jnp.mod(pos, ck.shape[1]) if window > 0 else pos
        row_upd = lambda c, u, s: jax.lax.dynamic_update_slice(
            c, u, (s, 0, 0))
        ck = jax.vmap(row_upd)(ck, k.astype(ck.dtype), slot)
        cv = jax.vmap(row_upd)(cv, v.astype(cv.dtype), slot)
        k_all, v_all = ck, cv
        # attention of the single query over the cache
        g = H // KVh
        if (_DECODE_ATTN["enabled"] and _KERNEL_DISPATCH["enabled"]
                and DECODE_SCORE_SHARDING is None):
            # fused flash-decode kernel: split-K online softmax over the
            # arena, valid-length/ring masking inside the kernel
            out = Kops.decode_attn_op(q.reshape(B, KVh, g, dh),
                                      k_all, v_all, pos, window=window)
            out = out.reshape(B, 1, H, dh).astype(x.dtype)
        else:
            qh = q.reshape(B, 1, KVh, g, dh)
            scores = jnp.einsum("bqkgd,bskd->bkgqs", qh.astype(jnp.float32),
                                k_all.astype(jnp.float32)) / math.sqrt(dh)
            if DECODE_SCORE_SHARDING is not None:
                scores = jax.lax.with_sharding_constraint(
                    scores, DECODE_SCORE_SHARDING)
            # mask unwritten arena rows: row b has written exactly
            # min(pos[b]+1, S) slots — rows [0, pos] of a full arena, or
            # the whole ring once a windowed arena wraps (softmax is
            # permutation-invariant over KV rows, so ring order is moot).
            # A fresh (pos < ring_len) windowed cache *must* mask its
            # zero-initialized tail, same as the full arena.
            valid = (jnp.arange(ck.shape[1])[None, :]
                     < jnp.minimum(pos + 1, ck.shape[1])[:, None])
            scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bkgqs,bskd->bqkgd", probs,
                             v_all.astype(jnp.float32))
            out = out.reshape(B, 1, H, dh).astype(x.dtype)
        new_cache = (ck, cv, pos + 1)
    else:
        out = attention(q, k, v, cfg, window=window, q_offset=q_offset)
    out = out.reshape(B, S, H * dh)
    out = qa(out, qp, f"{prefix}.attn_out.aq")
    return dense_proj(out, lp, qp, f"{prefix}.wo"), new_cache


# -------------------------------------------------------------------- mlp
def init_mlp(key, cfg: ModelConfig, prefix: str, n_layers: int, dtype,
             d_ff: Optional[int] = None) -> tuple[dict, dict]:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    L = (n_layers,) if n_layers else ()
    lax_ = ("layers",) if n_layers else ()
    p = {
        f"{prefix}.w_gate": jax.random.normal(k1, L + (D, F), dtype) * D ** -0.5,
        f"{prefix}.w_up": jax.random.normal(k2, L + (D, F), dtype) * D ** -0.5,
        f"{prefix}.w_down": jax.random.normal(k3, L + (F, D), dtype) * F ** -0.5,
    }
    axes = {
        f"{prefix}.w_gate": lax_ + ("embed", "mlp"),
        f"{prefix}.w_up": lax_ + ("embed", "mlp"),
        f"{prefix}.w_down": lax_ + ("mlp", "embed"),
    }
    return p, axes


def mlp_apply(lp: dict, qp: Optional[dict], cfg: ModelConfig, x, *,
              prefix: str):
    g = dense_proj(x, lp, qp, f"{prefix}.w_gate")
    u = dense_proj(x, lp, qp, f"{prefix}.w_up")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = qa(h, qp, f"{prefix}.mlp_act.aq")
    return dense_proj(h, lp, qp, f"{prefix}.w_down")


# -------------------------------------------------------------------- moe
def init_moe(key, cfg: ModelConfig, prefix: str, n_layers: int, dtype
             ) -> tuple[dict, dict]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    L = (n_layers,) if n_layers else ()
    lax_ = ("layers",) if n_layers else ()
    p = {
        f"{prefix}.router": jax.random.normal(k1, L + (D, E), dtype) * D ** -0.5,
        f"{prefix}.we_gate": jax.random.normal(k2, L + (E, D, F), dtype) * D ** -0.5,
        f"{prefix}.we_up": jax.random.normal(k3, L + (E, D, F), dtype) * D ** -0.5,
        f"{prefix}.we_down": jax.random.normal(k4, L + (E, F, D), dtype) * F ** -0.5,
    }
    axes = {
        f"{prefix}.router": lax_ + ("embed", "experts_router"),
        f"{prefix}.we_gate": lax_ + ("experts", "embed", "expert_mlp"),
        f"{prefix}.we_up": lax_ + ("experts", "embed", "expert_mlp"),
        f"{prefix}.we_down": lax_ + ("experts", "expert_mlp", "embed"),
    }
    if cfg.moe.shared_expert:
        ps, axs = init_mlp(key, cfg, f"{prefix}.shared", n_layers, dtype)
        p.update(ps)
        axes.update(axs)
    return p, axes


def moe_apply(lp: dict, qp: Optional[dict], cfg: ModelConfig, x, *,
              prefix: str, full_capacity: bool = False,
              shapes: Optional[LayerShapes] = None):
    """Top-k token-choice MoE, GShard-style grouped einsum dispatch.

    Tokens are split into G groups (one per sequence) with *per-group*
    capacity C = cf * n * k / E; the dispatch one-hot is (G, n, E, C) —
    linear in tokens. A global-capacity formulation is quadratic in tokens
    (measured ~1 TB/device temp on jamba train_4k) because C grows with N
    while the mask still spans all N tokens.

    `full_capacity` sets C = n * K so no token is ever dropped — the
    serving semantics. One-token decode can never overflow an expert, so a
    one-shot prefill only matches the sequential decode loop if its
    prompt tokens don't compete for capacity either (capacity pressure is
    a training-time load-balancing device, not an inference behaviour).

    Sharding: groups ride the batch axes; annotating the dispatched
    activations with experts -> 'model' (cfg.moe.impl='alltoall') makes
    GSPMD lower dispatch/combine to all-to-all (the §Perf EP lever).
    """
    B, S, D = x.shape
    shapes = shapes or LayerShapes.from_config(cfg)
    E, K = shapes.n_experts, cfg.moe.top_k
    if E < K:
        raise ValueError(f"{prefix}: {E} surviving experts < top_k={K} — "
                         f"the expert family was pruned below the router's "
                         f"top-k (keep at least top_k experts)")
    G, n = B, S
    xg = x.reshape(G, n, D)
    logits = (xg @ qw(lp, qp, f"{prefix}.router")).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, n, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)           # (G, n, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    C = n * K if full_capacity \
        else max(int(cfg.moe.capacity_factor * n * K / E), 4)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G, n, K, E)
    # position of each (token, k) within its expert's per-group queue
    flat = onehot.reshape(G, n * K, E)
    pos = jnp.cumsum(flat, axis=1).reshape(G, n, K, E) - 1.0
    pos = jnp.sum(pos * onehot, axis=-1)                     # (G, n, K)
    keep = (pos < C).astype(jnp.float32)
    gate_vals = gate_vals * keep

    posoh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=x.dtype)
    dispatch = jnp.einsum("gnke,gnkc->gnec", onehot.astype(x.dtype), posoh)
    combine = jnp.einsum("gnke,gnkc,gnk->gnec", onehot,
                         posoh.astype(jnp.float32), gate_vals)

    xe = jnp.einsum("gnec,gnd->gecd", dispatch, xg)          # (G, E, C, D)
    g = jnp.einsum("gecd,edf->gecf", xe, qw(lp, qp, f"{prefix}.we_gate"))
    u = jnp.einsum("gecd,edf->gecf", xe, qw(lp, qp, f"{prefix}.we_up"))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("gecf,efd->gecd", h, qw(lp, qp, f"{prefix}.we_down"))
    y = jnp.einsum("gnec,gecd->gnd", combine.astype(x.dtype), ye)

    if cfg.moe.shared_expert:
        y = y + mlp_apply(lp, qp, cfg, x, prefix=f"{prefix}.shared")
        return y.reshape(B, S, D)
    return y.reshape(B, S, D)


# ------------------------------------------------------------------ mamba
def init_mamba(key, cfg: ModelConfig, prefix: str, n_layers: int, dtype
               ) -> tuple[dict, dict]:
    D = cfg.d_model
    mc = cfg.mamba
    Di = mc.expand * D
    dtr = mc.dt_rank or D // 16
    N = mc.d_state
    ks = jax.random.split(key, 6)
    L = (n_layers,) if n_layers else ()
    lax_ = ("layers",) if n_layers else ()
    p = {
        f"{prefix}.in_proj": jax.random.normal(ks[0], L + (D, 2 * Di), dtype) * D ** -0.5,
        f"{prefix}.conv_w": jax.random.normal(ks[1], L + (mc.d_conv, Di), dtype) * 0.1,
        f"{prefix}.x_proj": jax.random.normal(ks[2], L + (Di, dtr + 2 * N), dtype) * Di ** -0.5,
        f"{prefix}.dt_proj": jax.random.normal(ks[3], L + (dtr, Di), dtype) * dtr ** -0.5,
        f"{prefix}.dt_bias": jnp.zeros(L + (Di,), dtype),
        f"{prefix}.A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)),
            L + (Di, N)).astype(jnp.float32) * 1.0,
        f"{prefix}.D": jnp.ones(L + (Di,), jnp.float32),
        f"{prefix}.out_proj": jax.random.normal(ks[4], L + (Di, D), dtype) * Di ** -0.5,
    }
    axes = {
        f"{prefix}.in_proj": lax_ + ("embed", "mamba_inner2"),
        f"{prefix}.conv_w": lax_ + ("conv_k", "mamba_inner"),
        f"{prefix}.x_proj": lax_ + ("mamba_inner", "mamba_lowrank"),
        f"{prefix}.dt_proj": lax_ + ("mamba_lowrank_dt", "mamba_inner"),
        f"{prefix}.dt_bias": lax_ + ("mamba_inner",),
        f"{prefix}.A_log": lax_ + ("mamba_inner", "mamba_state"),
        f"{prefix}.D": lax_ + ("mamba_inner",),
        f"{prefix}.out_proj": lax_ + ("mamba_inner", "embed"),
    }
    return p, axes


def _mamba_chunk_scan(xc, dt, Bc, Cc, A, D_vec, h0, chunk=64):
    """Chunked diagonal selective-SSM scan, memory-safe.

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t ;  y_t = <h_t, C_t>.
    The (B, S, Di, N) transition tensors are formed *per chunk inside the
    checkpointed body* (never full-sequence — that costs S/chunk x more
    HBM), and only y (B, S, Di) leaves the loop.

    xc: (B,S,Di) activations; dt: (B,S,Di) f32; Bc/Cc: (B,S,N);
    A: (Di,N) f32; D_vec: (Di,) f32; h0: (B,Di,N) f32.
    Returns (y (B,S,Di) f32, h_last).
    """
    B, S, Di = xc.shape
    N = A.shape[1]
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    nch = S // C

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    def chunk_step(h, inp):
        xcc, dtc, bcc, ccc = inp           # (B, C, ...)
        dA = jnp.exp(dtc[..., None] * A[None, None])          # (B,C,Di,N)
        dBx = (dtc * xcc.astype(jnp.float32))[..., None] \
            * bcc.astype(jnp.float32)[:, :, None, :]
        accA, accB = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        hs = accA * h[:, None] + accB
        y = jnp.einsum("bcdn,bcn->bcd", hs, ccc.astype(jnp.float32))
        y = y + D_vec[None, None] * xcc.astype(jnp.float32)
        return hs[:, -1], y

    def chunked(t):
        return jnp.moveaxis(t.reshape(B, nch, C, *t.shape[2:]), 1, 0)

    h_last, ys = jax.lax.scan(
        jax.checkpoint(chunk_step), h0,
        (chunked(xc), chunked(dt), chunked(Bc), chunked(Cc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, Di)
    return y, h_last


def mamba_apply(lp: dict, qp: Optional[dict], cfg: ModelConfig, x, *,
                prefix: str, state: Optional[tuple] = None,
                shapes: Optional[LayerShapes] = None):
    """Selective SSM block. state = (h (B,Di,N), conv (B,K-1,Di)) for decode.
    Di comes from `shapes` (pruned subnets keep fewer inner channels).
    Returns (out, new_state)."""
    B, S, D = x.shape
    mc = cfg.mamba
    shapes = shapes or LayerShapes.from_config(cfg)
    Di = shapes.mamba_inner
    N = mc.d_state
    Kc = mc.d_conv

    xi = dense_proj(x, lp, qp, f"{prefix}.in_proj_x")   # (B, S, Di)
    z = dense_proj(x, lp, qp, f"{prefix}.in_proj_z")

    conv_w = lp[f"{prefix}.conv_w"].astype(jnp.float32)   # (K, Di)
    if state is None:
        pad = jnp.zeros((B, Kc - 1, Di), xi.dtype)
        xpad = jnp.concatenate([pad, xi], axis=1)
        new_conv = xpad[:, -(Kc - 1):] if Kc > 1 else pad
    else:
        h_prev, conv_prev = state
        xpad = jnp.concatenate([conv_prev.astype(xi.dtype), xi], axis=1)
        new_conv = xpad[:, -(Kc - 1):] if Kc > 1 else conv_prev
    xc = sum(xpad[:, i:i + S].astype(jnp.float32) * conv_w[i]
             for i in range(Kc))
    xc = jax.nn.silu(xc).astype(x.dtype)

    proj = dense_proj(xc, lp, qp, f"{prefix}.x_proj")
    dtr = (cfg.mamba.dt_rank or D // 16)
    dt_low, Bc, Cc = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        dense_proj(dt_low, lp, qp, f"{prefix}.dt_proj").astype(jnp.float32)
        + lp[f"{prefix}.dt_bias"].astype(jnp.float32))     # (B, S, Di)
    A = -jnp.exp(lp[f"{prefix}.A_log"].astype(jnp.float32))  # (Di, N)

    h0 = jnp.zeros((B, Di, N), jnp.float32) if state is None \
        else state[0]
    y, h_last = _mamba_chunk_scan(
        xc, dt, Bc, Cc, A, lp[f"{prefix}.D"].astype(jnp.float32), h0,
        chunk=mc.chunk)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = qa(y, qp, f"{prefix}.mamba_out.aq")
    out = dense_proj(y, lp, qp, f"{prefix}.out_proj")
    return out, (h_last, new_conv)


# ------------------------------------------------------------------ rwkv6
def init_rwkv(key, cfg: ModelConfig, prefix: str, n_layers: int, dtype
              ) -> tuple[dict, dict]:
    D, F = cfg.d_model, cfg.d_ff
    rc = cfg.rwkv
    H = D // rc.head_size
    R = rc.decay_lora
    ks = jax.random.split(key, 10)
    L = (n_layers,) if n_layers else ()
    lax_ = ("layers",) if n_layers else ()
    std = D ** -0.5
    p = {
        # time-mix
        f"{prefix}.mu": jax.random.uniform(ks[0], L + (5, D), dtype),
        f"{prefix}.wr": jax.random.normal(ks[1], L + (D, D), dtype) * std,
        f"{prefix}.wk": jax.random.normal(ks[2], L + (D, D), dtype) * std,
        f"{prefix}.wv": jax.random.normal(ks[3], L + (D, D), dtype) * std,
        f"{prefix}.wg": jax.random.normal(ks[4], L + (D, D), dtype) * std,
        f"{prefix}.wo": jax.random.normal(ks[5], L + (D, D), dtype) * std,
        f"{prefix}.decay_w1": jax.random.normal(ks[6], L + (D, R), dtype) * std,
        f"{prefix}.decay_w2": jax.random.normal(ks[7], L + (R, D), dtype) * R ** -0.5,
        f"{prefix}.decay_w0": jnp.full(L + (D,), -1.0, jnp.float32),
        f"{prefix}.u": jnp.zeros(L + (D,), jnp.float32),   # time_first
        f"{prefix}.lnx_scale": jnp.ones(L + (D,), jnp.float32),
        f"{prefix}.lnx_bias": jnp.zeros(L + (D,), jnp.float32),
        # channel-mix
        f"{prefix}.cm_mu": jax.random.uniform(ks[8], L + (2, D), dtype),
        f"{prefix}.cm_k": jax.random.normal(ks[9], L + (D, F), dtype) * std,
        f"{prefix}.cm_v": jax.random.normal(ks[0], L + (F, D), dtype) * F ** -0.5,
        f"{prefix}.cm_r": jax.random.normal(ks[1], L + (D, D), dtype) * std,
    }
    axes = {
        f"{prefix}.mu": lax_ + ("mix5", "embed"),
        f"{prefix}.wr": lax_ + ("embed", "rwkv_heads"),
        f"{prefix}.wk": lax_ + ("embed", "rwkv_heads"),
        f"{prefix}.wv": lax_ + ("embed", "rwkv_heads"),
        f"{prefix}.wg": lax_ + ("embed", "rwkv_heads"),
        f"{prefix}.wo": lax_ + ("rwkv_heads", "embed"),
        f"{prefix}.decay_w1": lax_ + ("embed", "lora"),
        f"{prefix}.decay_w2": lax_ + ("lora", "rwkv_heads"),
        f"{prefix}.decay_w0": lax_ + ("rwkv_heads",),
        f"{prefix}.u": lax_ + ("rwkv_heads",),
        f"{prefix}.lnx_scale": lax_ + ("rwkv_heads",),
        f"{prefix}.lnx_bias": lax_ + ("rwkv_heads",),
        f"{prefix}.cm_mu": lax_ + ("mix2", "embed"),
        f"{prefix}.cm_k": lax_ + ("embed", "rwkv_ffn"),
        f"{prefix}.cm_v": lax_ + ("rwkv_ffn", "embed"),
        f"{prefix}.cm_r": lax_ + ("embed", "rwkv_heads"),
    }
    return p, axes


def _token_shift(x, last: Optional[jax.Array]):
    """xs[t] = x[t-1]; xs[0] = last (or 0)."""
    B, S, D = x.shape
    if S == 1:
        prev = jnp.zeros((B, 1, D), x.dtype) if last is None \
            else last[:, None].astype(x.dtype)
        return prev
    head = jnp.zeros((B, 1, D), x.dtype) if last is None \
        else last[:, None].astype(x.dtype)
    return jnp.concatenate([head, x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0, chunk: int = 64):
    """WKV recurrence, chunked two-level scan (exact; per-token math).

    r,k,v,w: (B, S, H, dh); u: (H, dh); s0: (B, H, dh, dh).
    y_t = r_t @ (S_t + u * k_t^T v_t); S_{t+1} = diag(w_t) S_t + k_t^T v_t.

    The outer scan carries state across chunks with a checkpointed body, so
    the backward keeps one (B,H,dh,dh) state per *chunk* instead of per
    token (a ~chunk x HBM reduction; per-token residuals measured at
    tens of GB for 4k-seq full configs). The TPU-optimized path would be a
    chunked Pallas kernel (DESIGN.md); this is the reference + dry-run path.
    """
    B, S, H, dh = r.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    nch = S // C

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp   # (B, H, dh)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,dh,dh)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, ..., None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, y

    def chunk_fn(s, inp):
        rc, kc, vc, wc = inp       # (C, B, H, dh)
        return jax.lax.scan(step, s, (rc, kc, vc, wc))

    def chunked(t):
        t = jnp.moveaxis(t, 1, 0).astype(jnp.float32)     # (S, B, H, dh)
        return t.reshape(nch, C, B, H, dh)

    s_last, ys = jax.lax.scan(
        jax.checkpoint(chunk_fn), s0,
        (chunked(r), chunked(k), chunked(v), chunked(w)))
    ys = ys.reshape(S, B, H, dh)
    return jnp.moveaxis(ys, 0, 1), s_last   # (B,S,H,dh)


def rwkv_timemix_apply(lp: dict, qp: Optional[dict], cfg: ModelConfig, x, *,
                       prefix: str, state: Optional[tuple] = None,
                       shapes: Optional[LayerShapes] = None):
    """RWKV6 (Finch) time-mix with data-dependent decay.

    state = (shift_last (B,D), wkv_state (B,H,dh,dh)); H comes from
    `shapes` (pruned subnets keep fewer heads). Returns (out, state).
    """
    B, S, D = x.shape
    rc = cfg.rwkv
    dh = rc.head_size
    shapes = shapes or LayerShapes.from_config(cfg)
    H = shapes.rwkv_heads
    last = state[0] if state is not None else None
    xs = _token_shift(x, last)
    mu = lp[f"{prefix}.mu"].astype(jnp.float32)  # (5, D)
    dx = (xs - x).astype(jnp.float32)
    x32 = x.astype(jnp.float32)

    def mixed(i):
        return (x32 + dx * mu[i]).astype(x.dtype)

    r = dense_proj(mixed(0), lp, qp, f"{prefix}.wr").reshape(B, S, H, dh)
    k = dense_proj(mixed(1), lp, qp, f"{prefix}.wk").reshape(B, S, H, dh)
    v = dense_proj(mixed(2), lp, qp, f"{prefix}.wv").reshape(B, S, H, dh)
    g = jax.nn.silu(dense_proj(mixed(3), lp, qp, f"{prefix}.wg")
                    .astype(jnp.float32))
    # data-dependent decay (LoRA)
    dd = jnp.tanh(dense_proj(mixed(4), lp, qp, f"{prefix}.decay_w1")
                  .astype(jnp.float32))
    dd = dense_proj(dd, lp, qp, f"{prefix}.decay_w2").astype(jnp.float32)
    logw = -jnp.exp(jnp.clip(
        lp[f"{prefix}.decay_w0"].astype(jnp.float32) + dd, -8.0, 4.0))
    w = jnp.exp(logw).reshape(B, S, H, dh)
    u = lp[f"{prefix}.u"].astype(jnp.float32).reshape(H, dh)

    s0 = jnp.zeros((B, H, dh, dh), jnp.float32) if state is None \
        else state[1]
    y, s_last = _wkv_scan(r, k, v, w, u, s0, chunk=rc.chunk)
    y = groupnorm_heads(y.reshape(B, S, H * dh).astype(x.dtype),
                        lp[f"{prefix}.lnx_scale"], lp[f"{prefix}.lnx_bias"],
                        H, cfg.norm_eps)
    y = (y.astype(jnp.float32) * g).astype(x.dtype)
    y = qa(y, qp, f"{prefix}.tm_out.aq")
    out = dense_proj(y, lp, qp, f"{prefix}.wo")
    return out, (x[:, -1].astype(jnp.float32), s_last)


def rwkv_chanmix_apply(lp: dict, qp: Optional[dict], cfg: ModelConfig, x, *,
                       prefix: str, state: Optional[jax.Array] = None):
    """RWKV channel-mix FFN. state = shift_last (B, D)."""
    xs = _token_shift(x, state)
    mu = lp[f"{prefix}.cm_mu"].astype(jnp.float32)
    dx = (xs - x).astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    xk = (x32 + dx * mu[0]).astype(x.dtype)
    xr = (x32 + dx * mu[1]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense_proj(xk, lp, qp, f"{prefix}.cm_k")
                               .astype(jnp.float32))).astype(x.dtype)
    k = qa(k, qp, f"{prefix}.cm_act.aq")
    val = dense_proj(k, lp, qp, f"{prefix}.cm_v")
    r = jax.nn.sigmoid(dense_proj(xr, lp, qp, f"{prefix}.cm_r")
                       .astype(jnp.float32))
    out = (val.astype(jnp.float32) * r).astype(x.dtype)
    return out, x[:, -1].astype(jnp.float32)

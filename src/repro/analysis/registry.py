"""Entry-point registry: what the static verifier analyzes.

The unit of analysis is a *traced entry*: one jitted dispatch reachable
from the serve loop (or the sharded trainer), traced once to a jaxpr via
`jax.make_jaxpr` with `kernels.introspect` recording the Pallas launches
the trace would dispatch. Tracing never compiles and never touches
devices, so the full matrix runs in seconds on the CPU CI host.

The serving side is *engine-derived*: each config group builds a real
(smoke-scale) engine and asks it for `Engine.entry_points()` — the
registry never re-states which jits exist, so a new engine dispatch added
without registry coverage shows up as an uncovered entry, not a silently
unanalyzed one. Every group is built against an explicit TP mesh
(`make_tp_mesh(tp)`, tp=1 on single-device hosts) so the sharding-pin
audit has real NamedShardings to check even on one device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.kernels import introspect

ARCH = "internlm2-1.8b"
MAX_SLOTS = 2
MAX_SEQ = 32

# group name -> build_engine kwargs; one group per serving mode of the
# backend/serving matrix (dense / pruned+packed / paged+quantized KV /
# speculative / chunked prefill). TP rides on every group via the mesh.
CONFIGS: dict[str, dict] = {
    "dense": {},
    "pruned_packed": {"pruned": True, "packed": True, "sparsity": 0.5,
                      "bits_init": 4.0},
    "paged": {"paged": True, "page_size": 8, "kv_bits": 8},
    "speculative": {"speculative": True, "draft_k": 4,
                    "draft_sparsity": 0.5, "draft_bits": 2.0},
    "chunked": {"prefill_chunk": 8},
}


@dataclasses.dataclass
class TracedEntry:
    group: str                    # config group ("dense", ..., "train")
    name: str                     # entry-point name within the group
    kind: str                     # "serving" | "training"
    fn: object                    # the jitted callable (for lowering)
    args: tuple
    static_argnums: tuple
    expected_out: object          # pytree of NamedShardings or None
    jaxpr: object                 # ClosedJaxpr from make_jaxpr
    launches: list                # introspect launch records
    tp: int = 1                   # mesh size the entry was built against

    @property
    def key(self) -> str:
        return f"{self.group}:{self.name}"


def trace_entry(group: str, ep: dict, kind: str = "serving", tp: int = 1
                ) -> TracedEntry:
    with introspect.record_launches() as launches:
        jaxpr = jax.make_jaxpr(
            ep["fn"], static_argnums=tuple(ep.get("static_argnums", ())))(
                *ep["args"])
    return TracedEntry(group=group, name=ep["name"], kind=kind,
                       fn=ep["fn"], args=tuple(ep["args"]),
                       static_argnums=tuple(ep.get("static_argnums", ())),
                       expected_out=ep.get("expected_out"),
                       jaxpr=jaxpr, launches=list(launches), tp=tp)


def build_serving(groups=None, *, arch: str = ARCH, tp: Optional[int] = None,
                  max_slots: int = MAX_SLOTS, max_seq: int = MAX_SEQ):
    """Build the engine matrix and trace every entry point.

    Returns (engines, traced): `engines` maps group -> Engine (the
    compile-set audit reads warmup contracts off the live object),
    `traced` is the flat TracedEntry list. `tp` defaults to the host
    device count (1-device hosts get a 1-device TP mesh — sharding pins
    are still real NamedShardings there)."""
    from repro.launch.engine import build_engine
    from repro.launch.mesh import make_tp_mesh

    if tp is None:
        tp = jax.device_count()
    mesh = make_tp_mesh(tp)
    groups = list(groups or CONFIGS)
    engines, traced = {}, []
    for group in groups:
        kwargs = CONFIGS[group]
        eng, _ = build_engine(arch, True, max_slots=max_slots,
                              max_seq=max_seq, verbose=False, mesh=mesh,
                              **kwargs)
        engines[group] = eng
        for ep in eng.entry_points():
            traced.append(trace_entry(group, ep, kind="serving", tp=tp))
    return engines, traced


def build_training(*, arch: str = ARCH, devices: Optional[int] = None,
                   grad_slices: Optional[int] = None) -> TracedEntry:
    """Trace one deterministic sharded GETA train step (the
    `make_ordered_loss_grads` path — DP over the host's devices).
    `grad_slices` must match the mesh size; it defaults to `devices`."""
    from repro.configs import CompressionConfig, get_arch
    from repro.data.synthetic import batch_for
    from repro.launch.mesh import make_subset_mesh
    from repro.launch.train import build_geta, make_sharded_geta_train_step
    from repro.models.transformer import LM

    if devices is None:
        devices = jax.device_count()
    if grad_slices is None:
        grad_slices = devices
    comp = CompressionConfig(
        target_sparsity=0.25, bit_lower=4, bit_upper=16, warmup_steps=2,
        projection_periods=1, projection_steps=2, pruning_periods=1,
        pruning_steps=2, cooldown_steps=2)
    cfg = get_arch(arch, smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    qparams = lm.init_qparams(params, bits_init=16.0)
    _, qasso = build_geta(lm, comp, lr=3e-3, base_optimizer="momentum")
    qstate = qasso.init(params, qparams)
    mesh = make_subset_mesh(devices)
    jstep, _ = make_sharded_geta_train_step(lm, qasso, mesh, params,
                                            qparams,
                                            grad_slices=grad_slices)
    batch = batch_for(cfg, 0, 0, max(2, devices), 16)
    ep = {"name": "train_step", "fn": jstep,
          "args": (params, qparams, qstate, batch), "static_argnums": (),
          "expected_out": None}
    return trace_entry("train", ep, kind="training", tp=devices)

"""The static contract passes (DESIGN.md §4.13).

1. `audit_identity`    — no cross-device reduction primitive in any TP
   serving jaxpr; training reductions only as ordered all_gathers inside
   the `make_ordered_loss_grads` shard_map. Optional second layer scans
   the *compiled* HLO for float add-combiner all-reduces GSPMD might
   introduce after SPMD partitioning (trace-level absence is necessary,
   not sufficient).
2. `audit_sharding_pins` — every arena/row-returning jit declares
   out_shardings matching the `kv_cache_specs`-derived contract (flags
   the operand-propagation pattern the pre-PR-10 `_insert` relied on).
3. `audit_compile_set` — brute-force the reachable dispatch-shape sets
   (decode windows, spec ks, chunk shapes) independently of the engine's
   warmup code and fail if warmup's precompiled set doesn't cover them.
4. VMEM budget — see `analysis.vmem`.
5. `audit_constants` — large closure-captured constants (silent HBM
   pinning + retrace hazards) and f64-widening `convert_element_type`.

Each pass maps traced entries (or live engines, for the compile-set
audit) to `report.Finding`s with stable IDs.
"""
from __future__ import annotations

import re
from typing import Optional

import numpy as np

from repro.analysis import jaxpr_utils as ju
from repro.analysis.report import Finding, make_finding

# Cross-device *reduction* primitives: combining values from different
# devices, where combine order can reassociate float sums — banned
# everywhere in serving (TP is column/head-parallel by construction: no
# contraction ever splits) and allowed in training only via the
# slice-ordered path below.
REDUCTION_PRIMS = frozenset({
    "psum", "psum2", "all_reduce", "reduce_scatter", "all_to_all",
    "pmax", "pmin", "pmean", "reduce_precision_psum",
})
# Pure data movement: bitwise replication/rotation, no arithmetic.
# Banned in serving jaxprs too (nothing should move between devices
# mid-decode), but allowed inside the trainer's shard_map (the ordered
# reduction gathers slices and sums them in a fixed order locally).
MOVEMENT_PRIMS = frozenset({"all_gather", "ppermute", "pbroadcast"})

IDENTITY = "identity"
SHARDING = "sharding"
COMPILE_SET = "compile_set"
CONSTANTS = "constants"


# ------------------------------------------------------ 1: identity audit
def audit_identity(traced_entries, compiled: bool = False
                   ) -> list[Finding]:
    findings = []
    for te in traced_entries:
        hits = ju.find_prims(te.jaxpr, REDUCTION_PRIMS | MOVEMENT_PRIMS)
        counted: dict[tuple, int] = {}
        for eqn, path in hits:
            prim = eqn.primitive.name
            if te.kind == "training":
                # the deterministic trainer's only legal collective: an
                # all_gather inside the make_ordered_loss_grads shard_map
                # (gather slices, sum in fixed order locally)
                if prim in MOVEMENT_PRIMS and ju.in_shard_map(path):
                    continue
            counted[(prim, ju.in_shard_map(path))] = \
                counted.get((prim, ju.in_shard_map(path)), 0) + 1
        for (prim, inside_sm), n in sorted(counted.items()):
            where = "inside shard_map" if inside_sm else "at top level"
            if te.kind == "training":
                msg = (f"training jaxpr contains {n}x `{prim}` {where} — "
                       f"reductions must flow through the slice-ordered "
                       f"all_gather+local-sum path only")
            else:
                msg = (f"TP serving jaxpr contains {n}x `{prim}` {where} — "
                       f"serving must stay collective-free (token identity "
                       f"holds because no contraction ever splits)")
            findings.append(make_finding(
                IDENTITY, te.group, te.name, prim, msg,
                detail={"count": n, "in_shard_map": inside_sm}))
        if compiled and te.tp > 1 and te.kind == "serving":
            findings.extend(_compiled_identity(te))
    return findings


def _hlo_computations(text: str) -> dict[str, str]:
    """name -> body for every computation in an HLO text dump."""
    comps: dict[str, str] = {}
    name, body = None, []
    for line in text.splitlines():
        m = re.match(r"\s*(ENTRY\s+)?(%?[\w.\-]+)\s*(\([^)]*\))?.*\{\s*$",
                     line)
        if m and name is None:
            name = m.group(2).lstrip("%")
            body = []
            continue
        if name is not None:
            if line.strip() == "}":
                comps[name] = "\n".join(body)
                name = None
            else:
                body.append(line)
    return comps


# HLO all-reduces whose JAX source op is a masked one-hot assembly:
# every output element has exactly one nonzero contributor (a sharded
# embedding gather, a KV-cache concatenate/update assembled from
# per-device shards), so the add combiner sums x+0+...+0 — bitwise
# exact, no reassociation. Everything else (dot_general above all:
# GSPMD's K-split partial-dot + all-reduce rewrite) genuinely
# reassociates a float sum and is flagged.
_EXACT_ASSEMBLY_OPS = frozenset({
    "gather", "concatenate", "dynamic_update_slice", "dynamic-update-slice",
    "scatter", "select_n",
})

_HLO_COLLECTIVE_RE = re.compile(
    r"=\s*(\S+)\s+(all-reduce|reduce-scatter)\(.*?to_apply=(%?[\w.\-]+)")
_HLO_OPNAME_RE = re.compile(r'op_name="[^"]*?/([\w\-]+)"')


def _compiled_identity(te) -> list[Finding]:
    """Scan the post-SPMD compiled HLO: flag reduce-scatter always and
    all-reduce when its combiner is a float add on a genuinely
    multi-contributor sum (reassociation hazard). Max/min combiners are
    exact (sharded-vocab argmax), all-gather is bitwise movement, and
    one-hot-assembly adds (see `_EXACT_ASSEMBLY_OPS`) are exact."""
    findings = []
    try:
        text = te.fn.lower(*te.args).compile().as_text()
    except Exception as exc:    # lowering is best-effort hardening
        findings.append(make_finding(
            IDENTITY, te.group, te.name, "hlo-lower-failed",
            f"could not lower/compile for the HLO identity scan: {exc}",
            severity="warning"))
        return findings
    comps = _hlo_computations(text)
    flagged = set()
    for line in text.splitlines():
        m = _HLO_COLLECTIVE_RE.search(line)
        if not m:
            continue
        rtype, op, region = m.groups()
        region = region.lstrip("%")
        src = _HLO_OPNAME_RE.search(line)
        src_op = src.group(1) if src else "unknown"
        body = comps.get(region, "")
        is_float = bool(re.match(r"\(?(f16|f32|f64|bf16)", rtype))
        is_add = re.search(r"\badd\(", body) is not None
        if op == "all-reduce" and not (is_float and is_add):
            continue
        if op == "all-reduce" and src_op in _EXACT_ASSEMBLY_OPS:
            continue
        slug = f"hlo-{op}-{src_op}"
        if slug in flagged:
            continue
        flagged.add(slug)
        findings.append(make_finding(
            IDENTITY, te.group, te.name, slug,
            f"compiled HLO contains `{op}` with a float add combiner "
            f"over a `{src_op}` (region {region}) — the SPMD partitioner "
            f"introduced a cross-device reduction the trace-level audit "
            f"cannot see",
            detail={"result_type": rtype, "region": region,
                    "source_op": src_op}))
    return findings


# -------------------------------------------------- 2: sharding-pin audit
def audit_sharding_pins(traced_entries) -> list[Finding]:
    findings = []
    for te in traced_entries:
        if te.expected_out is None or te.kind != "serving":
            continue
        import jax
        pjit_eqn = ju.outer_pjit_eqn(te.jaxpr)
        if pjit_eqn is None:
            findings.append(make_finding(
                SHARDING, te.group, te.name, "no-pjit",
                "entry did not trace to a single pjit equation — cannot "
                "audit its out_shardings", severity="warning"))
            continue
        actual = ju.out_shardings_of(pjit_eqn)
        leaves_p = jax.tree_util.tree_flatten_with_path(te.expected_out)[0]
        if len(actual) != len(leaves_p):
            findings.append(make_finding(
                SHARDING, te.group, te.name, "arity",
                f"out_shardings arity {len(actual)} != expected "
                f"{len(leaves_p)} leaves — contract tree is stale"))
            continue
        for (path, want), got in zip(leaves_p, actual):
            leaf = jax.tree_util.keystr(path) or "out"
            slug = re.sub(r"[^A-Za-z0-9_.\[\]]+", "", leaf) or "out"
            if ju.is_unspecified(got):
                findings.append(make_finding(
                    SHARDING, te.group, te.name, f"unpinned{slug}",
                    f"output leaf {leaf} has no out_sharding pinned — the "
                    f"arena's placement would be operand-propagated "
                    f"instead of contractual"))
            elif ju.spec_of(got) != ju.spec_of(want):
                findings.append(make_finding(
                    SHARDING, te.group, te.name, f"mismatch{slug}",
                    f"output leaf {leaf} pins {ju.spec_of(got)} but the "
                    f"kv_cache_specs contract says {ju.spec_of(want)}"))
    return findings


# --------------------------------------------------- 3: compile-set audit
def audit_compile_set(engines: dict) -> list[Finding]:
    """Diff brute-forced reachable dispatch-shape sets against the
    warmup contract, per engine config. Reachable sets are enumerated
    from the *dispatch-site quantizers* (`pow2_floor`, `chunk_plan`),
    warmed sets from the engine's own warmup helpers — independent
    derivations, so a shared bug can't hide."""
    from repro.launch.scheduler import chunk_buckets, reachable_chunk_shapes
    from repro.launch.speculative import pow2_floor, reachable_spec_ks

    findings = []
    for group, eng in sorted(engines.items()):
        if eng.draft is not None:
            reach = reachable_spec_ks(eng.draft_k, eng.max_seq)
            warmed = set(eng._spec_ks())
            for k in sorted(reach - warmed):
                findings.append(make_finding(
                    COMPILE_SET, group, "spec", f"k{k}",
                    f"speculative step can dispatch k={k} but warmup only "
                    f"precompiles {sorted(warmed)} — first hit would "
                    f"compile mid-serve",
                    detail={"reachable": sorted(reach),
                            "warmed": sorted(warmed)}))
        elif not eng._chunk:
            reach = {min(pow2_floor(r), eng.MAX_WINDOW)
                     for r in range(1, eng.max_seq + 1)}
            warmed = set(eng.warmed_window_ks())
            for k in sorted(reach - warmed):
                findings.append(make_finding(
                    COMPILE_SET, group, "decode_window", f"k{k}",
                    f"fused decode window can dispatch k={k} but warmup "
                    f"only precompiles {sorted(warmed)}",
                    detail={"reachable": sorted(reach),
                            "warmed": sorted(warmed)}))
        if eng._chunk:
            reach = reachable_chunk_shapes(eng.max_seq, eng._chunk)
            warmed = set(chunk_buckets(eng._chunk))
            for c in sorted(reach - warmed):
                findings.append(make_finding(
                    COMPILE_SET, group, "prefill_chunk", f"c{c}",
                    f"chunk plan can emit a length-{c} chunk but warmup "
                    f"only precompiles buckets {sorted(warmed)}",
                    detail={"reachable": sorted(reach),
                            "warmed": sorted(warmed)}))
    return findings


# --------------------------------------- 5: constant-capture / dtype audit
def audit_constants(traced_entries, max_elems: int = 1 << 16
                    ) -> list[Finding]:
    findings = []
    for te in traced_entries:
        big = ju.collect_consts(te.jaxpr, min_elems=max_elems + 1)
        seen: dict[str, int] = {}
        for path, c in big:
            shape = tuple(np.shape(c))
            dtype = np.asarray(c).dtype if not hasattr(c, "dtype") \
                else c.dtype
            slug = "x".join(map(str, shape)) + f"-{dtype}"
            seen[slug] = seen.get(slug, 0) + 1
            if seen[slug] > 1:
                continue    # one finding per distinct shape/dtype
            nbytes = int(np.size(c)) * np.dtype(dtype).itemsize
            findings.append(make_finding(
                CONSTANTS, te.group, te.name, f"const-{slug}",
                f"trace closure-captured a {shape} {dtype} constant "
                f"(~{nbytes / 2**20:.1f} MiB) — it pins HBM outside the "
                f"param tree and retraces on every new closure",
                detail={"shape": list(shape), "dtype": str(dtype),
                        "path": list(path)}))
        for eqn, _ in ju.walk_eqns(te.jaxpr):
            if eqn.primitive.name != "convert_element_type":
                continue
            new = eqn.params.get("new_dtype")
            if new is not None and np.dtype(new) == np.dtype(np.float64):
                findings.append(make_finding(
                    CONSTANTS, te.group, te.name, "f64-widen",
                    "jaxpr widens to float64 — serving/training math is "
                    "f32; an f64 convert doubles bytes and falls off the "
                    "MXU path"))
                break
    return findings


def run_all(engines: dict, traced_entries, *, compiled: bool = False,
            vmem_budget: Optional[int] = None,
            const_max_elems: int = 1 << 16) -> list[Finding]:
    from repro.analysis.vmem import audit_vmem
    findings = []
    findings += audit_identity(traced_entries, compiled=compiled)
    findings += audit_sharding_pins(traced_entries)
    findings += audit_compile_set(engines)
    findings += audit_vmem(traced_entries, budget=vmem_budget)
    findings += audit_constants(traced_entries, max_elems=const_max_elems)
    return findings

"""Static contract checker: proves the engine's identity, sharding, and
VMEM invariants from jaxprs (and optionally compiled HLO) before
anything runs. See `repro.analysis.verify` for the CLI and DESIGN.md
§4.13 for the pass catalogue."""
from repro.analysis.report import Finding, make_finding  # noqa: F401

"""Pass 4 — VMEM budget checker over recorded kernel launches.

The byte models live next to the kernels (`kernels.introspect`, sharing
`gemm_core.plan_blocks` / `decode_attn.plan_tiles` with the real launch
code so model and kernel cannot drift); this module turns recorded
launches into findings against the ~16 MiB/core budget. The same model
pre-filters autotuner candidates (`autotune.vmem_filter`), so a tile the
analyzer would reject can never be recorded as a tuning winner either.
"""
from __future__ import annotations

from typing import Optional

from repro.analysis.report import Finding, make_finding
from repro.kernels import introspect

PASS = "vmem"


def launch_slug(launch) -> str:
    """Stable ID slug for one launch: logical shape + epilogue, never a
    traversal index — the same kernel launched from two call sites
    dedups, and reordering the model's layers can't churn the baseline."""
    if isinstance(launch, introspect.GemmLaunch):
        return f"gemm:{launch.M}x{launch.N}x{launch.K}:{launch.ops}"
    return (f"{launch.kind}:B{launch.B}h{launch.KVh}g{launch.g}"
            f"d{launch.dh}c{launch.chunk}")


def audit_vmem(traced_entries, budget: Optional[int] = None
               ) -> list[Finding]:
    budget = budget or introspect.VMEM_BUDGET_BYTES
    findings, seen = [], set()
    for te in traced_entries:
        for launch in te.launches:
            nbytes = introspect.launch_vmem_bytes(launch)
            if nbytes <= budget:
                continue
            slug = launch_slug(launch)
            fid_key = (te.group, te.name, slug)
            if fid_key in seen:
                continue
            seen.add(fid_key)
            findings.append(make_finding(
                PASS, te.group, te.name, slug,
                f"tile footprint ~{nbytes / 2**20:.1f} MiB exceeds the "
                f"{budget / 2**20:.0f} MiB VMEM budget: "
                f"{launch.describe()}",
                detail={"bytes": int(nbytes), "budget": int(budget),
                        "launch": launch.describe()}))
    return findings

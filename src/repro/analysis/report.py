"""Findings, stable IDs, baseline/suppression file, deterministic report.

A finding is one contract violation located by a pass. Its ID is built
from stable coordinates only — `pass:group:entry:slug` — never from
traversal indices that could shuffle between runs, so the checked-in
baseline (`analysis_baseline.json`) diffs cleanly and CI can fail on
*new* violations while known, justified ones stay suppressed with a
recorded reason.

The report body is fully deterministic: findings sort by ID, every dict
serializes with sorted keys, and nothing time- or host-dependent (no
timestamps, no hostnames, no durations) enters the JSON.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

BASELINE_FORMAT = "repro-analysis-baseline-v1"
REPORT_FORMAT = "repro-analysis-report-v1"
DEFAULT_BASELINE = "analysis_baseline.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    fid: str            # stable id: "pass:group:entry:slug"
    pass_name: str
    group: str          # engine config ("dense", "paged", ...) / "train"
    entry: str          # entry-point name within the group
    message: str
    severity: str = "error"         # "error" | "warning"
    detail: Optional[dict] = None

    def to_json(self) -> dict:
        d = {"id": self.fid, "pass": self.pass_name, "group": self.group,
             "entry": self.entry, "severity": self.severity,
             "message": self.message}
        if self.detail:
            d["detail"] = self.detail
        return d


def make_finding(pass_name: str, group: str, entry: str, slug: str,
                 message: str, severity: str = "error",
                 detail: Optional[dict] = None) -> Finding:
    fid = ":".join((pass_name, group, entry, slug))
    return Finding(fid=fid, pass_name=pass_name, group=group, entry=entry,
                   message=message, severity=severity, detail=detail)


def load_baseline(path: Optional[str] = None) -> dict[str, str]:
    """fid -> justification from the baseline file ({} when absent)."""
    path = path or DEFAULT_BASELINE
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        raw = json.load(f)
    sup = raw.get("suppress", {})
    return {str(k): str(v) for k, v in sup.items()}


def save_baseline(findings: list[Finding], path: str,
                  reason: str = "baselined") -> str:
    """Write every current finding as a suppression (``--update-baseline``).
    An empty finding list writes an empty (all-green) baseline."""
    payload = {"format": BASELINE_FORMAT,
               "suppress": {f.fid: reason
                            for f in sorted(findings, key=lambda x: x.fid)}}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def split_findings(findings: list[Finding], baseline: dict[str, str]
                   ) -> tuple[list[Finding], list[Finding]]:
    """(new, suppressed) — a finding is suppressed iff its exact ID is in
    the baseline."""
    new = [f for f in findings if f.fid not in baseline]
    sup = [f for f in findings if f.fid in baseline]
    return new, sup


def make_report(findings: list[Finding], baseline: dict[str, str],
                config: dict) -> dict:
    """Deterministic machine-readable report (ordering fixed, no
    timestamps). `config` records what was analyzed — groups, device
    count, budget — so two reports are byte-identical iff the analysis
    saw the same program."""
    new, sup = split_findings(findings, baseline)
    ordered = sorted(findings, key=lambda f: f.fid)
    return {
        "format": REPORT_FORMAT,
        "config": {k: config[k] for k in sorted(config)},
        "counts": {
            "findings": len(findings),
            "new": len(new),
            "suppressed": len(sup),
            "errors": sum(f.severity == "error" for f in findings),
            "warnings": sum(f.severity == "warning" for f in findings),
        },
        "findings": [f.to_json() for f in ordered],
        "new": sorted(f.fid for f in new),
        "suppressed": sorted(f.fid for f in sup),
    }


def dumps(report: dict) -> str:
    return json.dumps(report, indent=2, sort_keys=True) + "\n"

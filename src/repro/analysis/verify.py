"""CLI: `python -m repro.analysis.verify` — run the static contract
checker over the serving/training entry-point matrix.

Exit status: 0 unless ``--fail-on-new`` is set and at least one finding
is not suppressed by the baseline — CI gates on new violations while
known, justified ones stay recorded in `analysis_baseline.json`.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import passes, registry, report


def build_and_run(groups=None, *, arch: str = registry.ARCH,
                  tp=None, compiled: bool = False, train: bool = True,
                  vmem_budget=None):
    """(engines, traced, findings) for the requested matrix slice."""
    engines, traced = registry.build_serving(groups, arch=arch, tp=tp)
    if train:
        traced.append(registry.build_training(arch=arch))
    findings = passes.run_all(engines, traced, compiled=compiled,
                              vmem_budget=vmem_budget)
    return engines, traced, findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.verify",
        description="Static contract checker: identity, sharding-pin, "
                    "compile-set, VMEM, and constant-capture audits over "
                    "every engine/trainer entry point.")
    ap.add_argument("--configs", default=None,
                    help="comma-separated config groups "
                         f"(default: all of {','.join(registry.CONFIGS)})")
    ap.add_argument("--arch", default=registry.ARCH)
    ap.add_argument("--tp", type=int, default=None,
                    help="TP mesh size (default: host device count)")
    ap.add_argument("--no-train", action="store_true",
                    help="skip the sharded train-step trace")
    ap.add_argument("--compiled", action="store_true",
                    help="also scan compiled HLO of TP serving entries "
                         "for GSPMD-introduced float reductions")
    ap.add_argument("--baseline", default=report.DEFAULT_BASELINE)
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit 1 when any finding is not in the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to suppress every current "
                         "finding, then exit 0")
    ap.add_argument("--json", action="store_true",
                    help="print the full machine-readable report")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="override the VMEM budget in bytes")
    args = ap.parse_args(argv)

    groups = args.configs.split(",") if args.configs else None
    engines, traced, findings = build_and_run(
        groups, arch=args.arch, tp=args.tp, compiled=args.compiled,
        train=not args.no_train, vmem_budget=args.vmem_budget)

    if args.update_baseline:
        path = report.save_baseline(findings, args.baseline)
        print(f"baseline updated: {path} ({len(findings)} suppressions)")
        return 0

    baseline = report.load_baseline(args.baseline)
    import jax
    cfg = {"arch": args.arch, "groups": sorted(engines),
           "entries": len(traced), "tp": traced[0].tp if traced else 1,
           "devices": jax.device_count(), "compiled": args.compiled,
           "train": not args.no_train}
    rep = report.make_report(findings, baseline, cfg)

    if args.json:
        sys.stdout.write(report.dumps(rep))
    else:
        new, sup = report.split_findings(findings, baseline)
        print(f"analyzed {len(traced)} entries across "
              f"{len(engines)} configs (+train={not args.no_train}) "
              f"on {cfg['devices']} device(s)")
        for f in sorted(findings, key=lambda x: x.fid):
            mark = "SUPPRESSED" if f.fid in baseline else f.severity.upper()
            print(f"  [{mark}] {f.fid}")
            print(f"      {f.message}")
        print(f"{len(findings)} finding(s): {len(new)} new, "
              f"{len(sup)} suppressed")

    new, _ = report.split_findings(findings, baseline)
    if args.fail_on_new and new:
        print(f"FAIL: {len(new)} new finding(s) not in {args.baseline}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Jaxpr-walking primitives for the static contract checker.

Everything here is pure introspection over `jax.make_jaxpr` output: no
compilation, no device execution. The central abstraction is a recursive
equation walk that descends into *every* sub-jaxpr an equation carries in
its params — `pjit` bodies, `shard_map` bodies, `scan`/`while`/`cond`
branches, custom-vjp call jaxprs — yielding each equation together with
the *path* of enclosing higher-order primitives, so a pass can ask both
"does a psum appear anywhere?" and "is this all_gather inside a
shard_map?" without knowing the nesting rules of each primitive.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterator, Optional

import jax
import numpy as np
from jax import core as jcore


def iter_subjaxprs(eqn) -> Iterator[tuple[str, "jcore.Jaxpr", tuple]]:
    """Yield (param_key, jaxpr, consts) for every sub-jaxpr in an
    equation's params — ClosedJaxpr values carry their consts, raw Jaxpr
    values (shard_map bodies, cond branches in some versions) carry none.
    Handles both bare values and tuples/lists of them."""
    for key, val in eqn.params.items():
        vals = list(val) if isinstance(val, (tuple, list)) else [val]
        for i, v in enumerate(vals):
            label = key if len(vals) == 1 else f"{key}[{i}]"
            if isinstance(v, jcore.ClosedJaxpr):
                yield label, v.jaxpr, tuple(v.consts)
            elif isinstance(v, jcore.Jaxpr):
                yield label, v, ()


def walk_eqns(jaxpr, path: tuple[str, ...] = ()
              ) -> Iterator[tuple[object, tuple[str, ...]]]:
    """DFS over every equation of `jaxpr` and all nested sub-jaxprs.

    Yields (eqn, path) where `path` is the tuple of enclosing primitive
    names ("pjit", "shard_map", "scan", ...) from outermost to innermost.
    Accepts a Jaxpr or ClosedJaxpr."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn, path
        for _, sub, _ in iter_subjaxprs(eqn):
            yield from walk_eqns(sub, path + (eqn.primitive.name,))


def prim_counts(jaxpr) -> Counter:
    """Histogram of primitive names over the full nested walk."""
    return Counter(eqn.primitive.name for eqn, _ in walk_eqns(jaxpr))


def find_prims(jaxpr, names) -> list[tuple[object, tuple[str, ...]]]:
    """All (eqn, path) whose primitive name is in `names`."""
    names = set(names)
    return [(eqn, path) for eqn, path in walk_eqns(jaxpr)
            if eqn.primitive.name in names]


def in_shard_map(path: tuple[str, ...]) -> bool:
    """True when the walk path passes through a shard_map body."""
    return "shard_map" in path


def collect_consts(closed, min_elems: int = 1
                   ) -> list[tuple[tuple[str, ...], object]]:
    """Every closure-captured constant in `closed` and all nested
    sub-jaxprs, as (path, const) — the HBM the trace pinned that is not
    an argument. `min_elems` filters scalars/small tables early."""
    out = []

    def visit(jaxpr, consts, path):
        for c in consts:
            if np.size(c) >= min_elems:
                out.append((path, c))
        for eqn in jaxpr.eqns:
            for label, sub, sub_consts in iter_subjaxprs(eqn):
                visit(sub, sub_consts,
                      path + (f"{eqn.primitive.name}:{label}",))

    visit(closed.jaxpr, tuple(closed.consts), ())
    return out


def outer_pjit_eqn(closed) -> Optional[object]:
    """The single top-level pjit equation of `jax.make_jaxpr(jitted_fn)`
    output — the equation whose params carry the jit's in/out shardings.
    None when the traced callable was not a jit wrapper."""
    eqns = closed.jaxpr.eqns if isinstance(closed, jcore.ClosedJaxpr) \
        else closed.eqns
    pjits = [e for e in eqns if e.primitive.name == "pjit"]
    if len(eqns) == 1 and len(pjits) == 1:
        return pjits[0]
    return pjits[0] if len(pjits) == 1 else None


def is_unspecified(sharding) -> bool:
    """True for pjit's UnspecifiedValue marker (no out_sharding pinned).
    Matched by type name — the class moved modules across JAX releases."""
    return sharding is None or type(sharding).__name__ == "UnspecifiedValue"


def out_shardings_of(pjit_eqn) -> tuple:
    """The flat out_shardings tuple a pjit equation declares (one entry
    per flattened output leaf; UnspecifiedValue where unpinned)."""
    return tuple(pjit_eqn.params.get("out_shardings", ()))


def spec_of(sharding):
    """The PartitionSpec of a NamedSharding-like object, else None."""
    return getattr(sharding, "spec", None)


def trace_jaxpr(fn, args, static_argnums=()):
    """`jax.make_jaxpr` with static argnums, returning the ClosedJaxpr.

    Trace only — nothing is lowered or compiled."""
    return jax.make_jaxpr(fn, static_argnums=tuple(static_argnums))(*args)

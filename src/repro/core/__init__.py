# GETA's primary contribution as a composable JAX feature set:
#   quant   — learnable (d, q_m, t) quantization, STE gradients (Eqs 1-6)
#   graph   — trace-graph model declaration (GraphBuilder)
#   qadg    — Algorithm 1: quantization-aware dependency graph analysis
#   groups  — pruning search space (minimally removable structures, masks)
#   saliency— HESSO-style group scores
#   qasso   — Algorithm 2-4: the four-stage joint optimizer
#   bops    — bit-operations accounting (the paper's efficiency metric)
#   subnet  — construct_subnet(): deployable pruned+quantized artifact
from repro.core.graph import FamilySpec, GraphBuilder, TraceGraph, Vertex
from repro.core.groups import GroupFamily, Member, PruningSpace
from repro.core.qadg import QADG, QuantSite, build_qadg
from repro.core.qasso import QASSO, QASSOConfig, QASSOState
from repro.core.quant import (QuantParams, bit_width, fake_quant,
                              init_quant_params, project_step_size,
                              step_size_for_bits)
from repro.core.saliency import SaliencyConfig
from repro.core.subnet import Subnet, construct_subnet

__all__ = [
    "FamilySpec", "GraphBuilder", "TraceGraph", "Vertex",
    "GroupFamily", "Member", "PruningSpace",
    "QADG", "QuantSite", "build_qadg",
    "QASSO", "QASSOConfig", "QASSOState",
    "QuantParams", "bit_width", "fake_quant", "init_quant_params",
    "project_step_size", "step_size_for_bits",
    "SaliencyConfig", "Subnet", "construct_subnet",
]

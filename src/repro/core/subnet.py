"""construct_subnet(): materialize the pruned + quantized deployable model.

Mirrors the paper's Framework Usage line 8. Produces:
- physically sliced parameters (pruned units removed),
- integer weight codes + scales for every weight-quant site (the
  `repro.kernels` quant-dequant GEMM serving path),
- a manifest (kept units per family, per-site bit widths, BOPs summary).

Serving integration: `servable_params()` flattens a Subnet into the param
dict convention consumed by `models.layers.dense_proj` — each compressed
2-D weight `<name>` becomes `<name>.codes` + `<name>.scale`, and the
model's matmuls then execute the dequant epilogue on the shared GEMM core
(int codes stream HBM->VMEM, decode inside VMEM). `compress_lm()` builds
such a Subnet for an LM without a pruning run (keep-all), which is what
`python -m repro.launch.serve --compressed` uses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qadg import QADG
from repro.core.quant import QuantParams, bit_width, quantize_int


def _storage_dtype(bits: float):
    nbits = int(np.ceil(bits))
    if nbits <= 8:
        return jnp.int8
    if nbits <= 16:
        return jnp.int16
    return jnp.int32


@dataclasses.dataclass
class Subnet:
    params: dict[str, jax.Array]            # sliced real-valued params
    int_weights: dict[str, jax.Array]       # param name -> integer codes
    scales: dict[str, jax.Array]            # param name -> step size d
    bits: dict[str, float]                  # site name -> bit width
    kept_units: dict[str, np.ndarray]       # family -> surviving unit ids
    meta: dict[str, Any]


def construct_subnet(qadg: QADG, params: dict, qparams: dict,
                     keep_masks: dict) -> Subnet:
    sliced, kept = qadg.space.materialize(params, keep_masks)

    int_weights: dict[str, jax.Array] = {}
    scales: dict[str, jax.Array] = {}
    bits: dict[str, float] = {}
    for site in qadg.sites:
        qp: QuantParams = qparams[site.name]
        b = float(bit_width(qp.d, qp.q_m, qp.t))
        bits[site.name] = b
        if site.kind != "weight":
            continue
        for pname in site.quantized_params:
            if pname not in sliced:
                continue
            codes, d = quantize_int(sliced[pname], qp)
            # narrowest container that holds the codes
            int_weights[pname] = codes.astype(_storage_dtype(b))
            scales[pname] = d

    n_total = qadg.space.total_units()
    n_kept = sum(int(np.sum(np.asarray(keep_masks[f.name]) > 0.5))
                 for f in qadg.space.prunable_families())
    return Subnet(
        params=sliced, int_weights=int_weights, scales=scales, bits=bits,
        kept_units=kept,
        meta={
            "sparsity": 1.0 - n_kept / max(n_total, 1),
            "mean_bits": float(np.mean(list(bits.values()))) if bits else 32.0,
            "n_sites": len(qadg.sites),
        })


# --------------------------------------------------------------- serving
def _routed(name: str) -> bool:
    """True if the models execute this weight through `dense_proj` (and so
    would consume `<name>.codes` at decode time). MoE einsum weights
    (router/we_*) and the embedding are not routed: their forward reads
    the dense tensor."""
    from repro.models.layers import ROUTED_COMPONENTS
    if name == "head":
        return True
    parts = name.split(".")
    return len(parts) >= 2 and parts[-2] in ROUTED_COMPONENTS


def compress_lm(lm, params: dict, qparams: dict,
                components: tuple[str, ...] | None = None) -> Subnet:
    """Quantize an LM's projection weights to int codes (no pruning).

    `lm` is a `models.transformer.LM`; `qparams` its weight-quant sites
    (`<name>.wq` -> QuantParams). Every routed quantizable weight — all
    `dense_proj` components (attn/mlp/mamba/rwkv/shared) by default,
    optionally narrowed via `components` — is replaced by integer codes +
    a scale; everything else stays dense. Returns a keep-all Subnet."""
    int_weights: dict[str, jax.Array] = {}
    scales: dict[str, jax.Array] = {}
    bits: dict[str, float] = {}
    dense = dict(params)
    dense_bytes = quant_bytes = 0
    for name in lm.quant_weight_names():
        site = name + ".wq"
        if name not in params or site not in qparams:
            continue
        parts = name.split(".")
        comp = parts[-2] if len(parts) >= 2 else ""
        if components is not None and comp not in components:
            continue
        if not _routed(name):
            # only compress weights the decode can actually execute from
            # codes — popping a non-routed weight would drop it entirely
            # (servable_params re-emits codes for routed names only)
            continue
        qp: QuantParams = qparams[site]
        b = float(bit_width(qp.d, qp.q_m, qp.t))
        codes, d = quantize_int(params[name], qp)
        store = codes.astype(_storage_dtype(b))
        int_weights[name] = store
        scales[name] = d
        bits[site] = b
        dense_bytes += params[name].size * params[name].dtype.itemsize
        quant_bytes += store.size * store.dtype.itemsize
        dense.pop(name)
    return Subnet(
        params=dense, int_weights=int_weights, scales=scales, bits=bits,
        kept_units={},
        meta={
            "sparsity": 0.0,
            "mean_bits": float(np.mean(list(bits.values()))) if bits else 32.0,
            "n_sites": len(bits),
            "weight_bytes_dense": dense_bytes,
            "weight_bytes_compressed": quant_bytes,
        })


def residual_qparams(subnet: Subnet, qparams: dict) -> Optional[dict]:
    """Quant sites for weights the compressed decode keeps dense.

    Weights executing from int codes already carry their quantizer inside
    the codes; the rest (embedding, MoE einsum weights — anything
    `servable_params` does not emit codes for) must keep their fake-quant
    site so compressed and dense decodes share numerics."""

    def executes_from_codes(site: str) -> bool:
        if not site.endswith(".wq"):
            return False
        name = site[:-len(".wq")]
        return name in subnet.int_weights and _routed(name)

    out = {site: qp for site, qp in qparams.items()
           if not executes_from_codes(site)}
    return out or None


def prepare_serving(lm, params: dict, qparams: Optional[dict] = None, *,
                    quantized: bool = True, compressed: bool = False,
                    bits_init: float = 8.0
                    ) -> tuple[dict, Optional[dict], dict[str, Any]]:
    """Resolve one (params, qparams) pair every serving entry point decodes
    with — built once, reused across the prefill jit, the per-slot decode
    jit and the cache-insertion jit (the engine never re-derives codes per
    request). Returns (params, qparams, meta).

    Dense path: weight-quant sites applied as fake-quant (QAT numerics).
    Compressed path: routed projections replaced by a keep-all Subnet's
    integer codes + scales (`servable_params`), with `residual_qparams`
    keeping fake-quant sites for the weights that stay dense so both paths
    share numerics. `compressed` implies quantization — a half-quantized
    model would match neither baseline."""
    if qparams is None and (quantized or compressed):
        qparams = lm.init_qparams(params, bits_init=bits_init)
    if not (quantized or compressed):
        qparams = None
    meta: dict[str, Any] = {}
    if compressed:
        subnet = compress_lm(lm, params, qparams)
        meta = dict(subnet.meta)
        params = servable_params(subnet)
        qparams = residual_qparams(subnet, qparams)
    return params, qparams, meta


def compression_report(arch: str, meta: dict) -> str:
    """One-line summary of a `prepare_serving(compressed=True)` meta dict,
    shared by every serving CLI so the report format can't drift."""
    return (f"{arch}: compressed {meta['n_sites']} sites to "
            f"{meta['mean_bits']:.1f} mean bits "
            f"({meta['weight_bytes_dense']/2**20:.1f} MiB -> "
            f"{meta['weight_bytes_compressed']/2**20:.1f} MiB)")


def servable_params(subnet: Subnet) -> dict:
    """Flatten a Subnet into the `dense_proj` param-dict convention.

    Compressed sites appear as `<name>.codes` (narrow int container,
    scan-stacked exactly like the dense tensor was) + `<name>.scale`;
    remaining params pass through. Feed the result anywhere a params dict
    is accepted (`LM.decode_step`, `LM.forward`)."""
    out = dict(subnet.params)
    for name, codes in subnet.int_weights.items():
        if not _routed(name):
            continue   # forward reads this weight dense; codes would only
            # bloat the scan carry (construct_subnet quantizes every site)
        scale = subnet.scales[name]
        if codes.ndim >= 3 and jnp.ndim(scale) == 0:
            # LM block weights are stacked (n_blocks, K, N): broadcast the
            # per-tensor scale over the stack axis so it scans with the
            # codes through the layer-stack lax.scan.
            scale = jnp.broadcast_to(scale, codes.shape[:1])
        # drop the dense copy (construct_subnet keeps it in sliced params);
        # carrying both would invert the bandwidth win
        out.pop(name, None)
        out[name + ".codes"] = codes
        out[name + ".scale"] = scale
    return out

"""construct_subnet(): materialize the pruned + quantized deployable model.

Mirrors the paper's Framework Usage line 8. Produces:
- physically sliced parameters (pruned units removed),
- integer weight codes + scales for every weight-quant site (the
  `repro.kernels` quant-dequant GEMM serving path),
- a manifest (kept units per family, per-site bit widths, BOPs summary).

Serving integration: `servable_params()` flattens a Subnet into the param
dict convention consumed by `models.layers.dense_proj` — each compressed
2-D weight `<name>` becomes `<name>.codes` + `<name>.scale`, and the
model's matmuls then execute the dequant epilogue on the shared GEMM core
(int codes stream HBM->VMEM, decode inside VMEM). `compress_lm()` builds
such a Subnet for an LM without a pruning run (keep-all), which is what
`python -m repro.launch.serve --compressed` uses. With `packed=True` the
codes bit-pack along K at their learned sub-byte storage widths and ride
the dict as `<name>.packed{bits}` word streams instead (`--packed`,
DESIGN.md §4.8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qadg import QADG
from repro.core.quant import (QuantParams, bit_width, pack_codes,
                              packed_storage_bits, quantize_int)


def tree_bytes(tree) -> int:
    """Bytes a pytree of arrays occupies — the one counter behind every
    realized-size figure (served params, KV arena, benchmark rows), so
    the reports can't drift apart."""
    return sum(int(leaf.size) * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree))


def _storage_dtype(bits: float):
    nbits = int(np.ceil(bits))
    if nbits <= 8:
        return jnp.int8
    if nbits <= 16:
        return jnp.int16
    return jnp.int32


@dataclasses.dataclass
class Subnet:
    params: dict[str, jax.Array]            # sliced real-valued params
    int_weights: dict[str, jax.Array]       # param name -> integer codes
    scales: dict[str, jax.Array]            # param name -> step size d
    bits: dict[str, float]                  # site name -> bit width
    kept_units: dict[str, np.ndarray]       # family -> surviving unit ids
    meta: dict[str, Any]
    # param name -> packed storage width: entries mark `int_weights[name]`
    # as a K-packed int32 word stream (`core.quant.pack_codes` at that
    # width) instead of a plain int container. Empty = unpacked subnet.
    packed_bits: dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SlimPlan:
    """Per-sublayer physical shapes of a pruned LM subnet.

    `layer_shapes` holds one `models.layers.LayerShapes` per
    position-in-period (aligned with `LM.plan`); `LM.apply_slim_plan`
    installs them so forward/prefill/decode_step reshape — and init_cache
    allocates — at the sliced widths. Per-stack pruning granularity
    (DESIGN.md §2.2) makes every layer of a stack share its position's
    shapes, so the layer-stack `lax.scan` stays shape-homogeneous and the
    compiled-shape set is bounded by the period (the engine's `warmup()`
    precompile contract).
    """
    layer_shapes: list[Any]                 # one LayerShapes per plan entry
    kept_units: dict[str, np.ndarray]       # family -> surviving unit ids
    sparsity: float                         # realized over prunable units
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


def construct_subnet(qadg: QADG, params: dict, qparams: dict,
                     keep_masks: dict) -> Subnet:
    sliced, kept = qadg.space.materialize(params, keep_masks)

    int_weights: dict[str, jax.Array] = {}
    scales: dict[str, jax.Array] = {}
    bits: dict[str, float] = {}
    for site in qadg.sites:
        qp: QuantParams = qparams[site.name]
        b = float(bit_width(qp.d, qp.q_m, qp.t))
        bits[site.name] = b
        if site.kind != "weight":
            continue
        for pname in site.quantized_params:
            if pname not in sliced:
                continue
            codes, d = quantize_int(sliced[pname], qp, bits=b)
            # narrowest container that holds the codes
            int_weights[pname] = codes.astype(_storage_dtype(b))
            scales[pname] = d

    n_total = qadg.space.total_units()
    n_kept = sum(int(np.sum(np.asarray(keep_masks[f.name]) > 0.5))
                 for f in qadg.space.prunable_families())
    return Subnet(
        params=sliced, int_weights=int_weights, scales=scales, bits=bits,
        kept_units=kept,
        meta={
            "sparsity": 1.0 - n_kept / max(n_total, 1),
            "mean_bits": float(np.mean(list(bits.values()))) if bits else 32.0,
            "mean_storage_bits": _mean_storage_bits(bits),
            "n_sites": len(qadg.sites),
        })


def _mean_storage_bits(bits: dict[str, float]) -> float:
    """Mean *integer* (ceil) bits over sites — the width the storage
    containers are actually sized from, reported alongside the float
    `mean_bits` so the report's bits and bytes figures agree."""
    if not bits:
        return 32.0
    return float(np.mean([np.ceil(b) for b in bits.values()]))


# ------------------------------------------------------------- slim plan
def _check_family(kept_units: dict, fam: str, got: int, unit: int = 1,
                  what: str = "") -> None:
    kept = kept_units.get(fam)
    if kept is not None and len(kept) * unit != got:
        raise ValueError(
            f"slim plan: family {fam} keeps {len(kept)} units "
            f"(x{unit}) but the sliced {what or 'param'} has width {got}")


def derive_slim_plan(lm, params: dict, kept_units: dict[str, np.ndarray],
                     sparsity: float = 0.0) -> SlimPlan:
    """Derive the per-sublayer execution shapes of a sliced LM.

    `params` is `PruningSpace.materialize` output; the sliced tensors are
    the ground truth for each width (surviving kv-head groups x gqa_group
    heads, MLP hidden units, experts, mamba inner channels, rwkv heads),
    cross-checked against `kept_units` wherever a structured family name
    identifies the axis. The residual width is pinned by the non-prunable
    embed/head space and stays `d_model`."""
    from repro.models.layers import LayerShapes
    cfg = lm.cfg

    def dim(name: str) -> int:
        return int(params[name].shape[-1])

    shapes = []
    for sub in lm.plan:
        pre = f"blocks.{sub.j}"
        kw: dict[str, int] = {}
        if sub.mixer == "attn":
            q_dim, kv_dim = dim(f"{pre}.attn.wq"), dim(f"{pre}.attn.wk")
            if q_dim % cfg.d_head or kv_dim % cfg.d_head:
                raise ValueError(
                    f"{pre}.attn: sliced q/kv widths {q_dim}/{kv_dim} are "
                    f"not multiples of d_head={cfg.d_head} — the kv-group "
                    f"family must remove whole heads")
            kw.update(n_heads=q_dim // cfg.d_head,
                      n_kv_heads=kv_dim // cfg.d_head)
            _check_family(kept_units, f"{pre}.attn.kv_groups",
                          kw["n_heads"], cfg.gqa_group, "wq head count")
        elif sub.mixer == "mamba":
            kw.update(mamba_inner=dim(f"{pre}.mamba.in_proj_x"))
            _check_family(kept_units, f"{pre}.mamba.channels",
                          kw["mamba_inner"], 1, "in_proj_x")
        else:
            hw = dim(f"{pre}.rwkv.wr")
            if hw % cfg.rwkv.head_size:
                raise ValueError(
                    f"{pre}.rwkv: sliced width {hw} is not a multiple of "
                    f"head_size={cfg.rwkv.head_size}")
            kw.update(rwkv_heads=hw // cfg.rwkv.head_size)
            _check_family(kept_units, f"{pre}.rwkv.heads",
                          kw["rwkv_heads"], 1, "wr head count")
        if sub.ffn == "mlp":
            kw.update(d_ff=dim(f"{pre}.mlp.w_gate"))
            for fam in kept_units:
                # the MLP hidden space is a generic dependency-analysis
                # family: "space.<sid>.blocks.<j>.mlp.gate"
                if fam.endswith(f".{pre}.mlp.gate"):
                    _check_family(kept_units, fam, kw["d_ff"], 1, "w_gate")
        elif sub.ffn == "moe":
            kw.update(n_experts=dim(f"{pre}.moe.router"))
            _check_family(kept_units, f"{pre}.moe.experts",
                          kw["n_experts"], 1, "router")
        elif sub.ffn == "chanmix":
            kw.update(cm_hidden=dim(f"{pre}.rwkv.cm_k"))
            _check_family(kept_units, f"{pre}.rwkv.cm_hidden",
                          kw["cm_hidden"], 1, "cm_k")
        shapes.append(dataclasses.replace(LayerShapes.from_config(cfg), **kw))
    return SlimPlan(layer_shapes=shapes, kept_units=dict(kept_units),
                    sparsity=float(sparsity))


def default_min_keep(cfg) -> dict[str, int]:
    """Per-family-kind keep floors for serving-side masks: at least one
    unit everywhere, and never fewer experts than the router's top_k."""
    floors = {"head_group": 1, "channel": 1, "state": 1}
    if cfg.moe is not None:
        floors["expert"] = cfg.moe.top_k
    return floors


def magnitude_keep_masks(space, params: dict, sparsity: float, *,
                         min_keep: Optional[dict[str, int]] = None
                         ) -> dict[str, jax.Array]:
    """Deterministic keep masks at a target sparsity: per prunable family,
    keep the top-(1-s) units by group L2 magnitude — the serving-side
    stand-in for a trained QASSO mask (`prepare_serving` synthesizes one
    when no mask dict is supplied). Ties break by unit index, so the same
    params always yield the same masks (the pruned-vs-masked parity
    checks lean on that)."""
    min_keep = dict(min_keep or {})
    masks = {}
    for fam in space.prunable_families():
        score = np.linalg.norm(
            np.asarray(space.group_matrix(params, fam), np.float32), axis=1)
        floor = max(int(min_keep.get(fam.kind, 1)), 1)
        n_keep = int(np.clip(fam.units - round(sparsity * fam.units),
                             floor, fam.units))
        keep = np.sort(np.argsort(-score, kind="stable")[:n_keep])
        m = np.zeros((fam.units,), np.float32)
        m[keep] = 1.0
        masks[fam.name] = jnp.asarray(m)
    return masks


def resolve_keep_masks(lm, params: dict, sparsity: float):
    """One mask-resolution recipe for the pruned path AND its masked
    reference oracle: QADG + magnitude masks with the default floors.
    Both sides calling this is what makes the token-identity parity
    checks compare against the *same* masks. Returns (qadg, masks)."""
    from repro.core.qadg import build_qadg
    qadg = build_qadg(lm.build_graph().graph)
    masks = magnitude_keep_masks(qadg.space, params, sparsity,
                                 min_keep=default_min_keep(lm.cfg))
    return qadg, masks


def masked_reference_params(lm, params: dict, sparsity: float, *,
                            quantized: bool = True):
    """The dense model with its pruned groups *exactly zero* — the shape a
    GETA checkpoint leaves the dense weights in after QASSO's cooldown
    hard-zeroes discarded groups. Numerically identical to the
    `prune_lm`-sliced subnet at the same masks and quantizer init (the
    PR 4/5 parity contract), which is what makes it (a) the pruned path's
    correctness oracle and (b) the speculative benchmark's target: a
    subnet drafted from the same checkpoint agrees with it token for
    token, so acceptance approaches 1. Resolves quantizers on the
    *unmasked* params — the same order `prepare_serving` uses, so scales
    match the sliced artifact's. Returns (masked params, qparams)."""
    qparams = lm.init_qparams(params) if quantized else None
    qadg, masks = resolve_keep_masks(lm, params, sparsity)
    masked = qadg.space.apply_masks(params, masks)
    return masked, qparams


def prune_lm(lm, params: dict, *, keep_masks: Optional[dict] = None,
             sparsity: float = 0.5) -> tuple[dict, SlimPlan]:
    """Physically slice an LM to its pruned shapes, end to end.

    Builds the QADG, resolves keep masks (a trained QASSO mask dict, or
    magnitude masks at `sparsity` when none is given), materializes the
    sliced params, and installs the derived SlimPlan on `lm` (mutating it:
    forward/prefill/decode_step and init_cache now run at the sliced
    widths). Returns (sliced params, plan)."""
    if keep_masks is None:
        qadg, keep_masks = resolve_keep_masks(lm, params, sparsity)
    else:
        from repro.core.qadg import build_qadg
        qadg = build_qadg(lm.build_graph().graph)
    sliced, kept = qadg.space.materialize(params, keep_masks)
    n_kept = sum(len(v) for v in kept.values())
    realized = 1.0 - n_kept / max(qadg.space.total_units(), 1)
    plan = derive_slim_plan(lm, sliced, kept, sparsity=realized)
    lm.apply_slim_plan(plan)
    return sliced, plan


# --------------------------------------------------------------- serving
def _routed(name: str) -> bool:
    """True if the models execute this weight through `dense_proj` (and so
    would consume `<name>.codes` at decode time). MoE einsum weights
    (router/we_*) and the embedding are not routed: their forward reads
    the dense tensor."""
    from repro.models.layers import ROUTED_COMPONENTS
    if name == "head":
        return True
    parts = name.split(".")
    return len(parts) >= 2 and parts[-2] in ROUTED_COMPONENTS


def compress_lm(lm, params: dict, qparams: dict,
                components: tuple[str, ...] | None = None, *,
                packed: bool = False) -> Subnet:
    """Quantize an LM's projection weights to int codes (no pruning).

    `lm` is a `models.transformer.LM`; `qparams` its weight-quant sites
    (`<name>.wq` -> QuantParams). Every routed quantizable weight — all
    `dense_proj` components (attn/mlp/mamba/rwkv/shared) by default,
    optionally narrowed via `components` — is replaced by integer codes +
    a scale; everything else stays dense. Returns a keep-all Subnet.

    `packed` realizes sub-byte storage: each site's codes are bit-packed
    along K (`core.quant.pack_codes`) at the narrowest width in
    `PACKED_STORAGE_BITS` that holds its learned bit width, so a 4-bit
    site occupies half — and a 2-bit site a quarter — of its int8
    container's HBM bytes. Sites whose learned width exceeds 8 bits keep
    the unpacked int16/int32 container. Per-site storage widths land in
    `Subnet.packed_bits` and `meta["packed_sites"]`; `meta` carries both
    the realized container bytes (`weight_bytes_compressed`) and the
    unpacked-container floor (`weight_bytes_unpacked`).

    Note: the meta intentionally does *not* claim a `sparsity` — this is
    a keep-all quantization, and `compression_report` treats the key's
    presence as "a pruning path ran" (an explicit 0.0 from `--sparsity 0`
    must still print)."""
    int_weights: dict[str, jax.Array] = {}
    scales: dict[str, jax.Array] = {}
    bits: dict[str, float] = {}
    packed_bits: dict[str, int] = {}
    dense = dict(params)
    dense_bytes = quant_bytes = unpacked_bytes = 0
    skipped: list[str] = []
    for name in lm.quant_weight_names():
        site = name + ".wq"
        if name not in params or site not in qparams:
            continue
        parts = name.split(".")
        comp = parts[-2] if len(parts) >= 2 else ""
        if components is not None and comp not in components:
            continue
        if not _routed(name):
            # only compress weights the decode can actually execute from
            # codes — popping a non-routed weight would drop it entirely
            # (servable_params re-emits codes for routed names only).
            # Record the skip so compression_report can surface it instead
            # of silently over-promising coverage.
            skipped.append(name)
            continue
        qp: QuantParams = qparams[site]
        b = float(bit_width(qp.d, qp.q_m, qp.t))
        codes, d = quantize_int(params[name], qp, bits=b)
        store = codes.astype(_storage_dtype(b))
        unpacked_bytes += store.size * store.dtype.itemsize
        sb = packed_storage_bits(b) if packed else None
        if sb is not None:
            store = pack_codes(codes, sb, axis=-2)
            packed_bits[name] = sb
        int_weights[name] = store
        scales[name] = d
        bits[site] = b
        dense_bytes += params[name].size * params[name].dtype.itemsize
        quant_bytes += store.size * store.dtype.itemsize
        dense.pop(name)
    meta = {
        "mean_bits": float(np.mean(list(bits.values()))) if bits else 32.0,
        "mean_storage_bits": _mean_storage_bits(bits),
        "n_sites": len(bits),
        "weight_bytes_dense": dense_bytes,
        "weight_bytes_compressed": quant_bytes,
        "skipped_sites": skipped,
    }
    if packed:
        meta["weight_bytes_unpacked"] = unpacked_bytes
        meta["packed_sites"] = dict(packed_bits)
    return Subnet(
        params=dense, int_weights=int_weights, scales=scales, bits=bits,
        kept_units={}, meta=meta, packed_bits=packed_bits)


def residual_qparams(subnet: Subnet, qparams: dict) -> Optional[dict]:
    """Quant sites for weights the compressed decode keeps dense.

    Weights executing from int codes already carry their quantizer inside
    the codes; the rest (embedding, MoE einsum weights — anything
    `servable_params` does not emit codes for) must keep their fake-quant
    site so compressed and dense decodes share numerics."""

    def executes_from_codes(site: str) -> bool:
        if not site.endswith(".wq"):
            return False
        name = site[:-len(".wq")]
        return name in subnet.int_weights and _routed(name)

    out = {site: qp for site, qp in qparams.items()
           if not executes_from_codes(site)}
    return out or None


def prepare_serving(lm, params: dict, qparams: Optional[dict] = None, *,
                    quantized: bool = True, compressed: bool = False,
                    packed: bool = False, bits_init: float = 8.0,
                    keep_masks: Optional[dict] = None,
                    prune_sparsity: Optional[float] = None
                    ) -> tuple[dict, Optional[dict], dict[str, Any]]:
    """Resolve one (params, qparams) pair every serving entry point decodes
    with — built once, reused across the prefill jit, the per-slot decode
    jit and the cache-insertion jit (the engine never re-derives codes per
    request). Returns (params, qparams, meta).

    Dense path: weight-quant sites applied as fake-quant (QAT numerics).
    Compressed path: routed projections replaced by a Subnet's integer
    codes + scales (`servable_params`), with `residual_qparams` keeping
    fake-quant sites for the weights that stay dense so both paths share
    numerics. `compressed` implies quantization — a half-quantized model
    would match neither baseline.

    Pruned path: `keep_masks` (a trained QASSO mask dict) or
    `prune_sparsity` (synthesized magnitude masks) physically slices the
    model first (`prune_lm`, mutating `lm` to its SlimPlan widths): params
    shrink, decode reshapes at surviving-head counts, and init_cache
    allocates the shrunk KV arena. Quantizers are resolved *before*
    slicing, so the pruned model shares its scales with the masked dense
    reference — the token-identity contract the parity tests pin. Pruning
    composes with `compressed`: the sliced weights are then quantized to
    int codes (the dequant epilogue runs on pruned shapes).

    Packed path: `packed` (implies `compressed`) bit-packs each site's
    codes at its learned sub-byte storage width (`compress_lm(packed=)`)
    and serves `<name>.packed{bits}` containers — `param_bytes` then
    reflects the packed word streams, and stacking with pruning yields
    the full GETA deployment artifact (sliced shapes, sub-byte bytes)."""
    compressed = compressed or packed
    if qparams is None and (quantized or compressed):
        qparams = lm.init_qparams(params, bits_init=bits_init)
    if not (quantized or compressed):
        qparams = None
    meta: dict[str, Any] = {}
    if keep_masks is not None or prune_sparsity is not None:
        params, plan = prune_lm(lm, params, keep_masks=keep_masks,
                                sparsity=(prune_sparsity or 0.0))
        meta["slim_plan"] = plan
        meta["sparsity"] = plan.sparsity
    if compressed:
        subnet = compress_lm(lm, params, qparams, packed=packed)
        for k, v in subnet.meta.items():
            meta.setdefault(k, v)   # pruning-path keys win on collision
        params = servable_params(subnet)
        qparams = residual_qparams(subnet, qparams)
    meta["param_bytes"] = tree_bytes(params)
    return params, qparams, meta


def compression_report(arch: str, meta: dict) -> str:
    """One-line summary of a `prepare_serving` meta dict, shared by every
    serving CLI so the report format can't drift. Prints whichever of the
    quantization / pruning / realized-bytes figures the meta carries
    (param bytes are the served dict as resolved; kv_bytes is stamped by
    the engine once the arena exists)."""
    parts = []
    if meta.get("n_sites"):
        parts.append(f"compressed {meta['n_sites']} sites to "
                     f"{meta['mean_bits']:.1f} mean bits "
                     f"({meta.get('mean_storage_bits', 8.0):.1f} storage) "
                     f"({meta['weight_bytes_dense']/2**20:.1f} MiB -> "
                     f"{meta['weight_bytes_compressed']/2**20:.1f} MiB)")
    if meta.get("packed_sites"):
        parts.append(f"{len(meta['packed_sites'])} sites sub-byte packed "
                     f"({meta['weight_bytes_unpacked']/2**20:.1f} MiB "
                     f"unpacked -> "
                     f"{meta['weight_bytes_compressed']/2**20:.1f} MiB)")
    if meta.get("skipped_sites"):
        parts.append(f"{len(meta['skipped_sites'])} non-routed sites "
                     f"kept dense")
    # `is not None`, not truthiness: an explicit --pruned --sparsity 0 run
    # (all-keep masks) still ran the pruning path and must say so;
    # compress-only metas simply don't carry the key.
    if meta.get("sparsity") is not None:
        parts.append(f"pruned to sparsity {meta['sparsity']:.2f}")
    if "param_bytes" in meta:
        parts.append(f"served params {meta['param_bytes']/2**20:.2f} MiB")
    if "kv_bytes" in meta:
        parts.append(f"KV arena {meta['kv_bytes']/2**20:.2f} MiB")
    return f"{arch}: " + "; ".join(parts or ["no compression applied"])


def servable_params(subnet: Subnet) -> dict:
    """Flatten a Subnet into the `dense_proj` param-dict convention.

    Compressed sites appear as `<name>.codes` (narrow int container,
    scan-stacked exactly like the dense tensor was) + `<name>.scale`;
    packed sites (`Subnet.packed_bits`) as `<name>.packed{bits}` (int32
    K-packed word stream — the storage width rides the *key*, so it stays
    static through jit while the words scan over the layer axis);
    remaining params pass through. Feed the result anywhere a params dict
    is accepted (`LM.decode_step`, `LM.forward`)."""
    out = dict(subnet.params)
    for name, codes in subnet.int_weights.items():
        if not _routed(name):
            continue   # forward reads this weight dense; codes would only
            # bloat the scan carry (construct_subnet quantizes every site)
        scale = subnet.scales[name]
        if codes.ndim >= 3 and jnp.ndim(scale) == 0:
            # LM block weights are stacked (n_blocks, K, N): broadcast the
            # per-tensor scale over the stack axis so it scans with the
            # codes through the layer-stack lax.scan.
            scale = jnp.broadcast_to(scale, codes.shape[:1])
        # drop the dense copy (construct_subnet keeps it in sliced params);
        # carrying both would invert the bandwidth win
        out.pop(name, None)
        sb = subnet.packed_bits.get(name)
        key = f"{name}.packed{sb}" if sb is not None else name + ".codes"
        out[key] = codes
        out[name + ".scale"] = scale
    return out

"""construct_subnet(): materialize the pruned + quantized deployable model.

Mirrors the paper's Framework Usage line 8. Produces:
- physically sliced parameters (pruned units removed),
- integer weight codes + scales for every weight-quant site (the
  `repro.kernels.quant_matmul` serving path),
- a manifest (kept units per family, per-site bit widths, BOPs summary).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qadg import QADG
from repro.core.quant import QuantParams, bit_width, quantize_int


@dataclasses.dataclass
class Subnet:
    params: dict[str, jax.Array]            # sliced real-valued params
    int_weights: dict[str, jax.Array]       # param name -> integer codes
    scales: dict[str, jax.Array]            # param name -> step size d
    bits: dict[str, float]                  # site name -> bit width
    kept_units: dict[str, np.ndarray]       # family -> surviving unit ids
    meta: dict[str, Any]


def construct_subnet(qadg: QADG, params: dict, qparams: dict,
                     keep_masks: dict) -> Subnet:
    sliced, kept = qadg.space.materialize(params, keep_masks)

    int_weights: dict[str, jax.Array] = {}
    scales: dict[str, jax.Array] = {}
    bits: dict[str, float] = {}
    for site in qadg.sites:
        qp: QuantParams = qparams[site.name]
        b = float(bit_width(qp.d, qp.q_m, qp.t))
        bits[site.name] = b
        if site.kind != "weight":
            continue
        for pname in site.quantized_params:
            if pname not in sliced:
                continue
            codes, d = quantize_int(sliced[pname], qp)
            # narrowest container that holds the codes
            nbits = int(np.ceil(b))
            if nbits <= 8:
                store = codes.astype(jnp.int8)
            elif nbits <= 16:
                store = codes.astype(jnp.int16)
            else:
                store = codes.astype(jnp.int32)
            int_weights[pname] = store
            scales[pname] = d

    n_total = qadg.space.total_units()
    n_kept = sum(int(np.sum(np.asarray(keep_masks[f.name]) > 0.5))
                 for f in qadg.space.prunable_families())
    return Subnet(
        params=sliced, int_weights=int_weights, scales=scales, bits=bits,
        kept_units=kept,
        meta={
            "sparsity": 1.0 - n_kept / max(n_total, 1),
            "mean_bits": float(np.mean(list(bits.values()))) if bits else 32.0,
            "n_sites": len(qadg.sites),
        })

"""Pruning search space: minimally-removable structures and their masks.

A `GroupFamily` is a set of structurally-tied parameter slices; each of its
`units` is one minimally removable structure g in the paper's group set G
(Eq 7b counts zeroed units). Members record how a unit maps into each tied
parameter tensor:

    Member(param, axis, unit_size, layout)

- `contiguous`: unit i owns param[..., i*unit_size:(i+1)*unit_size, ...]
  along `axis` (head groups, experts, channel-major flattens).
- `interleaved`: unit i owns every `units`-strided element (channel-last
  spatial flattens: index = spatial * units + i).

All mask/apply/gather operations are static-shaped and jit-friendly; the
Python loop over families unrolls at trace time (family count is a config
constant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Member:
    param: str
    axis: int
    unit_size: int = 1
    layout: str = "contiguous"  # | "interleaved"


@dataclasses.dataclass
class GroupFamily:
    name: str
    units: int
    members: list[Member]
    prunable: bool = True
    kind: str = "channel"  # channel | head_group | expert | state | ...

    def validate(self, params: dict[str, jax.Array]) -> None:
        for m in self.members:
            arr = params[m.param]
            n = arr.shape[m.axis]
            if n != self.units * m.unit_size:
                raise ValueError(
                    f"family {self.name}: member {m.param} axis {m.axis} has "
                    f"dim {n}, expected units({self.units}) * "
                    f"unit_size({m.unit_size})")


def _axis_mask(mask: jax.Array, member: Member, axis_len: int) -> jax.Array:
    """Expand a (units,) mask to a (axis_len,) per-element mask."""
    if member.layout == "contiguous":
        return jnp.repeat(mask, member.unit_size, total_repeat_length=axis_len)
    # interleaved: [s0u0 s0u1 ... s0u{U-1} s1u0 ...]
    return jnp.tile(mask, member.unit_size)[:axis_len]


def _broadcast_to_axis(vec: jax.Array, ndim: int, axis: int) -> jax.Array:
    shape = [1] * ndim
    shape[axis] = vec.shape[0]
    return vec.reshape(shape)


class PruningSpace:
    """The pruning search space over the QADNN (paper: parameter groups G)."""

    def __init__(self, families: list[GroupFamily]):
        names = [f.name for f in families]
        if len(names) != len(set(names)):
            raise ValueError("duplicate family names")
        self.families = families
        self.by_name = {f.name: f for f in families}

    # ---------------------------------------------------------------- masks
    def prunable_families(self) -> list[GroupFamily]:
        return [f for f in self.families if f.prunable]

    def init_masks(self) -> dict[str, jax.Array]:
        return {f.name: jnp.ones((f.units,), jnp.float32)
                for f in self.prunable_families()}

    def total_units(self) -> int:
        return sum(f.units for f in self.prunable_families())

    def apply_masks(self, params: dict[str, jax.Array],
                    masks: dict[str, jax.Array]) -> dict[str, jax.Array]:
        """Multiply every member slice by its unit mask (soft or hard)."""
        out = dict(params)
        for fam in self.prunable_families():
            mask = masks[fam.name]
            for m in fam.members:
                arr = out[m.param]
                am = _axis_mask(mask, m, arr.shape[m.axis])
                out[m.param] = arr * _broadcast_to_axis(
                    am.astype(arr.dtype), arr.ndim, m.axis)
        return out

    # ------------------------------------------------------------- geometry
    def member_view(self, arr: jax.Array, member: Member,
                    units: int) -> jax.Array:
        """Reshape one member tensor to (units, -1): row i = unit i's slice."""
        a = jnp.moveaxis(arr, member.axis, 0)
        n = a.shape[0]
        rest = int(np.prod(a.shape[1:], dtype=np.int64)) if a.ndim > 1 else 1
        a = a.reshape(n, rest)
        if member.layout == "contiguous":
            a = a.reshape(units, member.unit_size * rest)
        else:
            a = a.reshape(member.unit_size, units, rest)
            a = jnp.moveaxis(a, 1, 0).reshape(units, member.unit_size * rest)
        return a

    def group_matrix(self, params: dict[str, jax.Array],
                     family: GroupFamily) -> jax.Array:
        """(units, W) matrix stacking every member slice per unit — the
        [x]_g view used by saliency and the joint-stage update."""
        views = [self.member_view(params[m.param].astype(jnp.float32), m,
                                  family.units)
                 for m in family.members]
        return jnp.concatenate(views, axis=1)

    # ------------------------------------------------------------ subnet cut
    def materialize(self, params: dict[str, jax.Array],
                    masks: dict[str, jax.Array]) -> tuple[
                        dict[str, jax.Array], dict[str, np.ndarray]]:
        """construct_subnet(): physically slice away pruned units.

        Returns (sliced params, kept-unit indices per family). Members of the
        same param from several families are sliced sequentially (each along
        its own axis).
        """
        kept: dict[str, np.ndarray] = {}
        out = dict(params)
        for fam in self.prunable_families():
            mask = np.asarray(masks[fam.name])
            keep_units = np.nonzero(mask > 0.5)[0]
            kept[fam.name] = keep_units
            for m in fam.members:
                arr = out[m.param]
                axis_len = arr.shape[m.axis]
                if m.layout == "contiguous":
                    elem = (keep_units[:, None] * m.unit_size
                            + np.arange(m.unit_size)[None, :]).reshape(-1)
                else:
                    elem = (np.arange(m.unit_size)[:, None] * fam.units
                            + keep_units[None, :]).reshape(-1)
                if elem.size and int(elem.max()) >= axis_len:
                    # Silently truncating here would slice the wrong
                    # elements and ship a corrupted subnet.
                    raise ValueError(
                        f"family {fam.name}: member {m.param} (axis "
                        f"{m.axis}, layout {m.layout}) maps kept units to "
                        f"element index {int(elem.max())}, but the axis has "
                        f"length {axis_len} — mis-specified units"
                        f"({fam.units}) / unit_size({m.unit_size}) / layout")
                out[m.param] = jnp.take(arr, jnp.asarray(elem), axis=m.axis)
        return out, kept

    def sparsity(self, masks: dict[str, jax.Array]) -> jax.Array:
        """Fraction of prunable units currently zeroed (Eq 7b / total)."""
        zeroed = sum(jnp.sum(masks[f.name] <= 0.5)
                     for f in self.prunable_families())
        return zeroed / max(self.total_units(), 1)

    def validate(self, params: dict[str, jax.Array]) -> None:
        for f in self.families:
            f.validate(params)

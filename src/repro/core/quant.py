"""Learnable quantization with parameters (d, q_m, t) — paper §3, Eqs (1)-(6).

The quantizer maps a tensor x through a nonlinear clip

    x~ = sgn(x) * clip_{q_m}^t(|x|),   clip_{q_m}^t(a) = a^t       if a <= q_m
                                                         (q_m)^t   if a >  q_m
then symmetric uniform quantization

    x_Q = d * round(x~ / d)                                         (Eq 2)

The bit width is a *derived* quantity (Eq 3):

    b = log2((q_m)^t / d + 1) + 1

Gradients of x_Q w.r.t. (d, t, q_m) follow the straight-through estimator
(Eqs 4-6); the gradient w.r.t. x is STE identity inside the clip range and
rescaled by the clip boundary outside (standard PACT-style behaviour).

All functions are pure jnp and jit/vmap/pjit friendly. The Pallas-fused
version lives in `repro.kernels`; this module is the mathematical source of
truth (the kernels' ref oracle imports from here).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# Numerical guards: t and q_m pass through powers/logs.
_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Per-layer learnable quantization parameters (pytree).

    Each field is a scalar (per-tensor quantization, as in the paper) held in
    float32 regardless of the activation dtype so that tiny gradient updates
    are not lost to bf16 rounding.
    """

    d: jax.Array    # quantization step size  (> 0)
    q_m: jax.Array  # clip maximum            (> 0)
    t: jax.Array    # shaping exponent        (> 0), t=1 -> uniform

    def tree_flatten(self):
        return (self.d, self.q_m, self.t), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


jax.tree_util.register_pytree_node(
    QuantParams, QuantParams.tree_flatten, QuantParams.tree_unflatten
)


def init_quant_params(
    w: jax.Array | None = None,
    *,
    q_m: float | jax.Array | None = None,
    bits: float = 32.0,
    t: float = 1.0,
) -> QuantParams:
    """Paper Appendix C initialization: t = 1, q_m = max|W|, d chosen so the
    derived bit width equals `bits` (32 for CNNs-from-scratch, 8 for BERT)."""
    if q_m is None:
        if w is None:
            raise ValueError("need either a weight tensor or explicit q_m")
        q_m = jnp.maximum(jnp.max(jnp.abs(w)).astype(jnp.float32), 1e-3)
    q_m = jnp.asarray(q_m, jnp.float32)
    t_arr = jnp.asarray(t, jnp.float32)
    d = step_size_for_bits(q_m, t_arr, jnp.asarray(bits, jnp.float32))
    return QuantParams(d=d, q_m=q_m, t=t_arr)


def bit_width(d: jax.Array, q_m: jax.Array, t: jax.Array) -> jax.Array:
    """Eq (3): b = log2((q_m)^t / d + 1) + 1."""
    peak = jnp.power(jnp.maximum(q_m, _EPS), t)
    return jnp.log2(peak / jnp.maximum(d, _EPS) + 1.0) + 1.0


def step_size_for_bits(q_m: jax.Array, t: jax.Array, bits: jax.Array) -> jax.Array:
    """Invert Eq (3): the d that realizes a given bit width."""
    peak = jnp.power(jnp.maximum(q_m, _EPS), t)
    return peak / (jnp.exp2(bits - 1.0) - 1.0)


def step_size_bounds(
    q_m: jax.Array, t: jax.Array, b_l: jax.Array, b_u: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """[d_min, d_max] such that b in [b_l, b_u] (Alg 3 line 3).

    b is decreasing in d, so b <= b_u  <=>  d >= d(b_u)  and
    b >= b_l  <=>  d <= d(b_l)."""
    d_min = step_size_for_bits(q_m, t, b_u)
    d_max = step_size_for_bits(q_m, t, b_l)
    return d_min, d_max


def clip_qmt(x_abs: jax.Array, q_m: jax.Array, t: jax.Array) -> jax.Array:
    """clip_{q_m}^t(|x|) of Eq (13) — the nonlinear clipped magnitude."""
    q_m = jnp.maximum(q_m, _EPS)
    a = jnp.minimum(x_abs, q_m)
    return jnp.power(jnp.maximum(a, _EPS), t) * (x_abs > 0)


def residual(x_abs: jax.Array, d: jax.Array, q_m: jax.Array, t: jax.Array) -> jax.Array:
    """R(x) of Eq (14): round(x~/d) - x~/d for the clipped magnitude."""
    xt = clip_qmt(x_abs, q_m, t)
    r = xt / jnp.maximum(d, _EPS)
    return jnp.round(r) - r


def _fake_quant_fwd_math(x, d, q_m, t):
    """Shared forward math (Eqs 1-2). Returns x_Q with the dtype of x."""
    d32 = jnp.maximum(d.astype(jnp.float32), _EPS)
    sign = jnp.sign(x).astype(jnp.float32)
    xt = clip_qmt(jnp.abs(x).astype(jnp.float32), q_m.astype(jnp.float32),
                  t.astype(jnp.float32))
    xq = d32 * jnp.round(xt / d32) * sign
    return xq.astype(x.dtype)


@jax.custom_vjp
def fake_quant(x: jax.Array, d: jax.Array, q_m: jax.Array, t: jax.Array) -> jax.Array:
    """Differentiable quantize-dequantize with learnable (d, q_m, t).

    Forward: Eqs (1)-(2). Backward: STE for x, Eqs (4)-(6) for the scalars.
    """
    return _fake_quant_fwd_math(x, d, q_m, t)


def _fake_quant_fwd(x, d, q_m, t):
    y = _fake_quant_fwd_math(x, d, q_m, t)
    return y, (x, d, q_m, t)


def _fake_quant_bwd(res, g):
    x, d, q_m, t = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    d32 = jnp.maximum(d.astype(jnp.float32), _EPS)
    qm32 = jnp.maximum(q_m.astype(jnp.float32), _EPS)
    t32 = t.astype(jnp.float32)

    ax = jnp.abs(x32)
    sign = jnp.sign(x32)
    inside = ax <= qm32
    safe_ax = jnp.maximum(ax, _EPS)

    # --- dL/dx: STE. Inside the clip: d x_Q/dx ~ d x~/dx = t*|x|^{t-1}
    # treated as 1 by the STE (the paper's STE passes the gradient through
    # the round *and* the power; outside the clip the gradient is 0).
    dx = jnp.where(inside, g32, 0.0).astype(x.dtype)

    # --- Eq (4): dx_Q/dd = sgn(x) * (round(v) - v), v = clip^t/d.
    v = clip_qmt(ax, qm32, t32) / d32
    dd_elem = sign * (jnp.round(v) - v)
    dd = jnp.sum(g32 * dd_elem).astype(jnp.float32)

    # --- Eq (5): dx_Q/dt = sgn(x) * clip^t * log(clip_base)
    base = jnp.where(inside, safe_ax, qm32)
    dt_elem = sign * jnp.power(base, t32) * jnp.log(base)
    dt = jnp.sum(g32 * dt_elem).astype(jnp.float32)

    # --- Eq (6): dx_Q/dq_m = 0 inside, sgn(x)*t*q_m^{t-1} outside.
    dqm_elem = jnp.where(inside, 0.0, sign * t32 * jnp.power(qm32, t32 - 1.0))
    dqm = jnp.sum(g32 * dqm_elem).astype(jnp.float32)

    return dx, dd.reshape(d.shape), dqm.reshape(q_m.shape), dt.reshape(t.shape)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def quantize_int(x: jax.Array, qp: QuantParams,
                 bits: float | jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Deployment-path quantization: integer codes + scale.

    Returns (codes int8/int16/int32 depending on derived bits, scale d).
    Codes satisfy x_Q = codes * d (on the nonlinearly-mapped magnitude).

    Codes are clamped to the symmetric range of the ceil(bits)-wide
    container, ±(2^(ceil(b)-1)-1): at the bit-constraint boundary (d
    projected to exactly the layerwise b_u, then q_m nudged by a later
    step) `round(xt/d)` can land on 2^(b-1) — e.g. 128 at 8 bits — which
    would wrap negative in the narrow integer cast downstream. `bits`
    overrides the derived width when the caller has already fixed the
    container (default: Eq 3 on `qp`)."""
    d32 = jnp.maximum(qp.d.astype(jnp.float32), _EPS)
    sign = jnp.sign(x).astype(jnp.float32)
    xt = clip_qmt(jnp.abs(x).astype(jnp.float32), qp.q_m, qp.t)
    codes = jnp.round(xt / d32) * sign
    b = bit_width(qp.d, qp.q_m, qp.t) if bits is None \
        else jnp.asarray(bits, jnp.float32)
    cmax = jnp.exp2(jnp.ceil(b) - 1.0) - 1.0
    codes = jnp.clip(codes, -cmax, cmax)
    return codes, d32


def dequantize_int(codes: jax.Array, d: jax.Array,
                   out_dtype=jnp.float32) -> jax.Array:
    """Reconstruct the effective weight x_Q = codes * d.

    Note: per Eqs (1)-(2) the quantized value x_Q lives in the *shaped*
    domain (the t-companding is part of the learned effective weight and is
    never inverted at inference) — so dequantization is a single multiply."""
    return (codes * d).astype(out_dtype)


def storage_bits(qp: QuantParams) -> jax.Array:
    """Integer bits needed to store codes of this quantizer (ceil of Eq 3)."""
    return jnp.ceil(bit_width(qp.d, qp.q_m, qp.t))


# ------------------------------------------------------- sub-byte packing
# Storage widths the packed serving path realizes. A site whose learned
# width lands between two entries rounds up to the next one (ceil 5..8 all
# store at 8); widths above 8 keep their unpacked int16/int32 container.
PACKED_STORAGE_BITS = (2, 3, 4, 8)


def packed_storage_bits(bits: float) -> int | None:
    """Packed container width for a learned bit width, or None if the
    codes need more than 8 bits (stay on the unpacked int16/int32 path)."""
    nb = int(jnp.ceil(jnp.asarray(bits, jnp.float32)))
    for cand in PACKED_STORAGE_BITS:
        if nb <= cand:
            return cand
    return None


def _codes_per_word(bits: int) -> int:
    if not 2 <= int(bits) <= 8:
        raise ValueError(f"packed bits must be in [2, 8], got {bits}")
    return 32 // int(bits)


def pack_codes(codes: jax.Array, bits: int, *, axis: int = 0) -> jax.Array:
    """Bit-pack signed integer codes into an int32 word stream.

    Each 32-bit word holds ``32 // bits`` codes (16/10/8/4 for bits
    2/3/4/8) as ``bits``-wide two's-complement fields, least-significant
    field first, packed along `axis` (the reduction/K axis for weight
    matrices, so the per-column scale epilogue is untouched). A trailing
    partial word is zero-padded — zero codes dequantize to exact zeros,
    so the padding is inert in any matmul whose LHS is zero-padded to
    match. Codes must already fit ±(2^(bits-1)-1) (`quantize_int` clamps
    to exactly that range)."""
    bits = int(bits)
    cpw = _codes_per_word(bits)
    c = jnp.moveaxis(jnp.asarray(codes), axis, 0).astype(jnp.int32)
    pad = (-c.shape[0]) % cpw
    if pad:
        c = jnp.pad(c, ((0, pad),) + ((0, 0),) * (c.ndim - 1))
    mask = (1 << bits) - 1
    c = (c & mask).reshape((c.shape[0] // cpw, cpw) + c.shape[1:])
    shifts = (jnp.arange(cpw, dtype=jnp.int32) * bits).reshape(
        (1, cpw) + (1,) * (c.ndim - 2))
    # fields are disjoint, so the sum is a bitwise OR (int32 wraparound on
    # the sign bit of the top field is the intended two's-complement word)
    words = jnp.sum(c << shifts, axis=1, dtype=jnp.int32)
    return jnp.moveaxis(words, 0, axis)


def unpack_codes(packed: jax.Array, bits: int, size: int, *,
                 axis: int = 0) -> jax.Array:
    """Invert `pack_codes`: int32 words -> sign-extended int32 codes.

    `size` is the unpadded code count along `axis` (the word stream holds
    ceil(size / (32//bits)) words; the zero-filled tail is sliced off)."""
    bits = int(bits)
    cpw = _codes_per_word(bits)
    w = jnp.moveaxis(jnp.asarray(packed, jnp.int32), axis, 0)
    shifts = (jnp.arange(cpw, dtype=jnp.int32) * bits).reshape(
        (1, cpw) + (1,) * (w.ndim - 1))
    mask = (1 << bits) - 1
    vals = (w[:, None] >> shifts) & mask
    sgn = 1 << (bits - 1)
    vals = (vals ^ sgn) - sgn   # sign-extend the bits-wide field
    out = vals.reshape((w.shape[0] * cpw,) + w.shape[1:])[:size]
    return jnp.moveaxis(out, 0, axis)


# Storage widths the paged KV arena can hold codes at (DESIGN.md §4.11).
# Weight containers pack along the GEMM K axis into int32 words
# (`pack_codes`); KV pages instead pack along d_head into int8 bytes —
# the page is the streaming unit and a byte stream keeps the in-kernel
# nibble unpack a shift pair instead of a word-field walk.
KV_STORAGE_BITS = (4, 8)


def kv_quant_encode(x: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric absmax quantization for KV-cache pages.

    x: (..., dh) float rows (one K or V head-row per leading index).
    Returns (codes int8, scale f32 (...,)): scale = absmax / qmax per
    row so every write is independent (no page rescaling when a new row
    lands — the property that makes incremental decode writes exact).
    All-zero rows encode to codes 0 / scale 0 and decode to exact zeros,
    preserving the arena zero-init invariant through a quantize-dequantize
    round trip. bits=4 nibble-packs code pairs along the last axis
    ((..., dh//2) bytes, low nibble first)."""
    bits = int(bits)
    if bits not in KV_STORAGE_BITS:
        raise ValueError(f"kv bits must be one of {KV_STORAGE_BITS}, "
                         f"got {bits}")
    qmax = (1 << (bits - 1)) - 1
    x32 = jnp.asarray(x).astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1) / qmax
    d = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(x32 / d[..., None]),
                     -qmax, qmax).astype(jnp.int32)
    if bits == 4:
        if x32.shape[-1] % 2:
            raise ValueError(f"kv bits=4 packs code pairs; d_head="
                             f"{x32.shape[-1]} must be even")
        codes = (codes[..., 0::2] & 0xF) | ((codes[..., 1::2] & 0xF) << 4)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)


def kv_quant_decode(codes: jax.Array, scale: jax.Array, bits: int
                    ) -> jax.Array:
    """Invert `kv_quant_encode`: int8 codes + per-row scales -> f32 rows.

    Exact for zero rows (scale 0 times codes 0) and idempotent under
    re-encode at the same bits (round(c*d/d) == c), so a gather ->
    compute -> re-encode scatter of untouched rows is a no-op."""
    bits = int(bits)
    w = jnp.asarray(codes).astype(jnp.int32)
    if bits == 4:
        lo = (w << 28) >> 28          # sign-extend the low nibble
        hi = (w << 24) >> 28          # arithmetic shift: high nibble
        w = jnp.stack([lo, hi], axis=-1).reshape(
            w.shape[:-1] + (w.shape[-1] * 2,))
    elif bits != 8:
        raise ValueError(f"kv bits must be one of {KV_STORAGE_BITS}, "
                         f"got {bits}")
    return w.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def tree_bit_widths(qparams: dict[str, QuantParams]) -> dict[str, jax.Array]:
    return {k: bit_width(v.d, v.q_m, v.t) for k, v in qparams.items()}


def project_step_size(qp: QuantParams, b_l: float | jax.Array,
                      b_u: float | jax.Array) -> QuantParams:
    """PPSG projection (Alg 3 lines 3-4): clamp d into [d_min, d_max].

    Only d is projected — q_m and t are left untouched (paper §5.1: their
    exponential gradient terms make abrupt projection destabilizing)."""
    d_min, d_max = step_size_bounds(qp.q_m, qp.t,
                                    jnp.asarray(b_l, jnp.float32),
                                    jnp.asarray(b_u, jnp.float32))
    return QuantParams(d=jnp.clip(qp.d, d_min, d_max), q_m=qp.q_m, t=qp.t)


def positivity_guard(qp: QuantParams) -> QuantParams:
    """Keep the parameterization in its valid open domain after an SGD step."""
    return QuantParams(
        d=jnp.maximum(qp.d, 1e-8),
        q_m=jnp.maximum(qp.q_m, 1e-6),
        t=jnp.clip(qp.t, 0.05, 4.0),
    )

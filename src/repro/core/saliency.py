"""Group saliency scores — HESSO [13] style, used at Alg 2 line 11.

For each minimally-removable structure (unit) g the score mixes three
signals computed on the (units, W) group matrix view:

  magnitude   : ||x_g||_2 / sqrt(W)          (bigger -> more important)
  cosine      : |cos(x_g, grad_g)|           (alignment of weight & gradient:
                                              low alignment -> step won't
                                              restore the group if removed)
  first-order : |<grad_g, x_g>|              (Taylor expansion of loss change
                                              when zeroing the group)

Scores are normalized per family (z-score) before global ranking so
families of very different widths compete fairly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.groups import GroupFamily, PruningSpace

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class SaliencyConfig:
    w_magnitude: float = 1.0
    w_cosine: float = 0.25
    w_taylor: float = 1.0
    normalize: bool = True


def family_scores(space: PruningSpace, family: GroupFamily,
                  params: dict, grads: dict,
                  cfg: SaliencyConfig = SaliencyConfig(),
                  reduce=None) -> jax.Array:
    """(units,) saliency for one family. Higher = more important.

    Computed as per-member fused reductions (sum of squares / dot per unit,
    accumulated across members) — NEVER as a concatenated (units, W) group
    matrix: concatenating members with different shardings forces GSPMD to
    replicate every weight in f32 (measured ~100 GB/device on the 398B
    configs).

    `reduce`: optional cross-replica reduction point (see
    `distributed.collectives.replicate_stats`) applied to the member
    tensors BEFORE the per-unit reductions — under a device mesh this
    pins each input to the replicated layout so the unit sums run locally
    in a mesh-size-invariant order and every replica ranks units from
    bit-identical scores."""
    u = family.units

    def unit_reduce(val, m):
        """Sum `val` over every axis but the member axis, then fold the
        unit grouping — all in the tensor's ORIGINAL layout. (member_view's
        moveaxis+reshape flattens sharded dims, which GSPMD can only do by
        all-gathering — measured ~150 GB/device of gathered f32 expert
        stacks on jamba-398b.)"""
        axes = tuple(i for i in range(val.ndim) if i != m.axis)
        v = jnp.sum(val, axis=axes)               # (axis_len,)
        if m.unit_size == 1:
            return v
        if m.layout == "contiguous":
            return jnp.sum(v.reshape(u, m.unit_size), axis=1)
        return jnp.sum(v.reshape(m.unit_size, u), axis=0)

    dot = jnp.zeros((u,), jnp.float32)
    x2 = jnp.zeros((u,), jnp.float32)
    g2 = jnp.zeros((u,), jnp.float32)
    w = 0
    for m in family.members:
        xv = params[m.param].astype(jnp.float32)
        gv = grads[m.param].astype(jnp.float32)
        if reduce is not None:
            xv, gv = reduce(xv), reduce(gv)
        dot = dot + unit_reduce(xv * gv, m)
        x2 = x2 + unit_reduce(jnp.square(xv), m)
        g2 = g2 + unit_reduce(jnp.square(gv), m)
        w += xv.size // u

    mag = jnp.sqrt(x2) / jnp.sqrt(float(max(w, 1)))
    cos = jnp.abs(dot) / jnp.maximum(jnp.sqrt(x2 * g2), _EPS)
    taylor = jnp.abs(dot)

    def norm(v):
        if not cfg.normalize:
            return v
        mu = jnp.mean(v)
        sd = jnp.std(v) + _EPS
        return (v - mu) / sd

    return (cfg.w_magnitude * norm(mag)
            + cfg.w_cosine * norm(cos)
            + cfg.w_taylor * norm(taylor))


def global_redundancy_partition(space: PruningSpace, params: dict, grads: dict,
                                n_redundant: jax.Array,
                                cfg: SaliencyConfig = SaliencyConfig(),
                                frozen: dict | None = None,
                                pinned: dict | None = None,
                                reduce=None
                                ) -> dict[str, jax.Array]:
    """Alg 2 line 12: pick the `n_redundant` globally lowest-saliency units.

    Returns per-family float masks: 1.0 = redundant (in G_R), 0.0 = important.
    `n_redundant` may be a traced integer (the progressive schedule), so the
    partition is computed by global rank rather than a static top-k.

    `frozen`: per-family masks of units that must stay important — their
    score is lifted to +inf.
    `pinned`: per-family masks of units already chosen as redundant in an
    earlier period (sticky pruning) — their score is sunk to -inf so they
    stay in G_R *and count toward* n_redundant (the progressive schedule
    stays exact).
    `reduce`: cross-replica reduction hook threaded to `family_scores`
    (replica-consistent ranking under a device mesh).
    """
    fams = space.prunable_families()
    scores = []
    for fam in fams:
        s = family_scores(space, fam, params, grads, cfg, reduce=reduce)
        if frozen is not None and fam.name in frozen:
            s = jnp.where(frozen[fam.name] > 0.5, jnp.inf, s)
        if pinned is not None and fam.name in pinned:
            s = jnp.where(pinned[fam.name] > 0.5, -jnp.inf, s)
        scores.append(s)
    flat = jnp.concatenate(scores) if scores else jnp.zeros((0,))
    # rank 0 = least salient
    order = jnp.argsort(flat)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(flat.shape[0]))
    redundant_flat = (ranks < n_redundant).astype(jnp.float32)

    out = {}
    off = 0
    for fam in fams:
        out[fam.name] = redundant_flat[off: off + fam.units]
        off += fam.units
    return out

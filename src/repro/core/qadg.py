"""Quantization-Aware Dependency Graph analysis — paper §4, Algorithm 1.

Input: the trace graph of a QADNN (a model whose GraphBuilder trace has had
`attach_weight_quant` / `insert_act_quant` branches grown onto it).

Phase 1 (lines 3-8):  find the root vertex of every *attached branch*
(weight quantization), merge the branch vertices into the root — the merged
vertex absorbs the branch's (d, q_m, t) parameters. This de-duplicates the
shared `d` vertex and eliminates the shape-ambiguous `q_reshape`.

Phase 2 (lines 9-14): find (root, end) pairs of every *inserted branch*
(activation quantization), merge the in-between vertices into the end
vertex, and reconnect root -> merged end to preserve connectivity.

Phase 3 (line 15): run the dependency-graph analysis of OTOv2 [12] on the
cleaned graph to derive the pruning search space: union-find over *channel
spaces* — producers open a space, dimension-preserving ops propagate it,
`add` unions its inputs' spaces, composite vertices contribute their own
structured FamilySpec and tie their boundary axes into the residual space.

Output: `QADG` = (cleaned graph, PruningSpace, quantization sites).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.graph import (ADD_OPS, COMPOSITE_OPS, JOINT_OPS, PRODUCER_OPS,
                              SINK_OPS, FamilySpec, TraceGraph, Vertex)
from repro.core.groups import GroupFamily, Member, PruningSpace


@dataclasses.dataclass(frozen=True)
class QuantSite:
    """One parameterized quantizer surviving QADG analysis (a layer index
    i in the paper's set L). Param names address the model pytree."""
    name: str           # qprefix, e.g. "layers.0.mlp.w_in.wq"
    target: str         # vertex id the quantizer is fused into
    kind: str           # "weight" | "act"
    d: str
    q_m: str
    t: str
    # parameters whose values flow through this quantizer (weight quant);
    # empty for activation quantizers.
    quantized_params: tuple[str, ...] = ()


@dataclasses.dataclass
class QADG:
    graph: TraceGraph
    space: PruningSpace
    sites: list[QuantSite]

    def site_by_name(self, name: str) -> QuantSite:
        for s in self.sites:
            if s.name == name:
                return s
        raise KeyError(name)


# --------------------------------------------------------------------------
# Phase 1 + 2: branch merging
# --------------------------------------------------------------------------
def _collect_branch_params(graph: TraceGraph, vids: list[str]) -> dict:
    names = {}
    for vid in vids:
        v = graph.vertices[vid]
        for key in ("d", "q_m", "t"):
            if key in v.params:
                names[key] = v.params[key]
    return names


def merge_attached_branches(graph: TraceGraph) -> list[QuantSite]:
    """Alg 1 lines 3-8. Returns the weight-quant sites.

    Branches are grouped by (root vertex, qprefix): a composite root
    (attention, MoE, ...) carries one attached branch per weight tensor."""
    by_key: dict[tuple[str, str], list[str]] = {}
    for vid, v in graph.vertices.items():
        if v.is_quant and v.meta.get("qbranch") == "attached":
            key = (v.meta["qroot"], v.meta.get("qprefix") or v.meta["qroot"])
            by_key.setdefault(key, []).append(vid)

    sites = []
    for (root_vid, qprefix), branch in sorted(by_key.items()):
        pnames = _collect_branch_params(graph, branch)
        root = graph.vertices[root_vid]
        target = None
        for vid in branch:
            target = target or graph.vertices[vid].meta.get("qtarget")
        # Merge: absorb the branch into the root vertex.
        for vid in branch:
            graph.remove_vertex(vid)
        root.meta.setdefault("quant_weight_params", {})[qprefix] = pnames
        if target is None:
            # plain producer: the weight flows through (biases stay fp)
            wparams = tuple(v for k, v in sorted(root.params.items())
                            if k == "w")
            wparams = wparams or tuple(sorted(root.params.values()))
        else:
            wparams = (target,)
        sites.append(QuantSite(
            name=qprefix, target=root_vid, kind="weight",
            d=pnames["d"], q_m=pnames["q_m"], t=pnames["t"],
            quantized_params=wparams,
        ))
    return sites


def merge_inserted_branches(graph: TraceGraph) -> list[QuantSite]:
    """Alg 1 lines 9-14. Returns the activation-quant sites."""
    by_pair: dict[tuple[str, str], list[str]] = {}
    for vid, v in graph.vertices.items():
        if v.is_quant and v.meta.get("qbranch") == "inserted":
            by_pair.setdefault((v.meta["qroot"], v.meta["qend"]), []).append(vid)

    sites = []
    for (root_vid, end_vid), branch in sorted(by_pair.items()):
        pnames = _collect_branch_params(graph, branch)
        end = graph.vertices[end_vid]
        qprefix = end.meta.get("act_quant")
        for vid in branch:
            graph.remove_vertex(vid)
        # line 13: reconnect root to the merged end vertex.
        if end_vid not in graph.succ[root_vid]:
            graph.connect(root_vid, end_vid)
        end.meta["quant_act_params"] = pnames
        sites.append(QuantSite(
            name=qprefix or f"{end_vid}.aq",
            target=end_vid, kind="act",
            d=pnames["d"], q_m=pnames["q_m"], t=pnames["t"],
        ))
    return sites


# --------------------------------------------------------------------------
# Phase 3: dependency analysis over the cleaned graph (OTOv2-style)
# --------------------------------------------------------------------------
class _UnionFind:
    def __init__(self):
        self.parent: dict[int, int] = {}

    def make(self, x: int):
        self.parent.setdefault(x, x)

    def find(self, x: int) -> int:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra
        return ra


@dataclasses.dataclass
class _Space:
    sid: int
    dim: Optional[int] = None           # channel count
    producers: list[tuple[str, int]] = dataclasses.field(default_factory=list)
    consumers: list[tuple[str, int, int, str]] = dataclasses.field(
        default_factory=list)           # (param, axis, unit_size, layout)
    aux: list[tuple[str, int]] = dataclasses.field(default_factory=list)
    prunable: bool = True
    tag: str = ""


def dependency_analysis(graph: TraceGraph) -> PruningSpace:
    """OTOv2 [12]-style analysis specialized to the cleaned QADG."""
    uf = _UnionFind()
    spaces: dict[int, _Space] = {}
    out_space: dict[str, int] = {}
    out_mult: dict[str, int] = {}      # flatten factor along the path
    out_layout: dict[str, str] = {}
    next_sid = [0]

    def new_space(dim=None, prunable=True, tag="") -> int:
        sid = next_sid[0]
        next_sid[0] += 1
        uf.make(sid)
        spaces[sid] = _Space(sid, dim=dim, prunable=prunable, tag=tag)
        return sid

    def space(sid: int) -> _Space:
        return spaces[uf.find(sid)]

    def merge_spaces(a: int, b: int) -> int:
        ra, rb = uf.find(a), uf.find(b)
        if ra == rb:
            return ra
        sa, sb = spaces[ra], spaces[rb]
        root = uf.union(ra, rb)
        keep, drop = (sa, sb) if root == ra else (sb, sa)
        keep.producers += drop.producers
        keep.consumers += drop.consumers
        keep.aux += drop.aux
        keep.prunable = keep.prunable and drop.prunable
        if keep.dim is None:
            keep.dim = drop.dim
        elif drop.dim is not None and keep.dim != drop.dim:
            raise ValueError(
                f"dependency analysis: tied spaces with dims {keep.dim} != "
                f"{drop.dim} ({keep.tag} vs {drop.tag})")
        del spaces[drop.sid if drop.sid != root else keep.sid]
        return root

    for vid in graph.topo_order():
        v = graph.vertices[vid]
        preds = graph.pred[vid]
        pin = out_space.get(preds[0]) if preds else None

        if v.op == "identity" and not preds:          # model input
            sid = new_space(dim=v.meta.get("dim"), prunable=False, tag=vid)
            out_space[vid] = sid
            out_mult[vid] = 1
            out_layout[vid] = "contiguous"

        elif v.op in PRODUCER_OPS:
            # consume predecessor space along in_axis
            if v.op != "embedding" and pin is not None and v.in_axis is not None:
                space(pin).consumers.append(
                    (v.params["w"], v.in_axis, out_mult[preds[0]],
                     out_layout[preds[0]]))
            sid = new_space(dim=v.meta.get("out_dim"), tag=vid)
            space(sid).producers.append((v.params["w"], v.out_axis))
            if "b" in v.params:
                space(sid).aux.append(
                    (v.params["b"], v.meta.get("bias_axis", 0)))
            if v.meta.get("non_prunable"):
                space(sid).prunable = False
            out_space[vid] = sid
            out_mult[vid] = 1
            out_layout[vid] = "contiguous"

        elif v.op in JOINT_OPS or v.op in ("bn",):
            assert pin is not None, f"{vid}: joint op with no input"
            s = space(pin)
            for key in ("scale", "bias"):
                if key in v.params:
                    # stacked (L, D) norm scales carry the channel on axis 1
                    s.aux.append((v.params[key],
                                  v.meta.get("param_axis", 0)))
            out_space[vid] = pin
            m = out_mult[preds[0]]
            lay = out_layout[preds[0]]
            if "flatten_factor" in v.meta:
                m *= int(v.meta["flatten_factor"])
                lay = v.meta.get("flatten_layout", "interleaved")
            out_mult[vid] = m
            out_layout[vid] = lay

        elif v.op in ADD_OPS:
            sids = [out_space[p] for p in preds]
            sid = sids[0]
            for other in sids[1:]:
                sid = merge_spaces(sid, other)
            out_space[vid] = sid
            out_mult[vid] = out_mult[preds[0]]
            out_layout[vid] = out_layout[preds[0]]

        elif v.op in COMPOSITE_OPS:
            # boundary axes tie into the predecessor (residual) space
            assert pin is not None
            s = space(pin)
            for pname, axis in v.meta.get("in_members", []):
                s.consumers.append((pname, axis, 1, "contiguous"))
            for pname, axis in v.meta.get("resid_members", []):
                s.producers.append((pname, axis))
            out_space[vid] = pin      # composite returns to residual stream
            out_mult[vid] = out_mult[preds[0]]
            out_layout[vid] = out_layout[preds[0]]

        elif v.op in SINK_OPS:
            if pin is not None:
                space(pin).prunable = False
            out_space[vid] = pin if pin is not None else new_space(
                prunable=False, tag=vid)
            out_mult[vid] = out_mult.get(preds[0], 1) if preds else 1
            out_layout[vid] = out_layout.get(preds[0], "contiguous")

        elif v.is_quant:
            raise ValueError(
                f"quant vertex {vid} survived branch merging — run "
                "merge_attached_branches/merge_inserted_branches first")
        else:
            raise ValueError(f"unhandled op {v.op!r} at {vid}")

    # ---- emit families ----
    families: list[GroupFamily] = []
    seen_roots = set()
    for sid in list(spaces):
        root = uf.find(sid)
        if root in seen_roots:
            continue
        seen_roots.add(root)
        s = spaces[root]
        if not s.producers and not s.consumers:
            continue
        if s.dim is None:
            continue
        members = [Member(p, ax, 1, "contiguous") for p, ax in s.producers]
        members += [Member(p, ax, us, lay) for p, ax, us, lay in s.consumers]
        members += [Member(p, ax, 1, "contiguous") for p, ax in s.aux]
        if not members:
            continue
        families.append(GroupFamily(
            name=f"space.{root}.{s.tag or 'anon'}",
            units=s.dim, members=members, prunable=s.prunable,
            kind="channel"))

    # composite vertices contribute their own structured families verbatim
    for vid, v in graph.vertices.items():
        if v.spec is not None:
            sp = v.spec
            families.append(GroupFamily(
                name=sp.name, units=sp.units,
                members=[Member(p, ax, us, "contiguous")
                         for p, ax, us in sp.members],
                prunable=sp.prunable, kind=sp.kind))

    return PruningSpace(families)


# --------------------------------------------------------------------------
# Algorithm 1, end to end
# --------------------------------------------------------------------------
def build_qadg(graph: TraceGraph) -> QADG:
    # NB: no topo validation before merging — attached branches are cyclic
    # by construction (root -> ... -> mul -> root); Alg 1 removes the cycle.
    w_sites = merge_attached_branches(graph)   # lines 3-8
    a_sites = merge_inserted_branches(graph)   # lines 9-14
    graph.validate()                           # acyclic + connected again
    space = dependency_analysis(graph)         # line 15
    return QADG(graph=graph, space=space, sites=w_sites + a_sites)

"""QASSO: Quantization-Aware Structured Sparse Optimizer (paper §5, Alg 2-4).

Solves   min f(x, d, q_m, t)
         s.t. Card{g in G : [x]_g = 0} = K          (Eq 7b)
              b_i in [b_l, b_u]  for i in L          (Eq 7c)

through four stages driven by the step counter (all jit-compatible; the
stage switch is a lax.switch and period boundaries are lax.cond):

  warm-up     [0, K_w)                      : base optimizer on everything.
  projection  [K_w, K_w + B*K_b)            : PPSG (Alg 3) — SGD on
              (d, q_m, t), then project *only d* into the [d_min, d_max]
              implied by the progressively-shrinking range [b_l, b_u - p*b_r].
  joint       [.., + P*K_p)                 : saliency partition G_I / G_R
              per period; G_I gets the base step (Eq 8); G_R additionally
              forgets the *quantized* value -gamma*[x_Q]_g (Eq 9) with the
              angle-based gamma (Eq 16) / d (Eq 17) rules, kept feasible by
              the adaptive Alg 4 rescaling; (t, q_m) get SGD (line 14).
  cool-down   [.., total)                   : redundant groups hard-zeroed,
              (d*, q_m*, t*) frozen, G_I trains to convergence (line 22).

Deviations from the paper are documented inline and in DESIGN.md §2.2:
- alpha*||grad|| in Eqs 16/17 uses the scheduled lr and the raw gradient
  (the theory assumes SGD; we allow Adam-family base optimizers).
- Redundant partitions are sticky across periods (monotone pruning), the
  standard OTO-family behaviour.
- Non-quantized params (norm scales, biases) in redundant groups forget
  their raw value at the uniform case-1 rate (x_Q := x when no quantizer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core.groups import GroupFamily, Member, PruningSpace, _axis_mask, \
    _broadcast_to_axis
from repro.core.qadg import QuantSite
from repro.core.saliency import SaliencyConfig, global_redundancy_partition
from repro.optim.base import Optimizer, get_optimizer, tree_add

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class QASSOConfig:
    # Eq 7b / 7c targets
    target_sparsity: float = 0.5          # K, fraction of prunable units
    bit_lower: float = 4.0                # b_l
    bit_upper: float = 16.0               # b_u (initial, before reduction)
    # Alg 2 schedule
    warmup_steps: int = 100               # K_w
    projection_periods: int = 5           # B
    projection_steps: int = 50            # K_b
    bit_reduction: float = 2.0            # b_r
    pruning_periods: int = 5              # P
    pruning_steps: int = 50               # K_p
    cooldown_steps: int = 200
    # Eq 16/17 + Alg 4 constants (paper Appendix B)
    eta: float = 0.9
    xi: float = 0.999
    eps: float = 1e-8
    beta: float = 0.5
    # lrs
    lr_quant: float = 1e-4                # Appendix C: constant for (d,q_m,t)
    base_optimizer: str = "adamw"
    grad_clip: float = 0.0
    saliency: SaliencyConfig = dataclasses.field(default_factory=SaliencyConfig)

    # -- derived boundaries --
    @property
    def warmup_end(self) -> int:
        return self.warmup_steps

    @property
    def projection_end(self) -> int:
        return self.warmup_steps + self.projection_periods * self.projection_steps

    @property
    def joint_end(self) -> int:
        return self.projection_end + self.pruning_periods * self.pruning_steps

    @property
    def total_steps(self) -> int:
        return self.joint_end + self.cooldown_steps

    @property
    def bit_upper_final(self) -> float:
        return max(self.bit_upper - self.bit_reduction * self.projection_periods,
                   self.bit_lower)


class QASSOState(NamedTuple):
    step: jax.Array
    base: Any                      # base optimizer state over x
    redundant: dict[str, jax.Array]   # per-family: 1.0 = in G_R this period
    keep_mask: dict[str, jax.Array]   # per-family: 1.0 = kept (hard, set at joint end)
    gamma: jax.Array               # (num_weight_sites,) last forget rates


class QASSO:
    """Usage (mirrors the paper's Framework Usage box):

        qasso = QASSO(qadg.space, qadg.sites, cfg, lr_schedule)
        state = qasso.init(params, qparams)
        ...
        (loss, (gx, gq)) = value_and_grad(f, (0, 1))(params, qparams, batch)
        params, qparams, state, metrics = qasso.update(
            params, qparams, gx, gq, state)
    """

    def __init__(self, space: PruningSpace, sites: list[QuantSite],
                 cfg: QASSOConfig,
                 lr_schedule: Callable[[jax.Array], jax.Array]):
        self.space = space
        self.sites = list(sites)
        self.weight_sites = [s for s in sites if s.kind == "weight"]
        self.act_sites = [s for s in sites if s.kind == "act"]
        self.cfg = cfg
        self.lr_schedule = lr_schedule
        self.base: Optimizer = get_optimizer(cfg.base_optimizer)
        self.mesh = None
        self._stat_reduce = lambda x: x
        # param -> [(family, member)] covering map (prunable families only)
        self.covering: dict[str, list[tuple[GroupFamily, Member]]] = {}
        for fam in space.prunable_families():
            for m in fam.members:
                self.covering.setdefault(m.param, []).append((fam, m))
        self.total_units = space.total_units()
        self.k_units = int(round(cfg.target_sparsity * self.total_units))
        self.site_of_param = {p: s.name for s in self.weight_sites
                              for p in s.quantized_params}

    # ----------------------------------------------------------- sharding
    def replica_consistent(self, mesh) -> "QASSO":
        """Pin the optimizer's control statistics to `mesh`-replicated
        layouts (DESIGN.md §5): the seven Eq 15-17 reductions per weight
        site and the saliency accumulators get an explicit cross-replica
        all-reduce (`collectives.replicate_stats`) before any decision —
        partition ranking, bit-width projection, cooldown hard-zeroing —
        consumes them. Without this GSPMD may combine partial sums at
        replica-dependent points and the replicas drift onto different
        subnets. Call before tracing the sharded train step."""
        from repro.distributed.collectives import replicate_stats
        self.mesh = mesh
        self._stat_reduce = replicate_stats(mesh)
        return self

    # ------------------------------------------------------------------ init
    def init(self, params: dict, qparams: dict) -> QASSOState:
        del qparams
        masks = {f.name: jnp.zeros((f.units,), jnp.float32)
                 for f in self.space.prunable_families()}
        keep = {f.name: jnp.ones((f.units,), jnp.float32)
                for f in self.space.prunable_families()}
        return QASSOState(
            step=jnp.zeros((), jnp.int32),
            base=self.base.init(params),
            redundant=masks,
            keep_mask=keep,
            gamma=jnp.zeros((max(len(self.weight_sites), 1),), jnp.float32),
        )

    # ------------------------------------------------------- mask utilities
    def _elem_mask(self, pname: str, unit_masks: dict[str, jax.Array],
                   arr: jax.Array) -> jax.Array:
        """Elementwise mask for `pname`: max over covering families (an
        element is flagged if ANY covering unit is flagged)."""
        m = None
        for fam, mem in self.covering.get(pname, []):
            am = _axis_mask(unit_masks[fam.name], mem, arr.shape[mem.axis])
            bm = _broadcast_to_axis(am, arr.ndim, mem.axis)
            m = bm if m is None else jnp.maximum(m, jnp.broadcast_to(
                bm, m.shape))
            m = jnp.broadcast_to(m, arr.shape)
        if m is None:
            return jnp.zeros(arr.shape, jnp.float32)
        return m.astype(jnp.float32)

    def _mask_tree(self, params: dict, unit_masks: dict[str, jax.Array]
                   ) -> dict[str, jax.Array]:
        return {p: self._elem_mask(p, unit_masks, arr)
                for p, arr in params.items()}

    def _keep_elem_tree(self, params: dict, keep_units: dict[str, jax.Array]
                        ) -> dict[str, jax.Array]:
        """Elementwise keep: an element survives iff ALL covering units are
        kept — i.e. 1 - (any covering unit pruned)."""
        pruned_units = {k: 1.0 - v for k, v in keep_units.items()}
        pruned_elem = self._mask_tree(params, pruned_units)
        return {p: 1.0 - m for p, m in pruned_elem.items()}

    # ---------------------------------------------------------- stage bodies
    def _quant_sgd(self, qparams: dict, grads_q: dict) -> dict:
        """Plain SGD with the constant quant lr, positivity-guarded."""
        lr = self.cfg.lr_quant
        out = {}
        for name, qp in qparams.items():
            gq = grads_q[name]
            out[name] = Q.positivity_guard(Q.QuantParams(
                d=qp.d - lr * gq.d, q_m=qp.q_m - lr * gq.q_m,
                t=qp.t - lr * gq.t))
        return out

    def _project_all(self, qparams: dict, b_u_eff: jax.Array) -> dict:
        return {name: Q.project_step_size(qp, self.cfg.bit_lower, b_u_eff)
                for name, qp in qparams.items()}

    # Eq 16 / Eq 17 / Alg 4, one weight site ------------------------------
    @staticmethod
    def _site_stats_chunked(w, g, r, d0, qm, t):
        """The seven masked reductions of Eqs 15-17 for one weight tensor.

        A flat formulation leaves ~5 simultaneous f32 copies of every weight
        alive (the `pow` in clip/residual is expensive, so XLA materializes
        the shared subexpressions feeding multiple reductions — measured
        ~200 GB/device on the 398B configs). For stacked (n_blocks, ...)
        tensors we scan block-by-block along the *unsharded* leading axis
        (a reshape(-1) would all-gather sharded axes), scoping temps to one
        block. No AD flows through optimizer statistics, so the scan costs
        nothing in the backward."""

        def stats_of(ws, gs, rs):
            ws = ws.astype(jnp.float32)
            gs = gs.astype(jnp.float32)
            sign = jnp.sign(ws)
            clipv = sign * Q.clip_qmt(jnp.abs(ws), qm, t)
            resv = sign * Q.residual(jnp.abs(ws), d0, qm, t)
            return jnp.stack([
                jnp.sum(rs * gs * clipv),
                jnp.sum(rs * gs * resv),
                jnp.sum(rs * jnp.square(gs)),
                jnp.sum(rs * jnp.square(clipv)),
                jnp.sum(rs * jnp.square(resv)),
                jnp.sum(rs * jnp.abs(clipv)),
                jnp.sum(rs),
            ])

        if w.ndim < 3 or w.shape[0] == 1:
            return stats_of(w, g, r)

        def body(acc, inp):
            ws, gs, rs = inp
            return acc + stats_of(ws, gs, rs), None

        acc, _ = jax.lax.scan(body, jnp.zeros((7,), jnp.float32), (w, g, r))
        return acc

    def _joint_site(self, site: QuantSite, params: dict, grads: dict,
                    qparams: dict, red_elem: dict, alpha: jax.Array,
                    k_in_period: jax.Array):
        cfg = self.cfg
        qp = qparams[site.name]
        d0, qm, t = qp.d, qp.q_m, qp.t

        # gather redundant-restricted statistics over the site's weights.
        # Under a mesh, `_stat_reduce` pins each INPUT to the replicated
        # layout first: the reductions then run locally over full tensors
        # in a mesh-size-invariant order, so every replica — and the
        # 1-device reference — sees bit-identical stats (the downstream
        # cos-sign branches and the Alg 4 rescale loop are knife edges).
        stats = jnp.zeros((7,), jnp.float32)
        for pname in site.quantized_params:
            stats = stats + self._site_stats_chunked(
                self._stat_reduce(params[pname]),
                self._stat_reduce(grads[pname]),
                self._stat_reduce(red_elem[pname]), d0, qm, t)
        dot_clip, dot_res, n_g2, n_clip2, n_res2, clip_sum, cnt = stats

        n_g = jnp.sqrt(n_g2)
        n_clip = jnp.sqrt(n_clip2)
        n_res = jnp.sqrt(n_res2)
        clip_mean = clip_sum / jnp.maximum(cnt, 1.0)
        # angle between -g and -sgn*clip equals angle between g and sgn*clip
        cos_g = dot_clip / jnp.maximum(n_g * n_clip, _EPS)
        cos_d = dot_res / jnp.maximum(n_g * n_res, _EPS)

        has_red = cnt > 0.5
        case0 = jnp.logical_and(has_red, clip_mean <= cfg.eps)

        # Eq 16
        k_left = jnp.maximum(cfg.pruning_steps - k_in_period, 1.0)
        gamma_uniform = 1.0 / k_left          # 1 - (Kp-k-1)/(Kp-k)
        gamma_neg = -(1.0 - cfg.eta) * alpha * n_g / (
            cos_g * jnp.maximum(n_clip, _EPS))
        gamma = jnp.where(case0, 0.0,
                          jnp.where(cos_g >= 0, gamma_uniform, gamma_neg))
        gamma = jnp.where(has_red, gamma, 0.0)

        # Eq 17
        d_low = Q.step_size_for_bits(qm, t, jnp.float32(cfg.bit_lower))
        d_neg = -(cfg.xi * cfg.eta * alpha * n_g) / (
            jnp.maximum(gamma, _EPS) * cos_d * jnp.maximum(n_res, _EPS))
        d_new = jnp.where(cos_d >= 0, d_low, d_neg)
        # sites with nothing redundant keep their step size (projected)
        d_new = jnp.where(jnp.logical_and(has_red, gamma > 0), d_new, d0)

        # Alg 4: rescale (gamma, d) until b in [b_l, b_u_final]
        b_l = jnp.float32(cfg.bit_lower)
        b_u = jnp.float32(cfg.bit_upper_final)

        def bits(d):
            return Q.bit_width(d, qm, t)

        def cond(carry):
            g_, d_, it = carry
            b = bits(d_)
            return jnp.logical_and(
                jnp.logical_or(b > b_u + 1e-6, b < b_l - 1e-6), it < 200)

        def body(carry):
            g_, d_, it = carry
            b = bits(d_)
            too_high = b > b_u  # too many bits -> d too small
            g2 = jnp.where(too_high, cfg.beta * g_, g_)
            d2 = jnp.where(too_high, d_ / cfg.beta, cfg.beta * d_)
            return g2, d2, it + 1

        gamma, d_new, _ = jax.lax.while_loop(
            cond, body, (gamma, jnp.maximum(d_new, 1e-8),
                         jnp.zeros((), jnp.int32)))
        return gamma, d_new, case0

    # ------------------------------------------------------------- stages
    def _stage_warmup(self, params, qparams, gx, gq, state, lr, delta, base2):
        new_params = tree_add(params, delta)
        new_q = self._quant_sgd(qparams, gq)
        return new_params, new_q, state.redundant, state.keep_mask, state.gamma

    def _stage_projection(self, params, qparams, gx, gq, state, lr, delta,
                          base2):
        cfg = self.cfg
        new_params = tree_add(params, delta)
        # Alg 3 line 2: SGD on (d, q_m, t)
        new_q = self._quant_sgd(qparams, gq)
        # progressive range: period p reduces the upper bound by p*b_r
        period = (state.step - cfg.warmup_end) // jnp.maximum(
            cfg.projection_steps, 1)
        b_u_eff = jnp.maximum(
            jnp.float32(cfg.bit_upper) - cfg.bit_reduction
            * (period.astype(jnp.float32) + 1.0),
            jnp.float32(cfg.bit_lower))
        # Alg 3 lines 3-4: project only d
        new_q = self._project_all(new_q, b_u_eff)
        return new_params, new_q, state.redundant, state.keep_mask, state.gamma

    def _stage_joint(self, params, qparams, gx, gq, state, lr, delta, base2):
        cfg = self.cfg
        step = state.step
        joint_start = cfg.projection_end
        k_in_period = ((step - joint_start) % jnp.maximum(cfg.pruning_steps, 1)
                       ).astype(jnp.float32)
        period = (step - joint_start) // jnp.maximum(cfg.pruning_steps, 1)
        is_boundary = (step - joint_start) % jnp.maximum(
            cfg.pruning_steps, 1) == 0

        # Alg 2 lines 11-12: recompute the partition at period start,
        # progressive target round(K * (p+1)/P), sticky across periods.
        n_red = jnp.round(
            self.k_units * (period.astype(jnp.float32) + 1.0)
            / max(cfg.pruning_periods, 1)).astype(jnp.int32)

        def recompute(_):
            # sticky: previously redundant units are pinned (-inf score) so
            # they remain in G_R and count toward the progressive target.
            return global_redundancy_partition(
                self.space, params, gx, n_red, cfg.saliency,
                pinned=state.redundant, reduce=self._stat_reduce)

        redundant = jax.lax.cond(is_boundary, recompute,
                                 lambda _: state.redundant, None)

        red_elem = self._mask_tree(params, redundant)
        alpha = lr

        # line 14: (t, q_m) one SGD step (d handled by Eq 17 below)
        q_sgd = self._quant_sgd(qparams, gq)
        new_q = {}
        gammas = []
        site_gamma_for_param: dict[str, tuple[jax.Array, jax.Array]] = {}
        wsite_names = {s.name for s in self.weight_sites}
        for site in self.weight_sites:
            qp_s = Q.QuantParams(d=qparams[site.name].d,
                                 q_m=q_sgd[site.name].q_m,
                                 t=q_sgd[site.name].t)
            tmp_q = dict(qparams)
            tmp_q[site.name] = qp_s
            gamma, d_new, case0 = self._joint_site(
                site, params, gx, tmp_q, red_elem, alpha, k_in_period)
            new_q[site.name] = Q.positivity_guard(
                Q.QuantParams(d=d_new, q_m=qp_s.q_m, t=qp_s.t))
            gammas.append(gamma)
            for pname in site.quantized_params:
                site_gamma_for_param[pname] = (gamma, case0)
        # act sites: SGD + keep feasible (PPSG on the final range)
        for site in self.act_sites:
            new_q[site.name] = Q.project_step_size(
                q_sgd[site.name], cfg.bit_lower, cfg.bit_upper_final)
        for name in qparams:
            if name not in new_q:
                new_q[name] = q_sgd[name]

        # Eq 8 / Eq 9
        k_left = jnp.maximum(cfg.pruning_steps - k_in_period, 1.0)
        gamma_plain = 1.0 / k_left
        new_params = {}
        for pname, w in params.items():
            dlt = delta[pname]
            r = red_elem[pname]
            if pname in site_gamma_for_param:
                gamma, case0 = site_gamma_for_param[pname]
                # x_Q with the *new* step size (Alg 2 line 18)
                sname = self.site_of_param[pname]
                qp_n = new_q[sname]
                xq = Q.fake_quant(w, qp_n.d, qp_n.q_m, qp_n.t).astype(
                    jnp.float32)
                forget = gamma * xq
                upd = w + dlt - (r * forget).astype(w.dtype)
                upd = jnp.where(jnp.logical_and(case0, r > 0.5),
                                jnp.zeros_like(upd), upd)
            else:
                # non-quantized param: forget the raw value (x_Q := x)
                upd = w + dlt - (r * gamma_plain * w).astype(w.dtype)
            new_params[pname] = upd

        # joint end: hard-zero G_R, freeze keep mask (entering cool-down)
        is_last = step == (cfg.joint_end - 1)

        def finalize(args):
            prms, keep = args
            keep2 = {k: 1.0 - redundant[k] for k in keep}
            elem_keep = self._keep_elem_tree(prms, keep2)
            prms2 = {p: a * elem_keep[p].astype(a.dtype)
                     for p, a in prms.items()}
            return prms2, keep2

        new_params, keep_mask = jax.lax.cond(
            is_last, finalize, lambda a: a, (new_params, state.keep_mask))

        gamma_vec = (jnp.stack(gammas) if gammas
                     else jnp.zeros((1,), jnp.float32))
        return new_params, new_q, redundant, keep_mask, gamma_vec

    def _stage_cooldown(self, params, qparams, gx, gq, state, lr, delta,
                        base2):
        # line 22: fixed (d*, q_m*, t*); only G_I trains; G_R pinned at 0.
        keep_elem = self._keep_elem_tree(params, state.keep_mask)
        new_params = {p: (params[p] + delta[p]) * keep_elem[p].astype(
            params[p].dtype) for p in params}
        return new_params, qparams, state.redundant, state.keep_mask, \
            state.gamma

    # -------------------------------------------------------------- update
    def stage_index(self, step: jax.Array) -> jax.Array:
        cfg = self.cfg
        return (jnp.asarray(step >= cfg.warmup_end, jnp.int32)
                + jnp.asarray(step >= cfg.projection_end, jnp.int32)
                + jnp.asarray(step >= cfg.joint_end, jnp.int32))

    def update(self, params: dict, qparams: dict, gx: dict, gq: dict,
               state: QASSOState):
        cfg = self.cfg
        lr = self.lr_schedule(state.step)
        if cfg.grad_clip > 0:
            from repro.optim.base import clip_by_global_norm
            gx, _ = clip_by_global_norm(gx, cfg.grad_clip)

        # During cool-down, pruned units must not pollute base-opt moments.
        keep_elem = self._keep_elem_tree(params, state.keep_mask)
        gx_eff = {p: gx[p] * keep_elem[p].astype(gx[p].dtype) for p in gx}
        delta, base2 = self.base.update(gx_eff, state.base, params, lr)

        stage = self.stage_index(state.step)
        branches = [self._stage_warmup, self._stage_projection,
                    self._stage_joint, self._stage_cooldown]
        new_params, new_q, redundant, keep_mask, gamma = jax.lax.switch(
            stage, [lambda a, b=b: b(*a) for b in branches],
            (params, qparams, gx, gq, state, lr, delta, base2))

        new_state = QASSOState(step=state.step + 1, base=base2,
                               redundant=redundant, keep_mask=keep_mask,
                               gamma=gamma)
        bits = jnp.stack([Q.bit_width(new_q[s.name].d, new_q[s.name].q_m,
                                      new_q[s.name].t)
                          for s in self.sites]) if self.sites else \
            jnp.zeros((1,))
        metrics = {
            "stage": stage,
            "lr": lr,
            "sparsity_hard": self.space.sparsity(keep_mask),
            "sparsity_partition": self.space.sparsity(
                {k: 1.0 - v for k, v in redundant.items()}),
            "bits_mean": jnp.mean(bits),
            "bits_min": jnp.min(bits),
            "bits_max": jnp.max(bits),
            "gamma_mean": jnp.mean(gamma),
        }
        return new_params, new_q, new_state, metrics

"""Trace-graph representation of a model, the substrate for QADG (paper §4).

JAX has no module graph to trace (unlike torch.fx), so tracing is a
first-class model-definition concept in this framework: every layer in
`repro.models` registers its operators into a `TraceGraph` through the
`GraphBuilder` API while the parameter pytree is being initialized. The
resulting graph contains exactly the structures Algorithm 1 operates on:

- ordinary compute vertices (`linear`, `conv`, `norm`, `act`, `add`, ...),
- *attached branches*: the parameterized weight-quantization subgraph
  (`param d/q_m/t -> pow -> clip -> div -> round -> mul`) hanging off a
  weight-carrying vertex,
- *inserted branches*: the activation-quantization subgraph spliced between
  an activation vertex and its consumer,

including the weight-sharing (the same `d` feeding both `div` and `mul`)
and shape-ambiguous (`reshape` broadcast) vertices that break prior
dependency-graph analyses and that Algorithm 1 exists to eliminate.

Composite vertices (`attention`, `moe`, `mamba`, `rwkv_timemix`) carry a
structured pruning spec (head groups / experts / state channels) because
their minimally-removable structure is coarser than a single channel — the
same treatment OTOv2-style analyses give multi-head attention.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterable, Optional

# Vertex op taxonomy -------------------------------------------------------
PRODUCER_OPS = {"linear", "conv", "embedding"}
JOINT_OPS = {"norm", "bn", "act", "dropout", "pool", "scale", "rope", "identity"}
ADD_OPS = {"add"}
QUANT_OPS = {"q_param", "q_pow", "q_clip", "q_div", "q_round", "q_mul",
             "q_reshape"}
COMPOSITE_OPS = {"attention", "moe", "mamba", "rwkv_timemix", "rwkv_chanmix",
                 "conv_dw"}
SINK_OPS = {"output", "loss"}


@dataclasses.dataclass
class Vertex:
    vid: str
    op: str
    # parameter names owned by this vertex (entries of the model pytree)
    params: dict[str, Any] = dataclasses.field(default_factory=dict)
    # axis of the *weight* along which output channels live (producers)
    out_axis: Optional[int] = None
    # axis of the weight along which input channels live (producers)
    in_axis: Optional[int] = None
    # structured spec for composite vertices (see FamilySpec)
    spec: Optional["FamilySpec"] = None
    # free-form metadata (dims, weight-sharing ids, quant branch tags, ...)
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def is_quant(self) -> bool:
        return self.op in QUANT_OPS


@dataclasses.dataclass
class FamilySpec:
    """Structured pruning spec a composite vertex contributes directly.

    `units`: number of minimally-removable structures (kv-head groups,
    experts, state channels, ...).
    `members`: list of (param_name, axis, unit_size) — the param's `axis`
    has length units * unit_size and unit i owns the contiguous slice
    [i*unit_size, (i+1)*unit_size).
    """
    name: str
    units: int
    members: list[tuple[str, int, int]]
    prunable: bool = True
    kind: str = "composite"  # "channel" | "head_group" | "expert" | ...


class TraceGraph:
    def __init__(self) -> None:
        self.vertices: dict[str, Vertex] = {}
        self.succ: dict[str, list[str]] = {}
        self.pred: dict[str, list[str]] = {}
        self._uid = itertools.count()

    # -- construction ------------------------------------------------------
    def add_vertex(self, v: Vertex) -> Vertex:
        if v.vid in self.vertices:
            raise ValueError(f"duplicate vertex {v.vid}")
        self.vertices[v.vid] = v
        self.succ.setdefault(v.vid, [])
        self.pred.setdefault(v.vid, [])
        return v

    def connect(self, src: str, dst: str) -> None:
        if src not in self.vertices or dst not in self.vertices:
            raise KeyError(f"connect({src!r}, {dst!r}): unknown vertex")
        if dst not in self.succ[src]:
            self.succ[src].append(dst)
            self.pred[dst].append(src)

    def disconnect(self, src: str, dst: str) -> None:
        self.succ[src].remove(dst)
        self.pred[dst].remove(src)

    def remove_vertex(self, vid: str) -> None:
        for s in list(self.succ[vid]):
            self.disconnect(vid, s)
        for p in list(self.pred[vid]):
            self.disconnect(p, vid)
        del self.vertices[vid]
        del self.succ[vid]
        del self.pred[vid]

    def fresh_id(self, prefix: str) -> str:
        return f"{prefix}#{next(self._uid)}"

    # -- queries -----------------------------------------------------------
    def topo_order(self) -> list[str]:
        indeg = {v: len(self.pred[v]) for v in self.vertices}
        stack = [v for v, d in indeg.items() if d == 0]
        out: list[str] = []
        while stack:
            v = stack.pop()
            out.append(v)
            for s in self.succ[v]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    stack.append(s)
        if len(out) != len(self.vertices):
            raise ValueError("trace graph has a cycle")
        return out

    def quant_vertices(self) -> list[str]:
        return [vid for vid, v in self.vertices.items() if v.is_quant]

    def validate(self) -> None:
        self.topo_order()
        for vid, outs in self.succ.items():
            for o in outs:
                assert vid in self.pred[o], (vid, o)


class GraphBuilder:
    """Fluent API the model zoo uses to declare its trace graph.

    Chains vertices automatically: each call connects the new vertex to the
    `after` vertex (default: the previously added one).
    """

    def __init__(self) -> None:
        self.graph = TraceGraph()
        self._last: Optional[str] = None

    # -- core ops ----------------------------------------------------------
    def _add(self, v: Vertex, after: Optional[str | list[str]]) -> str:
        self.graph.add_vertex(v)
        if after is None and self._last is not None:
            after = self._last
        if after is not None:
            for a in ([after] if isinstance(after, str) else after):
                self.graph.connect(a, v.vid)
        self._last = v.vid
        return v.vid

    def input(self, vid: str = "input") -> str:
        return self._add(Vertex(vid, "identity"), after=[])

    def linear(self, vid: str, w: str, *, bias: str | None = None,
               out_axis: int = 1, in_axis: int = 0,
               after: Optional[str | list[str]] = None, **meta) -> str:
        params = {"w": w}
        if bias:
            params["b"] = bias
        return self._add(Vertex(vid, "linear", params=params,
                                out_axis=out_axis, in_axis=in_axis,
                                meta=meta), after)

    def conv(self, vid: str, w: str, *, bias: str | None = None,
             after=None, **meta) -> str:
        # HWIO layout: out_axis=3, in_axis=2
        params = {"w": w}
        if bias:
            params["b"] = bias
        return self._add(Vertex(vid, "conv", params=params, out_axis=3,
                                in_axis=2, meta=meta), after)

    def embedding(self, vid: str, w: str, *, out_axis: int = 1,
                  after=None, **meta) -> str:
        return self._add(Vertex(vid, "embedding", params={"w": w},
                                out_axis=out_axis, meta=meta), after)

    def norm(self, vid: str, scale: str | None = None,
             bias: str | None = None, after=None, **meta) -> str:
        params = {}
        if scale:
            params["scale"] = scale
        if bias:
            params["bias"] = bias
        return self._add(Vertex(vid, "norm", params=params, meta=meta), after)

    def bn(self, vid: str, scale: str, bias: str, after=None, **meta) -> str:
        return self._add(Vertex(vid, "bn",
                                params={"scale": scale, "bias": bias},
                                meta=meta), after)

    def act(self, vid: str, after=None, **meta) -> str:
        return self._add(Vertex(vid, "act", meta=meta), after)

    def add(self, vid: str, inputs: list[str], **meta) -> str:
        return self._add(Vertex(vid, "add", meta=meta), after=inputs)

    def pool(self, vid: str, after=None, **meta) -> str:
        return self._add(Vertex(vid, "pool", meta=meta), after)

    def output(self, vid: str = "output", after=None) -> str:
        return self._add(Vertex(vid, "output"), after)

    def composite(self, vid: str, op: str, spec: FamilySpec, params: dict,
                  after=None, **meta) -> str:
        assert op in COMPOSITE_OPS, op
        return self._add(Vertex(vid, op, params=params, spec=spec,
                                meta=meta), after)

    # -- quantization branches (paper Fig. 2) ------------------------------
    def attach_weight_quant(self, root_vid: str, qprefix: str,
                            target_param: str | None = None) -> list[str]:
        """Grow the *attached branch* of Fig 2(a) off a weight-carrying root.

        The branch deliberately contains the weight-sharing (`d` feeds both
        q_div and q_mul) and a shape-ambiguous `q_reshape` vertex — the
        structures Algorithm 1 must merge away.

        `target_param`: the parameter flowing through this quantizer; for
        composite roots (attention/moe/...) with several weights, one branch
        is attached per weight, each with its own qprefix.
        Returns the branch vertex ids.
        """
        g = self.graph
        root = g.vertices[root_vid]
        ids = []

        def q(vid_suffix, op, params=None, meta=None):
            vid = f"{qprefix}.{vid_suffix}"
            g.add_vertex(Vertex(vid, op, params=params or {},
                                meta={"qbranch": "attached",
                                      "qroot": root_vid,
                                      "qprefix": qprefix,
                                      "qtarget": target_param,
                                      **(meta or {})}))
            ids.append(vid)
            return vid

        d = q("d", "q_param", {"d": f"{qprefix}.d"})
        qm = q("q_m", "q_param", {"q_m": f"{qprefix}.q_m"})
        t = q("t", "q_param", {"t": f"{qprefix}.t"})
        pw = q("pow", "q_pow")
        cl = q("clip", "q_clip")
        dv = q("div", "q_div")
        rd = q("round", "q_round")
        rs = q("reshape", "q_reshape", meta={"shape_ambiguous": True})
        ml = q("mul", "q_mul")

        # wiring: root -> pow -> clip -> div -> round -> reshape -> mul -> root
        g.connect(root_vid, pw)
        g.connect(t, pw)
        g.connect(pw, cl)
        g.connect(qm, cl)
        g.connect(cl, dv)
        g.connect(d, dv)          # d used here ...
        g.connect(dv, rd)
        g.connect(rd, rs)
        g.connect(rs, ml)
        g.connect(d, ml)          # ... and shared here (weight sharing)
        g.connect(ml, root_vid)   # cycle back: handled/merged by Alg 1
        root.meta.setdefault("weight_quant", []).append(qprefix)
        return ids

    def insert_act_quant(self, root_vid: str, end_vid: str,
                         qprefix: str) -> list[str]:
        """Splice the *inserted branch* of Fig 2(b) between an activation
        (root) and its consumer (end). Returns branch vertex ids."""
        g = self.graph
        ids = []

        def q(vid_suffix, op, params=None, meta=None):
            vid = f"{qprefix}.{vid_suffix}"
            g.add_vertex(Vertex(vid, op, params=params or {},
                                meta={"qbranch": "inserted",
                                      "qroot": root_vid, "qend": end_vid,
                                      **(meta or {})}))
            ids.append(vid)
            return vid

        d = q("d", "q_param", {"d": f"{qprefix}.d"})
        qm = q("q_m", "q_param", {"q_m": f"{qprefix}.q_m"})
        t = q("t", "q_param", {"t": f"{qprefix}.t"})
        pw = q("pow", "q_pow")
        cl = q("clip", "q_clip")
        dv = q("div", "q_div")
        rd = q("round", "q_round")
        ml = q("mul", "q_mul")

        g.disconnect(root_vid, end_vid)
        g.connect(root_vid, pw)
        g.connect(t, pw)
        g.connect(pw, cl)
        g.connect(qm, cl)
        g.connect(cl, dv)
        g.connect(d, dv)
        g.connect(dv, rd)
        g.connect(rd, ml)
        g.connect(d, ml)
        g.connect(ml, end_vid)
        g.vertices[end_vid].meta.setdefault("act_quant", qprefix)
        return ids

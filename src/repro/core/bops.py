"""BOPs (bit operations) accounting — the paper's efficiency metric (§6).

BOPs of a layer = MACs * b_w * b_a, where b_w / b_a are the weight /
activation bit widths feeding that layer. Structured pruning reduces MACs;
quantization reduces b_w (and b_a when activation quantizers are attached).
We report relative BOPs against the full-precision (32x32) baseline, the
quantity in the paper's tables.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.qadg import QADG
from repro.core.quant import bit_width


@dataclasses.dataclass(frozen=True)
class LayerMacs:
    """Static MAC count of one weight-carrying layer at reference input."""
    vertex: str
    macs: float          # full (unpruned) MACs
    weight_param: str    # key into params


def layer_macs_linear(vertex: str, w_shape, tokens: int,
                      weight_param: str) -> LayerMacs:
    in_dim, out_dim = w_shape[-2], w_shape[-1]
    return LayerMacs(vertex, float(tokens) * in_dim * out_dim, weight_param)


def layer_macs_conv(vertex: str, w_shape, out_hw: tuple[int, int],
                    batch: int, weight_param: str) -> LayerMacs:
    kh, kw, cin, cout = w_shape
    return LayerMacs(
        vertex, float(batch) * out_hw[0] * out_hw[1] * kh * kw * cin * cout,
        weight_param)


def model_bops(qadg: QADG, params: dict, qparams: dict,
               layer_macs: list[LayerMacs],
               masks: Optional[dict] = None,
               act_bits_default: float = 32.0,
               weight_bits_default: float = 32.0) -> dict:
    """Compute absolute and relative BOPs.

    `masks`: per-family keep masks; pruning scales a layer's MACs by
    (kept fraction of its input space) * (kept fraction of its output space),
    derived from the elementwise survival of the weight tensor.
    """
    site_by_target = {}
    for s in qadg.sites:
        site_by_target.setdefault(s.target, {})[s.kind] = s

    # survival fraction per weight param from masks
    def survival(pname: str) -> float:
        if masks is None:
            return 1.0
        frac = 1.0
        for fam in qadg.space.prunable_families():
            for m in fam.members:
                if m.param == pname:
                    keep = float(np.mean(np.asarray(masks[fam.name]) > 0.5))
                    frac *= keep
        return frac

    total = 0.0
    baseline = 0.0
    per_layer = {}
    for lm in layer_macs:
        sites = site_by_target.get(lm.vertex, {})
        if "weight" in sites:
            s = sites["weight"]
            qp = qparams[s.name]
            bw = float(bit_width(qp.d, qp.q_m, qp.t))
        else:
            bw = weight_bits_default
        if "act" in sites:
            s = sites["act"]
            qp = qparams[s.name]
            ba = float(bit_width(qp.d, qp.q_m, qp.t))
        else:
            ba = act_bits_default
        macs = lm.macs * survival(lm.weight_param)
        bops = macs * bw * ba
        base = lm.macs * 32.0 * 32.0
        per_layer[lm.vertex] = {"macs": macs, "b_w": bw, "b_a": ba,
                                "bops": bops}
        total += bops
        baseline += base
    return {"bops": total, "baseline_bops": baseline,
            "rel_bops": total / max(baseline, 1.0), "per_layer": per_layer}

"""GETA-JAX: joint structured pruning + quantization-aware training,
as a multi-pod JAX framework. See README.md / DESIGN.md."""
__version__ = "1.0.0"

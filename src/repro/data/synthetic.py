"""Deterministic synthetic data pipelines (stateless, shardable, replayable).

Every batch is a pure function of (seed, step) — the property the fault-
tolerance design relies on: after restart at step k, batch(k) is bit-
identical, so no data-state checkpointing is needed and elastic reshards
replay exactly.

LM stream: a structured Markov-ish token process (next token depends on the
previous token plus a position signal) so models measurably learn; labels
for CIFAR-like images depend on class-conditional means so CNNs can fit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
             n_codebooks: int = 0, key=None) -> dict:
    """`key`, when given, REPLACES the (seed, step) derivation — the train
    loop threads its checkpointed data key here so a restored run replays
    the exact stream (the caller guarantees key == fold_in(PRNGKey(seed),
    step), which keeps the stream identical to the stateless form)."""
    if key is None:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    shape = (batch, seq, n_codebooks) if n_codebooks else (batch, seq)
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, shape, 0, vocab)
    # learnable structure: token t+1 correlated with token t
    shifted = jnp.roll(base, 1, axis=1)
    mix = jax.random.bernoulli(k2, 0.7, shape)
    tokens = jnp.where(mix, (shifted * 31 + 7) % vocab, base)
    return {"tokens": tokens.astype(jnp.int32)}


def qa_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 7919), step)
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (batch, seq), 0, vocab)
    start = jax.random.randint(k2, (batch,), 0, seq // 2)
    length = jax.random.randint(k3, (batch,), 1, seq // 4)
    end = jnp.minimum(start + length, seq - 1)
    # plant an answer signature the model can find: marker tokens
    marker_s = vocab - 2
    marker_e = vocab - 1
    b = jnp.arange(batch)
    tokens = tokens.at[b, start].set(marker_s)
    tokens = tokens.at[b, end].set(marker_e)
    return {"tokens": tokens.astype(jnp.int32),
            "start": start.astype(jnp.int32), "end": end.astype(jnp.int32)}


def image_batch(seed: int, step: int, batch: int, hw: int = 32,
                classes: int = 10) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 104729), step)
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch,), 0, classes)
    noise = jax.random.normal(k2, (batch, hw, hw, 3))
    # class-conditional mean pattern (fixed by class id, learnable)
    base_key = jax.random.PRNGKey(12345)
    means = jax.random.normal(base_key, (classes, hw, hw, 3)) * 1.5
    images = means[labels] + noise
    return {"images": images, "labels": labels}


def vlm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
              patches: int, d_model: int, dtype=jnp.bfloat16,
              key=None) -> dict:
    out = lm_batch(seed, step, batch, seq, vocab, key=key)
    vkey = jax.random.fold_in(jax.random.PRNGKey(seed + 31337), step)
    out["vision_embeds"] = (jax.random.normal(
        vkey, (batch, patches, d_model)) * 0.02).astype(dtype)
    return out


def batch_for(cfg, seed: int, step: int, batch: int, seq: int,
              key=None) -> dict:
    """Model-family-aware batch builder (the stub 'modality frontend').
    `key` optionally carries the checkpointed per-step data key (see
    `lm_batch`)."""
    if cfg.family == "audio":
        return lm_batch(seed, step, batch, seq, cfg.vocab,
                        n_codebooks=cfg.num_codebooks, key=key)
    if cfg.family == "vlm":
        return vlm_batch(seed, step, batch, seq - cfg.vision_patches,
                         cfg.vocab, cfg.vision_patches, cfg.d_model,
                         dtype=jnp.bfloat16 if cfg.dtype == "bfloat16"
                         else jnp.float32, key=key)
    return lm_batch(seed, step, batch, seq, cfg.vocab, key=key)

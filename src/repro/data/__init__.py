from repro.data.synthetic import (batch_for, image_batch, lm_batch, qa_batch,
                                  vlm_batch)
